//! Logical→physical index translation, including multi-object splits.
//!
//! The mapper is the runtime-facing half of tensor virtualization: given a
//! [`TensorDescriptor`] (or a weight layout + split), it translates logical
//! element coordinates into `(object, native coords, lane)` physical
//! indices. The translation is *established once* (here, and in shader form
//! by [`crate::translate`]) so it adds no per-access runtime latency.

use crate::tensor::layout::{WeightLayout, WeightShape};
use crate::vgpu::descriptor::TensorDescriptor;
use crate::vgpu::object::{GpuObject, ObjectKind, StorageType};

/// A resolved physical location: which object, which native coordinates
/// (u, v, layer/depth as applicable), and which lane within the vec4 texel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PhysicalIndex {
    pub object: usize,
    /// Native coords, meaning depends on storage: buffers use `[flat,0,0]`
    /// (element index), image buffers `[texel,0,0]`, 2D textures `[u,v,0]`,
    /// 3D/array textures `[u,v,w]`.
    pub coords: [usize; 3],
    /// Lane within the vec4 texel (equals `C4` for activations, `I4`/`O4`
    /// for weights depending on layout).
    pub lane: usize,
}

/// Mapping for an activation tensor realized as one or more objects.
#[derive(Clone, Debug)]
pub struct VirtualMapping {
    desc: TensorDescriptor,
    /// Number of physical objects the tensor is distributed across. The
    /// split axis is the outermost coordinate group (e.g. slice planes), so
    /// each object holds a contiguous sub-volume.
    pub objects: usize,
    /// Texels per object (all objects equal; last may be padded).
    pub texels_per_object: usize,
    /// Cached coordinate-group extents (outermost first): `map()` is a
    /// host-side packing hot path, so `coord_extents()`'s per-call `Vec`
    /// allocation is hoisted to construction time (EXPERIMENTS.md §Perf).
    ext: [usize; 3],
}

impl VirtualMapping {
    fn cache_ext(desc: &TensorDescriptor) -> [usize; 3] {
        let e = desc.coord_extents();
        match e.len() {
            1 => [e[0], 1, 1],
            2 => [e[0], e[1], 1],
            _ => [e[0], e[1], e[2]],
        }
    }

    /// Single-object mapping.
    pub fn single(desc: TensorDescriptor) -> Self {
        let texels = desc.texels();
        let ext = Self::cache_ext(&desc);
        VirtualMapping { desc, objects: 1, texels_per_object: texels, ext }
    }

    /// Split across `n` objects along the outermost coordinate group —
    /// the Fig. 2 pattern generalized (a convolution kernel reading several
    /// textures simultaneously to improve cache behaviour).
    pub fn split(desc: TensorDescriptor, n: usize) -> Self {
        let n = n.max(1);
        let ext = Self::cache_ext(&desc);
        let outer = ext[0];
        // Split along the outer axis in contiguous blocks.
        let outer_per_obj = outer.div_ceil(n);
        let inner: usize = desc.coord_extents()[1..].iter().product();
        VirtualMapping { desc, objects: n, texels_per_object: outer_per_obj * inner, ext }
    }

    pub fn descriptor(&self) -> &TensorDescriptor {
        &self.desc
    }

    /// Translate logical `(b,h,w,d,c)` to a physical index.
    pub fn map(&self, b: usize, h: usize, w: usize, d: usize, c: usize) -> PhysicalIndex {
        let flat = self.desc.layout.linear_index(&self.desc.shape, b, h, w, d, c);
        let lane = flat % 4;
        let texel = flat / 4;
        let (object, local_texel) = if self.objects == 1 {
            (0, texel)
        } else {
            (texel / self.texels_per_object, texel % self.texels_per_object)
        };
        let coords = match self.desc.storage {
            StorageType::Buffer => [flat - object * self.texels_per_object * 4, 0, 0],
            StorageType::ImageBuffer => [local_texel, 0, 0],
            StorageType::Texture2D => {
                let width = self.ext[1];
                [local_texel % width, local_texel / width, 0]
            }
            StorageType::Texture2DArray | StorageType::Texture3D => {
                let (width, height) = (self.ext[2], self.ext[1]);
                [
                    local_texel % width,
                    (local_texel / width) % height,
                    local_texel / (width * height),
                ]
            }
        };
        PhysicalIndex { object, coords, lane }
    }

    /// Realize all objects (equal-size sub-volumes of the descriptor).
    pub fn realize_objects(&self) -> Vec<GpuObject> {
        if self.objects == 1 {
            return vec![self.desc.realize()];
        }
        (0..self.objects)
            .map(|i| {
                let name = format!("{}.{i}", self.desc.name);
                let kind = match self.desc.storage {
                    StorageType::Buffer => ObjectKind::Buffer { len: self.texels_per_object * 4 },
                    StorageType::ImageBuffer => {
                        ObjectKind::ImageBuffer { texels: self.texels_per_object }
                    }
                    StorageType::Texture2D => {
                        let ext = self.desc.coord_extents();
                        ObjectKind::Texture2D {
                            width: ext[1],
                            height: self.texels_per_object / ext[1],
                        }
                    }
                    StorageType::Texture2DArray | StorageType::Texture3D => {
                        let ext = self.desc.coord_extents();
                        ObjectKind::Texture2DArray {
                            width: ext[2],
                            height: ext[1],
                            layers: self.texels_per_object / (ext[1] * ext[2]),
                        }
                    }
                };
                GpuObject::new(&name, kind, self.desc.dtype)
            })
            .collect()
    }
}

/// Mapping for convolution / fully-connected weights distributed across
/// `G · S_I` 2D textures — the exact arrangement of the paper's Figure 2:
/// an OHWI (5,2,1,7) weight tensor as four (4,2) textures, texel = vec4 of
/// input channels, width covering `O4·W·D`, height covering `S_O·H`.
#[derive(Clone, Debug)]
pub struct WeightTextureSplit {
    pub shape: WeightShape,
    pub layout: WeightLayout,
}

impl WeightTextureSplit {
    pub fn new(shape: WeightShape, layout: WeightLayout) -> Self {
        WeightTextureSplit { shape, layout }
    }

    /// Number of textures: one per (group, input-slice) pair.
    pub fn num_objects(&self) -> usize {
        self.layout.group * self.shape.slices_i()
    }

    /// Per-texture dimensions in texels: width = `O4 · W · D`, height =
    /// `S_O · H`; each texel is a vec4 of 4 input channels (`I4`).
    pub fn texture_dims(&self) -> (usize, usize) {
        let so = self.layout.so_extent(&self.shape);
        (4 * self.shape.w * self.shape.d, so * self.shape.h)
    }

    /// Translate logical weight element `(o,h,w,d,i)` to a physical index.
    pub fn map(&self, o: usize, h: usize, w: usize, d: usize, i: usize) -> PhysicalIndex {
        let so_ext = self.layout.so_extent(&self.shape);
        let slice_o = o / 4;
        let g = slice_o / so_ext;
        let so = slice_o % so_ext;
        let si = i / 4;
        let object = g * self.shape.slices_i() + si;
        let (width, _h) = self.texture_dims();
        // u covers (w, d, o4); v covers (so, h).
        let u = (w * self.shape.d + d) * 4 + o % 4;
        let v = so * self.shape.h + h;
        debug_assert!(u < width);
        PhysicalIndex { object, coords: [u, v, 0], lane: i % 4 }
    }

    /// Realize the texture array objects.
    pub fn realize_objects(&self, dtype: crate::tensor::DType, name: &str) -> Vec<GpuObject> {
        let (w, h) = self.texture_dims();
        (0..self.num_objects())
            .map(|i| {
                GpuObject::new(&format!("{name}.{i}"), ObjectKind::Texture2D { width: w, height: h }, dtype)
            })
            .collect()
    }
}

/// Convenience: exhaustively verify a mapping is injective over texel+lane
/// positions (used by tests and the property suite).
pub fn mapping_is_injective(m: &VirtualMapping) -> bool {
    let s = m.descriptor().shape;
    let mut seen = std::collections::HashSet::new();
    for b in 0..s.b {
        for h in 0..s.h {
            for w in 0..s.w {
                for d in 0..s.d {
                    for c in 0..s.c {
                        let p = m.map(b, h, w, d, c);
                        if !seen.insert((p.object, p.coords, p.lane)) {
                            return false;
                        }
                    }
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, Shape};
    use crate::util::propcheck::{check, Config};

    fn fig1_desc(storage: StorageType) -> TensorDescriptor {
        TensorDescriptor::with_default_layout("t", Shape::bhwc(1, 2, 3, 5), DType::F16, storage)
            .unwrap()
    }

    #[test]
    fn single_mapping_injective_all_storages() {
        for st in [
            StorageType::Buffer,
            StorageType::ImageBuffer,
            StorageType::Texture2D,
            StorageType::Texture3D,
        ] {
            let m = VirtualMapping::single(fig1_desc(st));
            assert!(mapping_is_injective(&m), "not injective for {st}");
        }
    }

    #[test]
    fn texture2d_coords_match_table1() {
        // Table 1, 2D texture row: (x·batch + b, y·slice + s) for BHWC.
        let m = VirtualMapping::single(fig1_desc(StorageType::Texture2D));
        let s = Shape::bhwc(1, 2, 3, 5);
        for h in 0..2 {
            for w in 0..3 {
                for c in 0..5 {
                    let p = m.map(0, h, w, 0, c);
                    assert_eq!(p.coords[0], w * s.b, "u = x·batch + b");
                    assert_eq!(p.coords[1], h * s.slices() + c / 4, "v = y·slice + s");
                    assert_eq!(p.lane, c % 4);
                }
            }
        }
    }

    #[test]
    fn texture3d_coords_match_table1() {
        // Table 1, 3D texture row: (x·batch + b, y, s).
        let m = VirtualMapping::single(fig1_desc(StorageType::Texture3D));
        for h in 0..2 {
            for w in 0..3 {
                for c in 0..5 {
                    let p = m.map(0, h, w, 0, c);
                    assert_eq!(p.coords, [w, h, c / 4]);
                }
            }
        }
    }

    #[test]
    fn buffer_flat_index_matches_table1() {
        // Table 1, 1D buffer row: ((s·height + y)·width + x)·batch + b.
        let m = VirtualMapping::single(fig1_desc(StorageType::ImageBuffer));
        let s = Shape::bhwc(1, 2, 3, 5);
        for h in 0..2 {
            for w in 0..3 {
                for c in 0..5 {
                    let p = m.map(0, h, w, 0, c);
                    let expect = ((c / 4) * s.h + h) * s.w + w; // b = 0, B = 1
                    assert_eq!(p.coords[0], expect);
                }
            }
        }
    }

    #[test]
    fn split_mapping_covers_multiple_objects() {
        let desc = TensorDescriptor::with_default_layout(
            "t",
            Shape::bhwc(1, 4, 4, 16), // 4 slices
            DType::F16,
            StorageType::ImageBuffer,
        )
        .unwrap();
        let m = VirtualMapping::split(desc, 4);
        assert_eq!(m.objects, 4);
        assert!(mapping_is_injective(&m));
        let mut used: Vec<bool> = vec![false; 4];
        let s = Shape::bhwc(1, 4, 4, 16);
        for h in 0..s.h {
            for w in 0..s.w {
                for c in 0..s.c {
                    used[m.map(0, h, w, 0, c).object] = true;
                }
            }
        }
        assert!(used.iter().all(|u| *u), "all objects referenced");
    }

    #[test]
    fn figure2_weight_split() {
        // OHWI (5,2,1,7) with G=2 → 4 textures of (4,2), 8 vec4 each.
        let ws = WeightShape::ohwi(5, 2, 1, 7);
        let split = WeightTextureSplit::new(ws, WeightLayout::gso_hwdsi_o4i4(2));
        assert_eq!(split.num_objects(), 4);
        assert_eq!(split.texture_dims(), (4, 2));
        let objs = split.realize_objects(DType::F16, "w");
        assert_eq!(objs.len(), 4);
        assert_eq!(objs[0].kind.elements(), 32); // 8 texels · 4

        // Injectivity across (object, coords, lane).
        let mut seen = std::collections::HashSet::new();
        for o in 0..5 {
            for h in 0..2 {
                for i in 0..7 {
                    let p = split.map(o, h, 0, 0, i);
                    assert!(p.object < 4);
                    assert!(seen.insert((p.object, p.coords, p.lane)));
                }
            }
        }
    }

    #[test]
    fn property_split_mappings_injective() {
        check("virtual mapping injective under random splits", Config::cases(30), |rng| {
            let shape = Shape::bhwc(
                1 + rng.gen_range(2) as usize,
                1 + rng.gen_range(6) as usize,
                1 + rng.gen_range(6) as usize,
                1 + rng.gen_range(20) as usize,
            );
            let storage = *rng.choose(&[
                StorageType::Buffer,
                StorageType::ImageBuffer,
                StorageType::Texture2D,
                StorageType::Texture3D,
            ]);
            let desc =
                TensorDescriptor::with_default_layout("t", shape, DType::F16, storage).unwrap();
            let n = 1 + rng.gen_range(4) as usize;
            let m = VirtualMapping::split(desc, n);
            if mapping_is_injective(&m) {
                Ok(())
            } else {
                Err(format!("collision: shape {shape}, storage {storage}, n {n}"))
            }
        });
    }
}
