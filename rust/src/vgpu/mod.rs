//! Tensor virtualization (paper §3.2).
//!
//! *Tensor virtualization* decouples a tensor's **logical** representation
//! (a BHWDC array) from its **physical** storage on the GPU (buffers,
//! image buffers, 2D/3D textures, texture arrays — possibly *several*
//! objects for one tensor). An abstraction layer owns the mapping between
//! logical tensor indices and physical GPU object indices, handling
//! fragmentation and distribution, so kernel authors never touch low-level
//! memory concerns.
//!
//! * [`object`] — the physical GPU object model and device limits.
//! * [`descriptor`] — a logical tensor bound to a storage decision
//!   (object type + layout + split policy).
//! * [`mapper`] — the logical→physical index translation, including the
//!   multi-object split of Fig. 2 (one tensor across four textures).

pub mod object;
pub mod descriptor;
pub mod mapper;

pub use object::{GpuObject, ObjectKind, StorageType, TextureLimits};
pub use descriptor::TensorDescriptor;
pub use mapper::{PhysicalIndex, VirtualMapping};
