//! Tensor descriptors: a logical tensor bound to a storage decision.

use crate::error::{DriftError, Result};
use crate::tensor::{ActDim, ActivationLayout, DType, Shape};
use crate::vgpu::object::{GpuObject, ObjectKind, StorageType, TextureLimits};

/// A logical tensor together with the physical realization choice made for
/// it (storage type + slice-aware layout). Producing the concrete
/// [`GpuObject`]s is [`TensorDescriptor::realize`]; index translation lives
/// in [`crate::vgpu::mapper`].
///
/// The paper's Figure 1 example: the logical (1,2,3,5) tensor realized as
/// a 3D texture (2,3,2) in `DSHWBC4`, a 2D texture (4,3) in `HSWBDC4`, or a
/// 12-pixel image buffer in `DSHWBC4`.
#[derive(Clone, Debug)]
pub struct TensorDescriptor {
    pub name: String,
    pub shape: Shape,
    pub dtype: DType,
    pub layout: ActivationLayout,
    pub storage: StorageType,
}

impl TensorDescriptor {
    pub fn new(
        name: &str,
        shape: Shape,
        dtype: DType,
        layout: ActivationLayout,
        storage: StorageType,
    ) -> Result<Self> {
        if layout.order.last() != Some(&ActDim::C4) {
            return Err(DriftError::Layout(format!(
                "descriptor {name}: layout {} must keep C4 innermost so texels are 4-channel slices",
                layout.name
            )));
        }
        Ok(TensorDescriptor {
            name: name.to_string(),
            shape,
            dtype,
            layout,
            storage,
        })
    }

    /// Default pairing used by the framework when the device profile has no
    /// overriding preference: buffers/image buffers and 3D textures take
    /// `DSHWBC4`; 2D textures take `HSWBDC4` (automatic zero clamp on H).
    pub fn with_default_layout(
        name: &str,
        shape: Shape,
        dtype: DType,
        storage: StorageType,
    ) -> Result<Self> {
        let layout = match storage {
            StorageType::Texture2D => ActivationLayout::hswbdc4(),
            _ => ActivationLayout::dshwbc4(),
        };
        Self::new(name, shape, dtype, layout, storage)
    }

    /// Total vec4 texels (padded elements / 4).
    pub fn texels(&self) -> usize {
        self.layout.padded_elements(&self.shape) / 4
    }

    /// Partition the non-C4 layout dims into native coordinate groups,
    /// outermost group first. 1D storage: one group. 2D: (v, u). 3D/array:
    /// (layer/depth, v, u). The innermost group always maps to the texture
    /// u axis so horizontally adjacent texels are memory-adjacent.
    pub fn coord_groups(&self) -> Vec<Vec<ActDim>> {
        let dims: Vec<ActDim> =
            self.layout.order.iter().copied().filter(|d| *d != ActDim::C4).collect();
        match self.storage.coord_dims() {
            1 => vec![dims],
            2 => vec![dims[..2].to_vec(), dims[2..].to_vec()],
            _ => vec![dims[..2].to_vec(), dims[2..3].to_vec(), dims[3..].to_vec()],
        }
    }

    /// Extent (in texels) of each coordinate group, outermost first.
    pub fn coord_extents(&self) -> Vec<usize> {
        self.coord_groups()
            .iter()
            .map(|g| g.iter().map(|d| ActivationLayout::extent(&self.shape, *d)).product())
            .collect()
    }

    /// Realize the descriptor into concrete GPU object dimensions.
    pub fn realize(&self) -> GpuObject {
        let ext = self.coord_extents();
        let kind = match self.storage {
            StorageType::Buffer => ObjectKind::Buffer {
                len: self.layout.padded_elements(&self.shape),
            },
            StorageType::ImageBuffer => ObjectKind::ImageBuffer { texels: self.texels() },
            StorageType::Texture2D => ObjectKind::Texture2D {
                // ext = [v, u] outermost first; width is the innermost axis.
                width: ext[1],
                height: ext[0],
            },
            StorageType::Texture2DArray => ObjectKind::Texture2DArray {
                width: ext[2],
                height: ext[1],
                layers: ext[0],
            },
            StorageType::Texture3D => ObjectKind::Texture3D {
                width: ext[2],
                height: ext[1],
                depth: ext[0],
            },
        };
        GpuObject::new(&self.name, kind, self.dtype)
    }

    /// Check the realization against device texture limits.
    pub fn validate(&self, limits: &TextureLimits) -> Result<()> {
        let obj = self.realize();
        if limits.allows(&obj.kind) {
            Ok(())
        } else {
            Err(DriftError::Device(format!(
                "descriptor {}: realization {:?} exceeds device limits",
                self.name, obj.kind
            )))
        }
    }

    /// Bytes of GPU memory the realization occupies.
    pub fn bytes(&self) -> usize {
        self.realize().bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig1_shape() -> Shape {
        Shape::bhwc(1, 2, 3, 5)
    }

    #[test]
    fn figure1_3d_texture() {
        // (1,2,3,5) as 3D texture in DSHWBC4 → (2,3,2) = (depth? no: w,h,d).
        let d = TensorDescriptor::with_default_layout(
            "t",
            fig1_shape(),
            DType::F16,
            StorageType::Texture3D,
        )
        .unwrap();
        // DSHWBC4 groups: [D,S],[H],[W,B] → depth=1·2=2, height=2, width=3·1=3.
        match d.realize().kind {
            ObjectKind::Texture3D { width, height, depth } => {
                assert_eq!((width, height, depth), (3, 2, 2));
            }
            k => panic!("wrong kind {k:?}"),
        }
        assert_eq!(d.texels(), 12);
    }

    #[test]
    fn figure1_2d_texture() {
        // (1,2,3,5) as 2D texture in HSWBDC4 → (2·⌈5/4⌉, 3) = (4,3):
        // height = H·S = 4, width = W·B·D = 3.
        let d = TensorDescriptor::with_default_layout(
            "t",
            fig1_shape(),
            DType::F16,
            StorageType::Texture2D,
        )
        .unwrap();
        match d.realize().kind {
            ObjectKind::Texture2D { width, height } => {
                assert_eq!((width, height), (3, 4));
            }
            k => panic!("wrong kind {k:?}"),
        }
    }

    #[test]
    fn figure1_image_buffer() {
        // (1,2,3,5) as a 1D image buffer → 2·3·⌈5/4⌉ = 12 pixels.
        let d = TensorDescriptor::with_default_layout(
            "t",
            fig1_shape(),
            DType::F16,
            StorageType::ImageBuffer,
        )
        .unwrap();
        match d.realize().kind {
            ObjectKind::ImageBuffer { texels } => assert_eq!(texels, 12),
            k => panic!("wrong kind {k:?}"),
        }
    }

    #[test]
    fn c4_must_be_innermost() {
        use crate::tensor::ActDim::*;
        let weird = ActivationLayout::new("C4_outer", vec![C4, B, H, W, D, S]).unwrap();
        assert!(TensorDescriptor::new(
            "t",
            fig1_shape(),
            DType::F16,
            weird,
            StorageType::Buffer
        )
        .is_err());
    }

    #[test]
    fn validate_against_limits() {
        let big = Shape::bhwc(1, 20000, 8, 4);
        let d = TensorDescriptor::with_default_layout("t", big, DType::F16, StorageType::Texture2D)
            .unwrap();
        assert!(d.validate(&TextureLimits::default()).is_err());
        let d = TensorDescriptor::with_default_layout("t", big, DType::F16, StorageType::Buffer)
            .unwrap();
        assert!(d.validate(&TextureLimits::default()).is_ok());
    }

    #[test]
    fn bytes_scale_with_dtype() {
        let d16 = TensorDescriptor::with_default_layout(
            "t",
            fig1_shape(),
            DType::F16,
            StorageType::Buffer,
        )
        .unwrap();
        let d32 = TensorDescriptor::with_default_layout(
            "t",
            fig1_shape(),
            DType::F32,
            StorageType::Buffer,
        )
        .unwrap();
        assert_eq!(d32.bytes(), 2 * d16.bytes());
    }
}
