//! Physical GPU object model.

use crate::tensor::DType;

/// The kind of physical GPU memory object a tensor may be realized as
/// (paper §3.1: "GPU buffers, image buffers, texture arrays, 2D textures,
/// and 3D textures").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageType {
    /// Raw linear buffer (OpenCL buffer / Metal buffer / WGSL storage).
    Buffer,
    /// 1D image buffer: linear memory with texture-unit access (vec4 texels).
    ImageBuffer,
    /// 2D texture (vec4 texels, 2D cache locality, free edge clamping).
    Texture2D,
    /// 2D texture array (Fig. 2: several 2D layers under one handle).
    Texture2DArray,
    /// 3D texture.
    Texture3D,
}

impl StorageType {
    /// Whether access goes through the texture path (vec4 texels, sampler
    /// cache) rather than raw pointers.
    pub fn is_texture(self) -> bool {
        !matches!(self, StorageType::Buffer)
    }

    /// Dimensionality of the native coordinate system.
    pub fn coord_dims(self) -> usize {
        match self {
            StorageType::Buffer | StorageType::ImageBuffer => 1,
            StorageType::Texture2D => 2,
            StorageType::Texture2DArray | StorageType::Texture3D => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StorageType::Buffer => "buffer",
            StorageType::ImageBuffer => "image_buffer",
            StorageType::Texture2D => "texture2d",
            StorageType::Texture2DArray => "texture2d_array",
            StorageType::Texture3D => "texture3d",
        }
    }
}

impl std::fmt::Display for StorageType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Concrete dimensions of one physical object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectKind {
    /// Linear buffer of `len` *elements* (not texels).
    Buffer { len: usize },
    /// Image buffer of `texels` vec4 texels.
    ImageBuffer { texels: usize },
    /// 2D texture, `width × height` vec4 texels.
    Texture2D { width: usize, height: usize },
    /// 2D texture array: `layers` layers of `width × height` texels.
    Texture2DArray { width: usize, height: usize, layers: usize },
    /// 3D texture of `width × height × depth` texels.
    Texture3D { width: usize, height: usize, depth: usize },
}

impl ObjectKind {
    pub fn storage_type(&self) -> StorageType {
        match self {
            ObjectKind::Buffer { .. } => StorageType::Buffer,
            ObjectKind::ImageBuffer { .. } => StorageType::ImageBuffer,
            ObjectKind::Texture2D { .. } => StorageType::Texture2D,
            ObjectKind::Texture2DArray { .. } => StorageType::Texture2DArray,
            ObjectKind::Texture3D { .. } => StorageType::Texture3D,
        }
    }

    /// Total element capacity (texels hold 4 elements).
    pub fn elements(&self) -> usize {
        match *self {
            ObjectKind::Buffer { len } => len,
            ObjectKind::ImageBuffer { texels } => texels * 4,
            ObjectKind::Texture2D { width, height } => width * height * 4,
            ObjectKind::Texture2DArray { width, height, layers } => width * height * layers * 4,
            ObjectKind::Texture3D { width, height, depth } => width * height * depth * 4,
        }
    }
}

/// A physical GPU object: kind + element dtype + a debug name.
/// In this reproduction objects model *allocations* (the simulator charges
/// bytes and access costs); host data for the PJRT path lives in literals.
#[derive(Clone, Debug)]
pub struct GpuObject {
    pub name: String,
    pub kind: ObjectKind,
    pub dtype: DType,
}

impl GpuObject {
    pub fn new(name: &str, kind: ObjectKind, dtype: DType) -> Self {
        GpuObject { name: name.to_string(), kind, dtype }
    }

    /// Allocated size in bytes (texel-padded for texture types).
    pub fn bytes(&self) -> usize {
        self.dtype.bytes_for(self.kind.elements())
    }
}

/// Device texture limits used to decide whether a realization is legal
/// (part of device specialization, §3.4).
#[derive(Clone, Copy, Debug)]
pub struct TextureLimits {
    pub max_texture_2d: usize,
    pub max_texture_3d: usize,
    pub max_array_layers: usize,
    pub max_image_buffer_texels: usize,
}

impl Default for TextureLimits {
    fn default() -> Self {
        // Conservative mobile-class limits.
        TextureLimits {
            max_texture_2d: 16384,
            max_texture_3d: 2048,
            max_array_layers: 2048,
            max_image_buffer_texels: 1 << 27,
        }
    }
}

impl TextureLimits {
    /// Whether an object of this kind fits the limits.
    pub fn allows(&self, kind: &ObjectKind) -> bool {
        match *kind {
            ObjectKind::Buffer { .. } => true,
            ObjectKind::ImageBuffer { texels } => texels <= self.max_image_buffer_texels,
            ObjectKind::Texture2D { width, height } => {
                width <= self.max_texture_2d && height <= self.max_texture_2d
            }
            ObjectKind::Texture2DArray { width, height, layers } => {
                width <= self.max_texture_2d
                    && height <= self.max_texture_2d
                    && layers <= self.max_array_layers
            }
            ObjectKind::Texture3D { width, height, depth } => {
                width <= self.max_texture_3d
                    && height <= self.max_texture_3d
                    && depth <= self.max_texture_3d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_counts_texels_as_vec4() {
        assert_eq!(ObjectKind::Buffer { len: 10 }.elements(), 10);
        assert_eq!(ObjectKind::ImageBuffer { texels: 12 }.elements(), 48);
        assert_eq!(ObjectKind::Texture2D { width: 4, height: 3 }.elements(), 48);
        assert_eq!(
            ObjectKind::Texture2DArray { width: 4, height: 2, layers: 4 }.elements(),
            128
        );
    }

    #[test]
    fn byte_sizes_respect_dtype() {
        let o = GpuObject::new("t", ObjectKind::Texture2D { width: 2, height: 2 }, DType::F16);
        assert_eq!(o.bytes(), 16 * 2);
        let o = GpuObject::new("t", ObjectKind::Buffer { len: 3 }, DType::I4);
        assert_eq!(o.bytes(), 2);
    }

    #[test]
    fn limits_gate_sizes() {
        let lim = TextureLimits { max_texture_2d: 8, ..Default::default() };
        assert!(lim.allows(&ObjectKind::Texture2D { width: 8, height: 8 }));
        assert!(!lim.allows(&ObjectKind::Texture2D { width: 9, height: 1 }));
        assert!(lim.allows(&ObjectKind::Buffer { len: usize::MAX / 2 }));
    }

    #[test]
    fn storage_type_properties() {
        assert!(!StorageType::Buffer.is_texture());
        assert!(StorageType::Texture3D.is_texture());
        assert_eq!(StorageType::Texture2D.coord_dims(), 2);
        assert_eq!(StorageType::Texture2DArray.coord_dims(), 3);
    }
}
