//! Baseline inference-engine models for the paper's comparisons.
//!
//! The paper compares ML Drift against llama.cpp, MLC LLM, ollama,
//! torchchat, MLX LM (LLM; Figs. 6–8), ONNX Runtime DirectML and CoreML
//! (diffusion; Table 3, §4.1). None of those run in this environment, so
//! each baseline is modeled as *the same roofline simulator* driving the
//! same model graphs, parameterized by that engine's documented design
//! choices (substitution table in DESIGN.md):
//!
//! * **Quantization format** — GGUF `q4_0` group quant for the
//!   llama.cpp family (model size between q8 and 8/4/4, §4.2).
//! * **Extension access** — llama.cpp's OpenCL backend does not use the
//!   mobile int8 dot-product extensions ML Drift's prefill path exploits
//!   (the 5–11× prefill gap of Fig. 6); its CUDA backend *does* reach
//!   tensor cores (Fig. 7's framing).
//! * **No stage-aware kernel split / no QKV+RoPE fusion** — the §3.6/3.7
//!   optimizations are ML Drift contributions.
//! * **Engine maturity multipliers** — residual per-engine efficiency
//!   deltas (kernel tuning, launch overheads) calibrated against one
//!   anchor bar per figure; everything else is prediction.


use crate::device::profile::DeviceProfile;
use crate::device::registry::webgpu_variant;
use crate::engine::compile::CompileOptions;
use crate::engine::llm::simulate_llm;
use crate::error::Result;
use crate::memory::Strategy;
use crate::models::llm::LlmConfig;
use crate::quant::QuantScheme;

/// A baseline engine model.
#[derive(Clone, Debug)]
pub struct Baseline {
    pub name: &'static str,
    /// Weight format the engine deploys.
    pub scheme: QuantScheme,
    /// Whether the engine applies ML-Drift-style fusion.
    pub fuse: bool,
    /// Whether it splits prefill/decode kernels (§3.7).
    pub stage_aware: bool,
    /// Engine reaches the device's int8 dot / coop-matrix extensions.
    pub int8_extensions: bool,
    /// CUDA-class backend: tensor cores + fp16 reachable (Fig. 7).
    pub cuda_class: bool,
    /// Residual compute-efficiency multiplier vs ML Drift kernels.
    pub compute_mult: f64,
    /// Residual bandwidth-efficiency multiplier.
    pub bw_mult: f64,
    /// Kernel-launch overhead multiplier.
    pub launch_mult: f64,
}

impl Baseline {
    /// ML Drift itself (identity baseline).
    pub fn mldrift() -> Baseline {
        Baseline {
            name: "ML Drift",
            scheme: QuantScheme::Mixed844,
            fuse: true,
            stage_aware: true,
            int8_extensions: true,
            cuda_class: false,
            compute_mult: 1.0,
            bw_mult: 1.0,
            launch_mult: 1.0,
        }
    }

    /// llama.cpp's OpenCL backend on mobile GPUs (Fig. 6).
    pub fn llamacpp_opencl() -> Baseline {
        Baseline {
            name: "llama.cpp (OpenCL)",
            scheme: QuantScheme::GgufQ4_0,
            fuse: false,
            stage_aware: false,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.40,
            bw_mult: 0.72,
            launch_mult: 1.6,
        }
    }

    /// MLC LLM (TVM-compiled, q4f16) on mobile (Fig. 6).
    pub fn mlc_llm() -> Baseline {
        Baseline {
            name: "MLC LLM (q4f16)",
            scheme: QuantScheme::GgufQ4_0,
            fuse: true, // TVM fuses elementwise chains
            stage_aware: false,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.45,
            bw_mult: 0.80,
            launch_mult: 1.3,
        }
    }

    /// llama.cpp's CUDA backend on the RTX 4090 (Fig. 7): tensor cores
    /// and fp16 fully reachable, and CUDA's memory path achieves a higher
    /// fraction of peak bandwidth than the OpenCL driver (the 5–25 %
    /// decode lead the paper reports).
    pub fn llamacpp_cuda() -> Baseline {
        Baseline {
            name: "llama.cpp (CUDA)",
            scheme: QuantScheme::GgufQ4_0,
            fuse: true,
            stage_aware: true,
            int8_extensions: true,
            cuda_class: true,
            compute_mult: 0.95,
            bw_mult: 1.25, // 0.62 (OpenCL-calibrated base) × 1.25 ≈ 0.78 of peak
            launch_mult: 0.8,
        }
    }

    /// ollama: llama.cpp CUDA wrapped with a serving layer (Fig. 7 shows
    /// it below both llama.cpp and ML Drift).
    pub fn ollama_cuda() -> Baseline {
        Baseline { name: "ollama (CUDA)", bw_mult: 0.80, compute_mult: 0.80, ..Self::llamacpp_cuda() }
    }

    /// torchchat CUDA (Fig. 7's slowest decode bars).
    pub fn torchchat_cuda() -> Baseline {
        Baseline { name: "torchchat (CUDA)", bw_mult: 0.58, compute_mult: 0.60, ..Self::llamacpp_cuda() }
    }

    /// llama.cpp's Metal backend on Apple Silicon (Fig. 8): mature, but
    /// ~14 % behind ML Drift prefill and consistently behind on decode.
    pub fn llamacpp_metal() -> Baseline {
        Baseline {
            name: "llama.cpp (Metal)",
            scheme: QuantScheme::GgufQ4_0,
            fuse: true,
            stage_aware: false,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.88,
            bw_mult: 0.82,
            launch_mult: 1.0,
        }
    }

    /// ollama on Metal.
    pub fn ollama_metal() -> Baseline {
        Baseline { name: "ollama (Metal)", bw_mult: 0.68, compute_mult: 0.75, ..Self::llamacpp_metal() }
    }

    /// MLX LM on Apple Silicon (Fig. 8: ~20 % behind Drift prefill on
    /// Gemma; competitive decode on Llama).
    pub fn mlx_lm() -> Baseline {
        Baseline {
            name: "MLX LM",
            scheme: QuantScheme::GgufQ4_0,
            fuse: true,
            stage_aware: true,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.83,
            bw_mult: 0.92,
            launch_mult: 0.9,
        }
    }

    /// ONNX Runtime + DirectML running Stable Diffusion (Table 3).
    pub fn onnx_directml() -> Baseline {
        Baseline {
            name: "ONNX Runtime DirectML",
            scheme: QuantScheme::F16,
            fuse: false,
            stage_aware: false,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.37,
            bw_mult: 0.55,
            launch_mult: 2.5,
        }
    }

    /// Apple CoreML Stable Diffusion (§4.1: 5.03 s on M1 Ultra vs Drift
    /// 3.86 s; 6.16 s on M4 Pro vs 5.34 s).
    pub fn coreml_sd() -> Baseline {
        Baseline {
            name: "CoreML SD",
            scheme: QuantScheme::F16,
            fuse: true,
            stage_aware: false,
            int8_extensions: false,
            cuda_class: false,
            compute_mult: 0.80,
            bw_mult: 0.85,
            launch_mult: 1.2,
        }
    }

    /// ML Drift's WebGPU backend (Table 3 / §4.2): same engine, reduced
    /// extension access + dispatch overhead, modeled via
    /// [`webgpu_variant`]. `compute_mult` etc. stay 1.0.
    pub fn mldrift_webgpu() -> Baseline {
        Baseline { name: "ML Drift WebGPU", ..Self::mldrift() }
    }

    /// Apply the baseline's device adjustments.
    pub fn adjust_device(&self, dev: &DeviceProfile) -> DeviceProfile {
        let mut d = if self.name == "ML Drift WebGPU" { webgpu_variant(dev) } else { dev.clone() };
        d.eff_compute *= self.compute_mult;
        d.eff_bandwidth *= self.bw_mult;
        d.launch_overhead_us *= self.launch_mult;
        if !self.int8_extensions {
            d.extensions.int8_dot = false;
            d.extensions.coop_matrix_int8 = false;
            d.int8_gops = 0.0;
        }
        if self.cuda_class {
            // CUDA path: fp16 + tensor-core matmuls reachable.
            d.extensions.fp16_arith = true;
            d.extensions.matrix_units_unreachable = false;
            d.extensions.int8_dot = true;
            // RTX 4090 tensor-core fp16 ≈ 330 TFLOPS dense.
            d.int8_gops = 660_000.0 * d.eff_compute.min(1.0);
            d.fp16_gflops = 330_000.0;
        }
        d
    }

    /// Compile options this engine's design corresponds to.
    pub fn compile_options(&self) -> CompileOptions {
        CompileOptions {
            fuse: self.fuse,
            attn_fusion: None, // set per model by simulate_llm
            stage_aware: self.stage_aware,
            memory_strategy: Strategy::GreedyBySize,
            emit_shaders: false,
        }
    }

    /// Run the LLM benchmark under this baseline.
    pub fn run_llm(
        &self,
        cfg: &LlmConfig,
        dev: &DeviceProfile,
        prefill: usize,
        gen: usize,
    ) -> Result<(f64, f64)> {
        let d = self.adjust_device(dev);
        let perf = simulate_llm(cfg, &d, self.scheme, prefill, gen, &self.compile_options())?;
        Ok((perf.prefill_tokens_per_s, perf.decode_tokens_per_s))
    }

    /// Run the Stable Diffusion pipeline under this baseline.
    pub fn run_sd(&self, dev: &DeviceProfile, iterations: usize) -> Result<crate::diffusion::SdReport> {
        let d = self.adjust_device(dev);
        let p = crate::diffusion::SdPipeline::compile(&d, &self.compile_options())?;
        Ok(p.run(iterations))
    }
}

/// The Fig. 6 lineup (mobile).
pub fn mobile_llm_baselines() -> Vec<Baseline> {
    vec![Baseline::mldrift(), Baseline::llamacpp_opencl(), Baseline::mlc_llm()]
}

/// The Fig. 7 lineup (RTX 4090 decode).
pub fn nvidia_llm_baselines() -> Vec<Baseline> {
    vec![
        Baseline::mldrift(),
        Baseline::llamacpp_cuda(),
        Baseline::ollama_cuda(),
        Baseline::torchchat_cuda(),
    ]
}

/// The Fig. 8 lineup (Apple M4 Pro).
pub fn apple_llm_baselines() -> Vec<Baseline> {
    vec![
        Baseline::mldrift(),
        Baseline::llamacpp_metal(),
        Baseline::ollama_metal(),
        Baseline::mlx_lm(),
    ]
}

/// Stage marker re-export for bench binaries.
pub use crate::codegen::select::Stage as LlmStage;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry::device;
    use crate::models::llm_config;

    #[test]
    fn fig6_prefill_gap_5_to_11x() {
        // ML Drift vs llama.cpp OpenCL on Adreno 830 (Fig. 6 headline).
        let cfg = llm_config("gemma2_2b").unwrap();
        let dev = device("adreno_830").unwrap();
        let (drift_p, drift_d) =
            Baseline::mldrift().run_llm(&cfg, &dev, 1024, 256).unwrap();
        let (lcpp_p, lcpp_d) =
            Baseline::llamacpp_opencl().run_llm(&cfg, &dev, 1024, 256).unwrap();
        let ratio = drift_p / lcpp_p;
        assert!(ratio > 4.0 && ratio < 13.0, "prefill speedup {ratio} (paper 5–11×)");
        assert!(drift_d > lcpp_d, "decode should also lead");
    }

    #[test]
    fn fig7_nvidia_decode_ordering() {
        // Fig. 7: llama.cpp CUDA ≥ ML Drift (within 5–25 %) > ollama > torchchat.
        let cfg = llm_config("llama3.1_8b").unwrap();
        let dev = device("rtx_4090").unwrap();
        let get = |b: Baseline| b.run_llm(&cfg, &dev, 1024, 256).unwrap().1;
        let drift = get(Baseline::mldrift());
        let lcpp = get(Baseline::llamacpp_cuda());
        let oll = get(Baseline::ollama_cuda());
        let tch = get(Baseline::torchchat_cuda());
        assert!(lcpp > drift, "CUDA llama.cpp leads decode: {lcpp} vs {drift}");
        let gap = 1.0 - drift / lcpp;
        assert!(gap > 0.02 && gap < 0.35, "gap {gap} (paper 5–25 %)");
        assert!(drift > oll, "Drift beats ollama: {drift} vs {oll}");
        assert!(oll > tch, "ollama beats torchchat");
    }

    #[test]
    fn fig8_apple_prefill_lead() {
        // Fig. 8: Drift prefill ~14 % over llama.cpp Metal, ~20 % over MLX.
        let cfg = llm_config("gemma2_2b").unwrap();
        let dev = device("m4_pro").unwrap();
        let (drift_p, drift_d) = Baseline::mldrift().run_llm(&cfg, &dev, 1024, 256).unwrap();
        let (lcpp_p, lcpp_d) = Baseline::llamacpp_metal().run_llm(&cfg, &dev, 1024, 256).unwrap();
        let (mlx_p, _) = Baseline::mlx_lm().run_llm(&cfg, &dev, 1024, 256).unwrap();
        assert!(drift_p > lcpp_p, "prefill lead over llama.cpp");
        assert!(drift_p > mlx_p, "prefill lead over MLX");
        assert!(drift_d > lcpp_d, "decode lead over llama.cpp");
        let lead = drift_p / lcpp_p;
        assert!(lead < 1.6, "lead should be modest on Apple: {lead}");
    }

    #[test]
    fn table3_sd_ordering_on_intel() {
        // Drift OpenCL < Drift WebGPU < ONNX DirectML (end-to-end seconds).
        let dev = device("intel_165u").unwrap();
        let cl = Baseline::mldrift().run_sd(&dev, 20).unwrap().end_to_end_s;
        let web = Baseline::mldrift_webgpu().run_sd(&dev, 20).unwrap().end_to_end_s;
        let dml = Baseline::onnx_directml().run_sd(&dev, 20).unwrap().end_to_end_s;
        assert!(cl < web && web < dml, "{cl} < {web} < {dml} (paper 13.5 < 27.9 < 37.0)");
        let dml_ratio = dml / cl;
        assert!(dml_ratio > 1.8 && dml_ratio < 4.5, "DirectML ratio {dml_ratio} (paper 2.7×)");
    }

    #[test]
    fn coreml_slower_than_drift_metal() {
        let dev = device("m1_ultra").unwrap();
        let drift = Baseline::mldrift().run_sd(&dev, 20).unwrap().end_to_end_s;
        let coreml = Baseline::coreml_sd().run_sd(&dev, 20).unwrap().end_to_end_s;
        assert!(drift < coreml, "{drift} < {coreml} (paper 3.86 < 5.03)");
    }
}
