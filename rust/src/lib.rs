//! # ML Drift (reproduction)
//!
//! A three-layer Rust + JAX + Pallas reproduction of *Scaling On-Device GPU
//! Inference for Large Generative Models* (Lee, Kulik, Grundmann; 2025).
//!
//! ML Drift is a GPU inference framework for large generative models. Its key
//! ideas, all implemented here:
//!
//! * **Tensor virtualization** ([`vgpu`]) — decouple logical tensor indices
//!   from physical GPU object indices so a tensor can be realized as buffers,
//!   textures, or *several* texture objects at once.
//! * **Coordinate translation** ([`translate`]) — codegen-time helpers that
//!   translate logical `(b, x, y, s)` coordinates into storage coordinates.
//! * **Device specialization** ([`device`], [`codegen`]) — per-device shader
//!   generation (OpenCL / Metal / WGSL), adaptive kernel selection, and
//!   vendor-extension exploitation.
//! * **Memory planning** ([`memory`]) — GREEDY-BY-SIZE offset calculation for
//!   intermediate tensors (93 % savings on Stable Diffusion 1.4).
//! * **Operator fusion** ([`fusion`]) — elementwise chains, residual merges,
//!   fused RMSNorm, and the QKV + RoPE layout fusion.
//! * **Stage-aware LLM inference** ([`engine`], [`kv`]) — distinct prefill /
//!   decode kernel and quantization strategies, GPU-optimized KV-cache layouts.
//!
//! Because no mobile/desktop GPU hardware is reachable in this environment,
//! execution latency is produced by a calibrated roofline simulator ([`sim`])
//! running over the *real* execution plans the compiler emits, while numerical
//! correctness is proven end-to-end on the PJRT CPU runtime ([`runtime`]) with
//! AOT-compiled JAX+Pallas artifacts. See `DESIGN.md` for the substitution map.

// CI runs `cargo clippy --release -- -D warnings` (tier-1 gate). The
// two style lints below are deliberate idiom, not defects: `graph/graph.rs`
// mirrors the paper's layer naming (`module_inception`), and the
// kernel/scatter code indexes parallel strided arrays where iterator
// rewrites would obscure the §3.8 layout math (`needless_range_loop`).
// Everything else in clippy's default set stays a hard error.
#![allow(clippy::module_inception)]
#![allow(clippy::needless_range_loop)]
// The crate is 100% safe Rust and stays that way: every cross-thread
// seam (the engine worker, reply channels, the metrics registry) is
// built on std's safe primitives, so `unsafe` would only ever appear as
// an optimization shortcut — exactly the kind of latent race surface
// the pipelined executor cannot afford. `mldrift lint` (rule
// `unsafe-pin`) pins the count of `unsafe` tokens at zero; if a future
// PR has a genuine need, downgrade this to `#![deny(unsafe_code)]`,
// document the invariant at each `#[allow]` site, and re-pin the count
// there.
#![forbid(unsafe_code)]

pub mod error;
pub mod util;
pub mod tensor;
pub mod vgpu;
pub mod translate;
pub mod graph;
pub mod fusion;
pub mod memory;
pub mod device;
pub mod codegen;
pub mod sim;
pub mod quant;
pub mod models;
pub mod kv;
pub mod engine;
pub mod diffusion;
pub mod runtime;
pub mod serving;
pub mod baselines;
pub mod bench;
pub mod check;

pub use error::{DriftError, Result};
