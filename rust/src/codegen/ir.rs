//! Kernel IR: a backend-neutral kernel specification.

use crate::codegen::select::KernelVariant;
use crate::vgpu::descriptor::TensorDescriptor;

/// One kernel argument: a named tensor bound to a storage decision.
#[derive(Clone, Debug)]
pub struct KernelArg {
    pub name: String,
    pub desc: TensorDescriptor,
    /// Written by the kernel (vs read).
    pub is_output: bool,
}

/// A backend-neutral kernel specification, ready for a [`super::Backend`]
/// emitter. The `body` is template text in the shared C-like dialect with
/// `FLT4` vectors and per-arg `<name>_Read` / `<name>_Write` helpers.
#[derive(Clone, Debug)]
pub struct KernelSpec {
    pub name: String,
    pub variant: KernelVariant,
    pub args: Vec<KernelArg>,
    pub body: String,
    /// Workgroup (threadgroup) dimensions.
    pub workgroup: [usize; 3],
    /// Global grid in workgroups.
    pub grid: [usize; 3],
    /// Compile-time integer constants folded into the source.
    pub defines: Vec<(String, i64)>,
}

impl KernelSpec {
    /// Total threads launched.
    pub fn total_threads(&self) -> usize {
        self.workgroup.iter().product::<usize>() * self.grid.iter().product::<usize>()
    }

    pub fn input_args(&self) -> impl Iterator<Item = &KernelArg> {
        self.args.iter().filter(|a| !a.is_output)
    }

    pub fn output_args(&self) -> impl Iterator<Item = &KernelArg> {
        self.args.iter().filter(|a| a.is_output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::select::KernelVariant;
    use crate::tensor::{DType, Shape};
    use crate::vgpu::object::StorageType;

    #[test]
    fn spec_thread_accounting() {
        let desc = TensorDescriptor::with_default_layout(
            "x",
            Shape::bhwc(1, 8, 8, 16),
            DType::F16,
            StorageType::Buffer,
        )
        .unwrap();
        let spec = KernelSpec {
            name: "k".into(),
            variant: KernelVariant::Elementwise,
            args: vec![
                KernelArg { name: "src".into(), desc: desc.clone(), is_output: false },
                KernelArg { name: "dst".into(), desc, is_output: true },
            ],
            body: String::new(),
            workgroup: [8, 8, 1],
            grid: [4, 2, 1],
            defines: vec![],
        };
        assert_eq!(spec.total_threads(), 8 * 8 * 4 * 2);
        assert_eq!(spec.input_args().count(), 1);
        assert_eq!(spec.output_args().count(), 1);
    }
}
