//! Manually-optimized kernel body templates.
//!
//! Bodies are written in the shared C-like dialect: `FLT4` vectors,
//! `<arg>_Read(b,x,y,d,s)` / `<arg>_Write(v,b,x,y,d,s)` helpers generated
//! by [`crate::translate`], and `DEF_*` compile-time constants. Backend
//! emitters translate this dialect into OpenCL-C / MSL / WGSL.

use crate::codegen::select::KernelVariant;
use crate::graph::{BinOp, EwOp, Node, OpKind};

/// Epilogue source for fused elementwise ops (applied to `acc`).
pub fn epilogue_src(epilogue: &[EwOp]) -> String {
    let mut s = String::new();
    for op in epilogue {
        let line = match op {
            EwOp::Relu => "  acc = max(acc, FLT4_ZERO);".to_string(),
            EwOp::Gelu => {
                "  acc = acc * 0.5f * (FLT4_ONE + tanh4(0.7978845608f * (acc + 0.044715f * acc * acc * acc)));".to_string()
            }
            EwOp::Silu => "  acc = acc / (FLT4_ONE + exp4(-acc));".to_string(),
            EwOp::Tanh => "  acc = tanh4(acc);".to_string(),
            EwOp::Sigmoid => "  acc = FLT4_ONE / (FLT4_ONE + exp4(-acc));".to_string(),
            EwOp::Exp => "  acc = exp4(acc);".to_string(),
            EwOp::Rsqrt => "  acc = rsqrt4(acc);".to_string(),
            EwOp::Neg => "  acc = -acc;".to_string(),
            EwOp::Scale(v) => format!("  acc = acc * {v:?}f;"),
            EwOp::Offset(v) => format!("  acc = acc + {v:?}f;"),
        };
        s.push_str(&line);
        s.push('\n');
    }
    s
}

/// Fused-add source for branch merges (Fig. 4 left).
pub fn fused_adds_src(fused: &[(usize, BinOp)]) -> String {
    let mut s = String::new();
    for (idx, (_, op)) in fused.iter().enumerate() {
        let sym = match op {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
        };
        s.push_str(&format!(
            "  acc = acc {sym} fused{idx}_Read(B, X, Y, D, S);\n"
        ));
    }
    s
}

/// Body template for a kernel variant instantiated for `node`.
pub fn body_for(variant: KernelVariant, node: &Node) -> String {
    let epi = epilogue_src(&node.epilogue);
    let fused = fused_adds_src(&node.fused_adds);
    match variant {
        KernelVariant::Conv2dGeneric => format!(
            r#"// Direct convolution: each thread computes one vec4 output slice
// at (B, X, Y); weights walk (ky, kx, S_in) with vec4 MADs.
int X = GID0; int Y = GID1; int S = GID2; int B = 0; int D = 0;
if (X >= DEF_OW || Y >= DEF_OH || S >= DEF_OS) return;
FLT4 acc = bias_Read(0, S, 0, 0, 0);
for (int ky = 0; ky < DEF_KH; ++ky) {{
  int iy = Y * DEF_STRIDE - DEF_PAD + ky;
  if (iy < 0 || iy >= DEF_IH) continue;  // zero clamp (free on 2D textures)
  for (int kx = 0; kx < DEF_KW; ++kx) {{
    int ix = X * DEF_STRIDE - DEF_PAD + kx;
    if (ix < 0 || ix >= DEF_IW) continue;
    for (int si = 0; si < DEF_IS; ++si) {{
      FLT4 v = src_Read(B, ix, iy, D, si);
      acc += v.x * w_Read4(S, ky, kx, si, 0);
      acc += v.y * w_Read4(S, ky, kx, si, 1);
      acc += v.z * w_Read4(S, ky, kx, si, 2);
      acc += v.w * w_Read4(S, ky, kx, si, 3);
    }}
  }}
}}
{fused}{epi}dst_Write(acc, B, X, Y, D, S);
"#
        ),
        KernelVariant::Conv2dWinograd => format!(
            r#"// Winograd F(4x4, 3x3): input tile 6x6 -> 36 MADs replaced by 16
// per-channel products after B^T d B transform; weights pre-transformed
// at conversion time (4.5x fewer multiplies, more adds).
int TX = GID0; int TY = GID1; int S = GID2; int B = 0; int D = 0;
if (TX >= DEF_TILES_X || TY >= DEF_TILES_Y || S >= DEF_OS) return;
FLT4 d_tile[36]; FLT4 m[16];
for (int i = 0; i < 36; ++i) {{
  int ix = TX * 4 - 1 + (i % 6), iy = TY * 4 - 1 + (i / 6);
  d_tile[i] = (ix < 0 || iy < 0 || ix >= DEF_IW || iy >= DEF_IH)
      ? FLT4_ZERO : src_Read(B, ix, iy, D, 0);
}}
winograd_input_transform(d_tile);
for (int si = 0; si < DEF_IS; ++si) {{
  for (int i = 0; i < 16; ++i) m[i] += d_tile[i] * wT_ReadTile(S, si, i);
}}
winograd_output_transform(m);
for (int oy = 0; oy < 4; ++oy) for (int ox = 0; ox < 4; ++ox) {{
  int X = TX * 4 + ox, Y = TY * 4 + oy;
  if (X >= DEF_OW || Y >= DEF_OH) continue;
  FLT4 acc = m[oy * 4 + ox] + bias_Read(0, S, 0, 0, 0);
{fused}{epi}  dst_Write(acc, B, X, Y, D, S);
}}
"#
        ),
        KernelVariant::FcGemmTiled => format!(
            r#"// Tiled GEMM: 32x4 threads, each accumulating a 4(M)x4(N) tile in
// registers; A tiles staged through local memory.
int X = GID0; int S = GID1; int B = 0; int Y = 0; int D = 0;
if (X >= DEF_M || S >= DEF_OS) return;
FLT4 acc = bias_Read(0, S, 0, 0, 0);
for (int si = 0; si < DEF_IS; ++si) {{
  FLT4 a = src_Read(B, X, Y, D, si);
  acc += a.x * w_Read4(S, 0, 0, si, 0);
  acc += a.y * w_Read4(S, 0, 0, si, 1);
  acc += a.z * w_Read4(S, 0, 0, si, 2);
  acc += a.w * w_Read4(S, 0, 0, si, 3);
}}
{fused}{epi}dst_Write(acc, B, X, Y, D, S);
"#
        ),
        KernelVariant::FcGemmInt8Dot => format!(
            r#"// int8 GEMM via dot-product extension: activations pre-quantized by
// quantize_act into CHAR4 + per-row scale; weights per-channel int8.
// acc_i32 += dot8(a4, w4) per 4-channel slice; dequantize on store (§3.7).
int X = GID0; int S = GID1; int B = 0; int Y = 0; int D = 0;
if (X >= DEF_M || S >= DEF_OS) return;
INT4 acc_i = INT4_ZERO;
for (int si = 0; si < DEF_IS; ++si) {{
  CHAR4 a = src_q_ReadC(B, X, Y, D, si);
  acc_i.x += DOT8(a, wq_ReadC(S, si, 0));
  acc_i.y += DOT8(a, wq_ReadC(S, si, 1));
  acc_i.z += DOT8(a, wq_ReadC(S, si, 2));
  acc_i.w += DOT8(a, wq_ReadC(S, si, 3));
}}
FLT4 acc = convert_flt4(acc_i) * src_scale_Read(0, X, 0, 0, 0) * w_scale_Read(0, S, 0, 0, 0)
         + bias_Read(0, S, 0, 0, 0);
{fused}{epi}dst_Write(acc, B, X, Y, D, S);
"#
        ),
        KernelVariant::FcGemvDequantFused => format!(
            r#"// Decode mat-vec: one workgroup per 4 output channels; weights are
// dequantized in-register (§3.7 decode path: no separate quant kernel,
// memory traffic = quantized bytes only).
int S = GID0; int B = 0; int X = 0; int Y = 0; int D = 0;
if (S >= DEF_OS) return;
FLT4 acc = FLT4_ZERO;
for (int si = LID0; si < DEF_IS; si += WG0) {{
  FLT4 a = src_Read(B, 0, 0, 0, si);
  FLT4 w0 = dequant4(wq_ReadC(S, si, 0), w_scale_Read(0, S, 0, 0, 0));
  FLT4 w1 = dequant4(wq_ReadC(S, si, 1), w_scale_Read(0, S, 0, 0, 0));
  FLT4 w2 = dequant4(wq_ReadC(S, si, 2), w_scale_Read(0, S, 0, 0, 0));
  FLT4 w3 = dequant4(wq_ReadC(S, si, 3), w_scale_Read(0, S, 0, 0, 0));
  acc.x += dot(a, w0); acc.y += dot(a, w1);
  acc.z += dot(a, w2); acc.w += dot(a, w3);
}}
acc = workgroup_reduce_add(acc) + bias_Read(0, S, 0, 0, 0);
if (LID0 != 0) return;
{fused}{epi}dst_Write(acc, B, X, Y, D, S);
"#
        ),
        KernelVariant::MatMulTiled => format!(
            r#"// Batched matmul for attention: (B,1,M,K) x (B,1,K,N).
int X = GID0; int S = GID1; int B = GID2; int Y = 0; int D = 0;
if (X >= DEF_M || S >= DEF_NS || B >= DEF_B) return;
FLT4 acc = FLT4_ZERO;
for (int si = 0; si < DEF_KS; ++si) {{
  FLT4 a = lhs_Read(B, X, Y, D, si);
  acc += a.x * rhs_Read4(B, si, S, 0);
  acc += a.y * rhs_Read4(B, si, S, 1);
  acc += a.z * rhs_Read4(B, si, S, 2);
  acc += a.w * rhs_Read4(B, si, S, 3);
}}
{fused}{epi}dst_Write(acc, B, X, Y, D, S);
"#
        ),
        KernelVariant::QuantizeAct => r#"// Dedicated activation quantization (prefill, §3.7): one workgroup
// per row computes absmax, then emits CHAR4 + scale.
int X = GID0; int B = 0; int Y = 0; int D = 0;
FLT lmax = 0.0f;
for (int si = LID0; si < DEF_IS; si += WG0) {
  FLT4 v = fabs4(src_Read(B, X, Y, D, si));
  lmax = max(lmax, max(max(v.x, v.y), max(v.z, v.w)));
}
lmax = workgroup_reduce_max(lmax);
FLT scale = lmax / 127.0f;
scale_Write1(scale, 0, X, 0, 0, 0);
for (int si = LID0; si < DEF_IS; si += WG0) {
  FLT4 v = src_Read(B, X, Y, D, si);
  dst_WriteC(quant_char4(v, scale), B, X, Y, D, si);
}
"#
        .to_string(),
        KernelVariant::Softmax => r#"// Numerically-stable softmax over the channel axis, one row per WG.
int X = GID0; int B = GID1; int Y = 0; int D = 0;
FLT m = -FLT_INF;
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 v = src_Read(B, X, Y, D, si);
  m = max(m, max(max(v.x, v.y), max(v.z, v.w)));
}
m = workgroup_reduce_max(m);
FLT sum = 0.0f;
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 e = exp4(src_Read(B, X, Y, D, si) - m);
  sum += e.x + e.y + e.z + e.w;
}
sum = workgroup_reduce_add(sum);
FLT inv = 1.0f / sum;
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 e = exp4(src_Read(B, X, Y, D, si) - m);
  dst_Write(e * inv, B, X, Y, D, si);
}
"#
        .to_string(),
        KernelVariant::RmsNorm | KernelVariant::LayerNorm => r#"// RMS / layer norm over channels, one row per workgroup.
int X = GID0; int B = GID1; int Y = 0; int D = 0;
FLT ss = 0.0f;
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 v = src_Read(B, X, Y, D, si);
  ss += dot(v, v);
}
ss = workgroup_reduce_add(ss);
FLT inv = rsqrt(ss / DEF_C + DEF_EPS);
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 v = src_Read(B, X, Y, D, si) * inv * gamma_Read(0, si, 0, 0, 0);
  dst_Write(v, B, X, Y, D, si);
}
"#
        .to_string(),
        KernelVariant::FusedAddRmsNorm => r#"// Fused residual + RMSNorm (Fig. 4 right): one pass computes
// sum = a + b, writes it as the secondary output, accumulates sum^2,
// then normalizes - saving a full read+write of the activation.
int X = GID0; int B = GID1; int Y = 0; int D = 0;
FLT ss = 0.0f;
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 s = a_Read(B, X, Y, D, si) + b_Read(B, X, Y, D, si);
  sum_Write(s, B, X, Y, D, si);   // secondary output (residual chain)
  ss += dot(s, s);
}
ss = workgroup_reduce_add(ss);
FLT inv = rsqrt(ss / DEF_C + DEF_EPS);
for (int si = LID0; si < DEF_S; si += WG0) {
  FLT4 s = sum_Read(B, X, Y, D, si);
  dst_Write(s * inv * gamma_Read(0, si, 0, 0, 0), B, X, Y, D, si);
}
"#
        .to_string(),
        KernelVariant::GroupNorm => r#"// Group norm: mean/var per (group, batch) via two-pass reduction.
int G = GID0; int B = GID1;
FLT mean = 0.0f, var = 0.0f;
for (int i = LID0; i < DEF_GROUP_ELEMS; i += WG0) mean += group_elem(G, B, i);
mean = workgroup_reduce_add(mean) / DEF_GROUP_ELEMS;
for (int i = LID0; i < DEF_GROUP_ELEMS; i += WG0) {
  FLT d = group_elem(G, B, i) - mean; var += d * d;
}
var = workgroup_reduce_add(var) / DEF_GROUP_ELEMS;
FLT inv = rsqrt(var + DEF_EPS);
for (int i = LID0; i < DEF_GROUP_ELEMS; i += WG0)
  group_store(G, B, i, (group_elem(G, B, i) - mean) * inv);
"#
        .to_string(),
        KernelVariant::QkvRopeFused => r#"// Fused QKV layout transform + RoPE (§3.6): reads the packed
// projection (B,1,S,(hq+2*hkv)*dh), applies rotary embedding to Q and K
// halves, and scatters into the attention layouts:
//   Q: (B*h_kv, S*h_q/h_kv, d_h)   K: OHWI (cache, d_h)   V: OHWI (d_h, cache)
int T = GID0; int H = GID1; int B = GID2;   // token, head
if (T >= DEF_S || H >= DEF_HQ) return;
FLT c = rope_cos(T, LID0), s = rope_sin(T, LID0);
for (int si = LID0; si < DEF_DH / 8; si += WG0) {
  FLT4 even = qkv_Read(B, T, 0, 0, q_slice(H, 2 * si));
  FLT4 odd  = qkv_Read(B, T, 0, 0, q_slice(H, 2 * si + 1));
  q_out_Write(even * c - odd * s, q_batch(B, H), q_row(T, H), 0, 0, si);
  q_out_Write(even * s + odd * c, q_batch(B, H), q_row(T, H), 0, 0, si + DEF_DH / 8);
}
if (H < DEF_HKV) {
  for (int si = LID0; si < DEF_DH / 4; si += WG0) {
    FLT4 k = rope_rotate(qkv_Read(B, T, 0, 0, k_slice(H, si)), c, s);
    k_cache_Write(k, T, H, si);        // OHWI: O=cache_pos, I=d_h
    FLT4 v = qkv_Read(B, T, 0, 0, v_slice(H, si));
    v_cache_Write(v, H, si, T);        // OHWI reversed: O=d_h, I=cache_pos
  }
}
"#
        .to_string(),
        KernelVariant::Rope => r#"// Standalone rotary embedding (unfused baseline path).
int T = GID0; int S = GID1; int B = GID2;
FLT c = rope_cos(T, S), s = rope_sin(T, S);
FLT4 even = src_Read(B, T, 0, 0, 2 * S);
FLT4 odd  = src_Read(B, T, 0, 0, 2 * S + 1);
dst_Write(even * c - odd * s, B, T, 0, 0, 2 * S);
dst_Write(even * s + odd * c, B, T, 0, 0, 2 * S + 1);
"#
        .to_string(),
        KernelVariant::Elementwise => {
            let inner = match &node.kind {
                OpKind::Binary(op) => {
                    let sym = match op {
                        BinOp::Add => "+",
                        BinOp::Sub => "-",
                        BinOp::Mul => "*",
                        BinOp::Div => "/",
                    };
                    format!("FLT4 acc = a_Read(B, X, Y, D, S) {sym} b_Read(B, X, Y, D, S);")
                }
                _ => "FLT4 acc = src_Read(B, X, Y, D, S);".to_string(),
            };
            format!(
                r#"// Elementwise kernel (standalone: only when fusion could not absorb).
int X = GID0; int S = GID1; int B = GID2; int Y = 0; int D = 0;
if (X >= DEF_W || S >= DEF_NS) return;
{inner}
{epi}dst_Write(acc, B, X, Y, D, S);
"#
            )
        }
        KernelVariant::Embedding => r#"// Token embedding gather: one row per token id.
int T = GID0; int S = GID1; int B = GID2;
int id = token_ReadI(B, T, 0, 0, 0);
dst_Write(table_Read4(id, S), B, T, 0, 0, S);
"#
        .to_string(),
        KernelVariant::Memory => r#"// Data-movement kernel (reshape/transpose/concat/upsample/pool):
// pure coordinate remap through the translation helpers.
int X = GID0; int Y = GID1; int S = GID2; int B = 0; int D = 0;
if (X >= DEF_OW || Y >= DEF_OH || S >= DEF_OS) return;
dst_Write(src_Read(remap_b(B), remap_x(X), remap_y(Y), remap_d(D), remap_s(S)), B, X, Y, D, S);
"#
        .to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tensor::{DType, Shape};

    #[test]
    fn bodies_reference_helpers() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let fc = g.fully_connected("fc", x, 64, DType::I8).unwrap();
        let body = body_for(KernelVariant::FcGemvDequantFused, &g.nodes[fc]);
        assert!(body.contains("src_Read"));
        assert!(body.contains("dst_Write"));
        assert!(body.contains("dequant4"));
    }

    #[test]
    fn epilogue_rendering() {
        let src = epilogue_src(&[EwOp::Silu, EwOp::Scale(2.0)]);
        assert!(src.contains("exp4(-acc)"));
        assert!(src.contains("* 2.0f"));
    }

    #[test]
    fn fused_adds_render_reads() {
        let src = fused_adds_src(&[(3, BinOp::Mul)]);
        assert!(src.contains("acc * fused0_Read"));
    }

    #[test]
    fn binary_elementwise_body() {
        let mut g = Graph::new("t");
        let a = g.input("a", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let b = g.input("b", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let add = g.binary("add", a, b, BinOp::Add).unwrap();
        let body = body_for(KernelVariant::Elementwise, &g.nodes[add]);
        assert!(body.contains("a_Read(B, X, Y, D, S) + b_Read(B, X, Y, D, S)"));
    }
}
