//! Adaptive kernel selection (§3.4, §3.7).
//!
//! Given an operator node, a device profile, and the LLM inference stage,
//! pick the kernel variant, storage types, weight layout, and workgroup
//! shape. These decisions are "empirically determined offline" in the
//! paper; here they are encoded as the rules the paper describes.

use crate::device::profile::{DeviceProfile, Vendor};
use crate::graph::{Node, OpKind};
use crate::tensor::layout::WeightLayout;
use crate::vgpu::object::StorageType;

/// LLM inference stage (the paper's §3.7 distinction). Diffusion and
/// generic CNN workloads use `Single`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Compute-bound prompt processing (long sequences).
    Prefill,
    /// Memory-bound autoregressive token generation.
    Decode,
    /// Non-staged workloads (diffusion, CNNs).
    Single,
}

/// Kernel implementation variants the generator can instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelVariant {
    /// Direct convolution, vec4 slices.
    Conv2dGeneric,
    /// Winograd F(4×4, 3×3) fast convolution (large-C 3×3 stride-1).
    Conv2dWinograd,
    /// Tiled GEMM for long-sequence FC / conv-as-matmul (prefill).
    FcGemmTiled,
    /// GEMM using int8 dot-product / cooperative-matrix extensions over
    /// pre-quantized activations (prefill fast path, §3.7).
    FcGemmInt8Dot,
    /// Mat-vec with weights dequantized inside the kernel (decode path,
    /// §3.7: quantization integrated in the operational kernel).
    FcGemvDequantFused,
    /// Generic batched matmul (attention scores / context).
    MatMulTiled,
    /// Dedicated activation-quantization kernel (prefill, §3.7).
    QuantizeAct,
    Softmax,
    RmsNorm,
    FusedAddRmsNorm,
    GroupNorm,
    LayerNorm,
    /// Fused QKV layout transform + rotary embedding (§3.6).
    QkvRopeFused,
    Rope,
    Elementwise,
    Embedding,
    /// Data movement (reshape / transpose / concat / upsample / pool).
    Memory,
}

impl KernelVariant {
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::Conv2dGeneric => "conv2d_generic",
            KernelVariant::Conv2dWinograd => "conv2d_winograd4x4",
            KernelVariant::FcGemmTiled => "fc_gemm_tiled",
            KernelVariant::FcGemmInt8Dot => "fc_gemm_int8dot",
            KernelVariant::FcGemvDequantFused => "fc_gemv_dequant",
            KernelVariant::MatMulTiled => "matmul_tiled",
            KernelVariant::QuantizeAct => "quantize_act",
            KernelVariant::Softmax => "softmax",
            KernelVariant::RmsNorm => "rms_norm",
            KernelVariant::FusedAddRmsNorm => "fused_add_rms_norm",
            KernelVariant::GroupNorm => "group_norm",
            KernelVariant::LayerNorm => "layer_norm",
            KernelVariant::QkvRopeFused => "qkv_rope_fused",
            KernelVariant::Rope => "rope",
            KernelVariant::Elementwise => "elementwise",
            KernelVariant::Embedding => "embedding",
            KernelVariant::Memory => "memory_op",
        }
    }
}

/// A complete specialization decision for one node.
#[derive(Clone, Debug)]
pub struct KernelChoice {
    pub variant: KernelVariant,
    /// Storage for input/output activations.
    pub act_storage: StorageType,
    /// Storage for weights (if the op has them).
    pub weight_storage: StorageType,
    /// Weight layout (if the op has weights).
    pub weight_layout: Option<WeightLayout>,
    /// Workgroup size.
    pub workgroup: [usize; 3],
    /// Whether a dedicated activation-quantization kernel must precede
    /// this one (prefill int8 path).
    pub needs_act_quant: bool,
}

/// Vendor-tuned workgroup defaults (offline-tuned in the paper).
fn default_workgroup(vendor: Vendor, variant: KernelVariant) -> [usize; 3] {
    use KernelVariant::*;
    match (vendor, variant) {
        (Vendor::Qualcomm, Conv2dGeneric | Conv2dWinograd) => [8, 4, 2],
        (Vendor::Qualcomm, FcGemmTiled | FcGemmInt8Dot | MatMulTiled) => [32, 4, 1],
        (Vendor::Arm, Conv2dGeneric | Conv2dWinograd) => [4, 4, 2],
        (Vendor::Arm, FcGemmTiled | FcGemmInt8Dot | MatMulTiled) => [16, 4, 1],
        (Vendor::Apple, _) => [32, 1, 1],
        (Vendor::Intel, FcGemmTiled | FcGemmInt8Dot | MatMulTiled) => [16, 8, 1],
        (Vendor::Nvidia, _) => [32, 4, 1],
        (_, FcGemvDequantFused) => [64, 1, 1],
        _ => [8, 8, 1],
    }
}

/// Pick storage for activations, falling back to buffers when the
/// realization would exceed the device's texture limits.
fn pick_act_storage(node: &Node, dev: &DeviceProfile) -> StorageType {
    let pref = dev.preferred_activation_storage();
    if pref == StorageType::Buffer {
        return pref;
    }
    let desc = crate::vgpu::descriptor::TensorDescriptor::with_default_layout(
        &node.name,
        node.shape,
        node.dtype,
        pref,
    );
    match desc {
        Ok(d) if d.validate(&dev.texture_limits).is_ok() => pref,
        _ => StorageType::Buffer,
    }
}

/// The selection rules.
pub fn select_kernel(node: &Node, dev: &DeviceProfile, stage: Stage) -> KernelChoice {
    let quantized_weights = node.weight.map(|w| w.dtype.is_quantized()).unwrap_or(false);
    let has_int8_path = dev.extensions.int8_dot || dev.extensions.coop_matrix_int8;

    let (variant, needs_act_quant) = match &node.kind {
        OpKind::Conv2D { kh, kw, stride, .. } => {
            let in_c = node.weight.map(|w| w.shape.i).unwrap_or(0);
            // Winograd F(4,3): 3×3 stride-1 convs with enough channels to
            // amortize the transforms; not profitable under WebGPU (no
            // subgroup shuffles in the paper's implementation).
            if *kh == 3
                && *kw == 3
                && *stride == 1
                && in_c >= 16
                && node.kind.is_compute()
                && dev.api != crate::device::profile::Api::WebGpu
            {
                (KernelVariant::Conv2dWinograd, false)
            } else {
                (KernelVariant::Conv2dGeneric, false)
            }
        }
        OpKind::FullyConnected { .. } => match stage {
            // §3.7: prefill = compute-bound, convert activations to int8
            // once (dedicated kernel) and hit the int8 dot/coop-matrix
            // path; decode = memory-bound, dequantize inside the matvec.
            Stage::Prefill if quantized_weights && has_int8_path => {
                (KernelVariant::FcGemmInt8Dot, true)
            }
            Stage::Prefill => (KernelVariant::FcGemmTiled, false),
            Stage::Decode => (KernelVariant::FcGemvDequantFused, false),
            Stage::Single => (KernelVariant::FcGemmTiled, false),
        },
        OpKind::MatMul { .. } => (KernelVariant::MatMulTiled, false),
        OpKind::QuantAct => (KernelVariant::QuantizeAct, false),
        OpKind::Softmax => (KernelVariant::Softmax, false),
        OpKind::RmsNorm { .. } => (KernelVariant::RmsNorm, false),
        OpKind::FusedAddRmsNorm { .. } => (KernelVariant::FusedAddRmsNorm, false),
        OpKind::GroupNorm { .. } => (KernelVariant::GroupNorm, false),
        OpKind::LayerNorm { .. } => (KernelVariant::LayerNorm, false),
        OpKind::FusedQkvRope { .. } => (KernelVariant::QkvRopeFused, false),
        OpKind::Rope { .. } => (KernelVariant::Rope, false),
        OpKind::Elementwise(_) | OpKind::Binary(_) => (KernelVariant::Elementwise, false),
        OpKind::Embedding { .. } => (KernelVariant::Embedding, false),
        _ => (KernelVariant::Memory, false),
    };

    // Weight layout: kernels that walk input slices innermost want I4
    // innermost; the decode matvec wants O4 innermost (one vec4 of output
    // channels per thread). Group size 4 batches output slices per
    // workgroup on tiled GEMMs (the ≤20 % matmul speedup of §3.1).
    let weight_layout = node.weight.map(|_| match variant {
        KernelVariant::FcGemvDequantFused => WeightLayout::gso_hwdsi_i4o4(1),
        KernelVariant::FcGemmInt8Dot | KernelVariant::FcGemmTiled => {
            WeightLayout::gso_hwdsi_o4i4(4)
        }
        KernelVariant::Conv2dWinograd => WeightLayout::gso_hwdsi_o4i4(2),
        _ => WeightLayout::gso_hwdsi_i4o4(2),
    });

    KernelChoice {
        variant,
        act_storage: pick_act_storage(node, dev),
        weight_storage: dev.preferred_weight_storage(),
        weight_layout,
        workgroup: default_workgroup(dev.vendor, variant),
        needs_act_quant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry::device;
    use crate::graph::Graph;
    use crate::tensor::{DType, Shape};

    fn fc_node(wdtype: DType) -> Node {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 128, 2048), DType::F16);
        let id = g.fully_connected("fc", x, 2048, wdtype).unwrap();
        g.nodes[id].clone()
    }

    #[test]
    fn stage_aware_fc_selection() {
        let dev = device("adreno_750").unwrap();
        let n = fc_node(DType::I8);
        let pre = select_kernel(&n, &dev, Stage::Prefill);
        assert_eq!(pre.variant, KernelVariant::FcGemmInt8Dot);
        assert!(pre.needs_act_quant, "prefill inserts a dedicated quant kernel");
        let dec = select_kernel(&n, &dev, Stage::Decode);
        assert_eq!(dec.variant, KernelVariant::FcGemvDequantFused);
        assert!(!dec.needs_act_quant, "decode fuses quantization into the kernel");
    }

    #[test]
    fn prefill_without_int8_ext_uses_float_gemm() {
        let dev = device("rtx_4090").unwrap(); // no int8 path via OpenCL
        let n = fc_node(DType::I8);
        let pre = select_kernel(&n, &dev, Stage::Prefill);
        assert_eq!(pre.variant, KernelVariant::FcGemmTiled);
        assert!(!pre.needs_act_quant);
    }

    #[test]
    fn winograd_for_3x3_stride1_large_c() {
        let dev = device("adreno_750").unwrap();
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 64, 64, 320), DType::F16);
        let c = g.conv2d("c", x, 320, 3, 1, 1, DType::F16).unwrap();
        let choice = select_kernel(&g.nodes[c], &dev, Stage::Single);
        assert_eq!(choice.variant, KernelVariant::Conv2dWinograd);
        // 1×1 conv stays generic.
        let c1 = g.conv2d("c1", x, 320, 1, 1, 0, DType::F16).unwrap();
        let choice = select_kernel(&g.nodes[c1], &dev, Stage::Single);
        assert_eq!(choice.variant, KernelVariant::Conv2dGeneric);
    }

    #[test]
    fn storage_prefers_vendor_then_falls_back() {
        let adreno = device("adreno_750").unwrap();
        let mali = device("mali_g715").unwrap();
        let n = fc_node(DType::I8);
        assert_eq!(select_kernel(&n, &adreno, Stage::Single).act_storage, StorageType::Texture2D);
        assert_eq!(select_kernel(&n, &mali, Stage::Single).act_storage, StorageType::Buffer);
        // Oversized tensor falls back to buffer even on Adreno.
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 60000, 64), DType::F16);
        let big = g.softmax("s", x).unwrap();
        assert_eq!(
            select_kernel(&g.nodes[big], &adreno, Stage::Single).act_storage,
            StorageType::Buffer
        );
    }

    #[test]
    fn decode_gemv_wants_o4_innermost() {
        let dev = device("adreno_750").unwrap();
        let n = fc_node(DType::I4);
        let dec = select_kernel(&n, &dev, Stage::Decode);
        let wl = dec.weight_layout.unwrap();
        assert!(wl.name.contains("I4O4"), "decode layout should end in O4: {}", wl.name);
        let pre = select_kernel(&n, &dev, Stage::Prefill);
        let wl = pre.weight_layout.unwrap();
        assert!(wl.name.contains("O4I4"), "prefill dot8 layout should end in I4: {}", wl.name);
    }
}
