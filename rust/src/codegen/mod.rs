//! Shader code generation and device specialization (§3.4).
//!
//! ML Drift performs *dynamic code generation at runtime from manually
//! optimized shader templates*. The pipeline per operator is:
//!
//! 1. **Adaptive kernel selection** ([`select`]) — pick the fastest kernel
//!    variant for the op, device, and LLM stage (Winograd convolutions,
//!    int8-dot GEMMs, decode matvecs with inline dequantization …).
//! 2. **Storage decisions** — preferred GPU object types per vendor,
//!    validated against texture limits (falling back to buffers).
//! 3. **Helper generation** — coordinate-translation `Read`/`Write`
//!    helpers from [`crate::translate`] baked into the source.
//! 4. **Syntax translation** ([`backend`]) — the backend emitter converts
//!    the template into OpenCL-C, Metal Shading Language, or WGSL.
//! 5. **Weights conversion** — weight layouts chosen per §3.1
//!    (`(G, S_O, O4, HWD, S_I, I4)` permutations) for the selected kernel.

pub mod ir;
pub mod kernels;
pub mod backend;
pub mod select;

pub use backend::{emit, Backend};
pub use ir::{KernelArg, KernelSpec};
pub use select::{select_kernel, KernelChoice, KernelVariant, Stage};
