//! Backend emitters: OpenCL-C, Metal Shading Language, WGSL.
//!
//! Each backend performs the paper's "syntax translation": the shared
//! template dialect (FLT4 vectors, `LOAD_TEXEL`/`STORE_TEXEL` intrinsics,
//! `GID*`/`LID*` thread ids) becomes compilable source in the target
//! shading language, with the coordinate-translation helpers from
//! [`crate::translate`] inlined per argument.

use crate::codegen::ir::KernelSpec;
use crate::translate::codegen::read_write_helpers;
use crate::vgpu::object::StorageType;

/// Target shading language.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    OpenCl,
    Metal,
    Wgsl,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::OpenCl => "opencl",
            Backend::Metal => "metal",
            Backend::Wgsl => "wgsl",
        }
    }

    fn file_ext(self) -> &'static str {
        match self {
            Backend::OpenCl => "cl",
            Backend::Metal => "metal",
            Backend::Wgsl => "wgsl",
        }
    }
}

fn common_prelude(backend: Backend) -> &'static str {
    match backend {
        Backend::OpenCl => r#"// ---- mldrift OpenCL prelude ----
#pragma OPENCL EXTENSION cl_khr_fp16 : enable
#define FLT half
#define FLT4 half4
#define FLT4_ZERO ((half4)(0.0h))
#define FLT4_ONE ((half4)(1.0h))
#define FLT_INF INFINITY
#define GID0 get_global_id(0)
#define GID1 get_global_id(1)
#define GID2 get_global_id(2)
#define LID0 get_local_id(0)
#define WG0 get_local_size(0)
#define exp4(v) exp(v)
#define tanh4(v) tanh(v)
#define rsqrt4(v) rsqrt(v)
#define fabs4(v) fabs(v)
#define DOT8(a, b) dot8_ext(a, b) // cl_*_dot_product8 vendor extension
"#,
        Backend::Metal => r#"// ---- mldrift Metal prelude ----
#include <metal_stdlib>
using namespace metal;
#define FLT half
#define FLT4 half4
#define FLT4_ZERO half4(0.0h)
#define FLT4_ONE half4(1.0h)
#define FLT_INF INFINITY
#define GID0 gid.x
#define GID1 gid.y
#define GID2 gid.z
#define LID0 lid.x
#define WG0 wg_size.x
#define exp4(v) exp(v)
#define tanh4(v) tanh(v)
#define rsqrt4(v) rsqrt(v)
#define fabs4(v) abs(v)
#define DOT8(a, b) simd_dot8(a, b)
"#,
        Backend::Wgsl => r#"// ---- mldrift WGSL prelude ----
// WGSL has no preprocessor: the generator textually substitutes the
// dialect tokens below before emitting (shown as aliases for readability).
alias FLT = f32;            // f16 requires the shader-f16 feature
alias FLT4 = vec4<f32>;
const FLT4_ZERO = vec4<f32>(0.0);
const FLT4_ONE = vec4<f32>(1.0);
const FLT_INF = 3.4e38;
// GID* <- global_invocation_id, LID* <- local_invocation_id
"#,
    }
}

/// Per-argument storage access macros.
fn access_macros(backend: Backend, arg: &str, storage: StorageType) -> String {
    match backend {
        Backend::OpenCl => match storage {
            StorageType::Buffer => format!(
                "#define LOAD_TEXEL({arg}, idx) vload4(idx, {arg}_buf)\n\
                 #define STORE_TEXEL({arg}, idx, v) vstore4(v, idx, {arg}_buf)\n"
            ),
            StorageType::ImageBuffer => format!(
                "#define LOAD_TEXEL({arg}, idx) read_imageh({arg}_img, (idx))\n\
                 #define STORE_TEXEL({arg}, idx, v) write_imageh({arg}_img, (idx), v)\n"
            ),
            StorageType::Texture2D => format!(
                "#define LOAD_TEXEL({arg}, u, v) read_imageh({arg}_tex, smp_none, (int2)(u, v))\n\
                 #define STORE_TEXEL({arg}, u, v, val) write_imageh({arg}_tex, (int2)(u, v), val)\n"
            ),
            StorageType::Texture2DArray | StorageType::Texture3D => format!(
                "#define LOAD_TEXEL({arg}, u, v, w) read_imageh({arg}_tex, smp_none, (int4)(u, v, w, 0))\n\
                 #define STORE_TEXEL({arg}, u, v, w, val) write_imageh({arg}_tex, (int4)(u, v, w, 0), val)\n"
            ),
        },
        Backend::Metal => match storage {
            StorageType::Buffer => format!(
                "#define LOAD_TEXEL({arg}, idx) {arg}_buf[idx]\n\
                 #define STORE_TEXEL({arg}, idx, v) {arg}_buf[idx] = (v)\n"
            ),
            StorageType::ImageBuffer => format!(
                "#define LOAD_TEXEL({arg}, idx) {arg}_tb.read(uint(idx))\n\
                 #define STORE_TEXEL({arg}, idx, v) {arg}_tb.write(v, uint(idx))\n"
            ),
            StorageType::Texture2D => format!(
                "#define LOAD_TEXEL({arg}, u, v) {arg}_tex.read(uint2(u, v))\n\
                 #define STORE_TEXEL({arg}, u, v, val) {arg}_tex.write(val, uint2(u, v))\n"
            ),
            StorageType::Texture2DArray => format!(
                "#define LOAD_TEXEL({arg}, u, v, w) {arg}_tex.read(uint2(u, v), uint(w))\n\
                 #define STORE_TEXEL({arg}, u, v, w, val) {arg}_tex.write(val, uint2(u, v), uint(w))\n"
            ),
            StorageType::Texture3D => format!(
                "#define LOAD_TEXEL({arg}, u, v, w) {arg}_tex.read(uint3(u, v, w))\n\
                 #define STORE_TEXEL({arg}, u, v, w, val) {arg}_tex.write(val, uint3(u, v, w))\n"
            ),
        },
        Backend::Wgsl => match storage {
            StorageType::Buffer | StorageType::ImageBuffer => format!(
                "// LOAD_TEXEL({arg}, idx) -> {arg}_buf.data[idx]\n\
                 // STORE_TEXEL({arg}, idx, v) -> {arg}_buf.data[idx] = v\n"
            ),
            StorageType::Texture2D => format!(
                "// LOAD_TEXEL({arg}, u, v) -> textureLoad({arg}_tex, vec2<i32>(u, v), 0)\n\
                 // STORE_TEXEL({arg}, u, v, val) -> textureStore({arg}_tex, vec2<i32>(u, v), val)\n"
            ),
            _ => format!(
                "// LOAD_TEXEL({arg}, u, v, w) -> textureLoad({arg}_tex, vec3<i32>(u, v, w), 0)\n\
                 // STORE_TEXEL({arg}, u, v, w, val) -> textureStore({arg}_tex, vec3<i32>(u, v, w), val)\n"
            ),
        },
    }
}

fn arg_decl(backend: Backend, arg: &str, storage: StorageType, is_output: bool) -> String {
    match backend {
        Backend::OpenCl => match storage {
            StorageType::Buffer => format!("__global half* {arg}_buf"),
            StorageType::ImageBuffer => format!("__read_write image1d_buffer_t {arg}_img"),
            StorageType::Texture2D => {
                if is_output {
                    format!("__write_only image2d_t {arg}_tex")
                } else {
                    format!("__read_only image2d_t {arg}_tex")
                }
            }
            StorageType::Texture2DArray => format!("__read_only image2d_array_t {arg}_tex"),
            StorageType::Texture3D => format!("__read_only image3d_t {arg}_tex"),
        },
        Backend::Metal => match storage {
            StorageType::Buffer => format!("device half4* {arg}_buf"),
            StorageType::ImageBuffer => format!("texture_buffer<half, access::read_write> {arg}_tb"),
            StorageType::Texture2D => {
                let acc = if is_output { "write" } else { "read" };
                format!("texture2d<half, access::{acc}> {arg}_tex")
            }
            StorageType::Texture2DArray => format!("texture2d_array<half, access::read> {arg}_tex"),
            StorageType::Texture3D => format!("texture3d<half, access::read> {arg}_tex"),
        },
        Backend::Wgsl => match storage {
            StorageType::Buffer | StorageType::ImageBuffer => {
                let mode = if is_output { "read_write" } else { "read" };
                format!("var<storage, {mode}> {arg}_buf: TensorBuf")
            }
            StorageType::Texture2D => {
                if is_output {
                    format!("var {arg}_tex: texture_storage_2d<rgba16float, write>")
                } else {
                    format!("var {arg}_tex: texture_2d<f32>")
                }
            }
            StorageType::Texture2DArray => format!("var {arg}_tex: texture_2d_array<f32>"),
            StorageType::Texture3D => format!("var {arg}_tex: texture_3d<f32>"),
        },
    }
}

/// Emit full kernel source for one backend.
pub fn emit(backend: Backend, spec: &KernelSpec) -> String {
    let mut src = String::new();
    src.push_str(&format!(
        "// kernel: {} (variant {}) [{}.{}]\n",
        spec.name,
        spec.variant.name(),
        spec.name,
        backend.file_ext()
    ));
    src.push_str(common_prelude(backend));
    src.push('\n');
    // Compile-time constants.
    for (k, v) in &spec.defines {
        match backend {
            Backend::Wgsl => src.push_str(&format!("const {k}: i32 = {v};\n")),
            _ => src.push_str(&format!("#define {k} {v}\n")),
        }
    }
    src.push('\n');
    // Access macros + coordinate-translation helpers per argument.
    for arg in &spec.args {
        src.push_str(&access_macros(backend, &arg.name, arg.desc.storage));
        let helpers = read_write_helpers(&arg.name, &arg.desc);
        if backend == Backend::Wgsl {
            // WGSL: helpers as fn with explicit i32 params.
            src.push_str(&wgslify(&helpers.source));
        } else {
            src.push_str(&helpers.source);
        }
        src.push('\n');
    }
    // Entry point.
    let params: Vec<String> = spec
        .args
        .iter()
        .map(|a| arg_decl(backend, &a.name, a.desc.storage, a.is_output))
        .collect();
    match backend {
        Backend::OpenCl => {
            src.push_str(&format!(
                "__kernel __attribute__((reqd_work_group_size({}, {}, {})))\nvoid {}({}) {{\n",
                spec.workgroup[0],
                spec.workgroup[1],
                spec.workgroup[2],
                spec.name,
                params.join(", ")
            ));
        }
        Backend::Metal => {
            src.push_str(&format!(
                "kernel void {}({},\n    uint3 gid [[thread_position_in_grid]],\n    uint3 lid [[thread_position_in_threadgroup]],\n    uint3 wg_size [[threads_per_threadgroup]]) {{\n",
                spec.name,
                params
                    .iter()
                    .enumerate()
                    .map(|(i, p)| format!("{p} [[id({i})]]"))
                    .collect::<Vec<_>>()
                    .join(",\n    ")
            ));
        }
        Backend::Wgsl => {
            for (i, p) in params.iter().enumerate() {
                src.push_str(&format!("@group(0) @binding({i}) {p};\n"));
            }
            src.push_str(&format!(
                "@compute @workgroup_size({}, {}, {})\nfn {}(@builtin(global_invocation_id) gid: vec3<u32>,\n    @builtin(local_invocation_id) lid: vec3<u32>) {{\n",
                spec.workgroup[0], spec.workgroup[1], spec.workgroup[2], spec.name
            ));
        }
    }
    src.push_str(&spec.body);
    src.push_str("}\n");
    src
}

/// Light token rewrite of the C-dialect helpers for WGSL.
fn wgslify(c_src: &str) -> String {
    c_src
        .replace("FLT4 ", "fn_ret_FLT4 ") // annotate, kept readable
        .replace("int b, int x, int y, int d, int s", "b: i32, x: i32, y: i32, d: i32, s: i32")
        .replace("  int ", "  let ")
        .replace("void ", "fn ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::ir::KernelArg;
    use crate::codegen::kernels::body_for;
    use crate::codegen::select::KernelVariant;
    use crate::graph::Graph;
    use crate::tensor::{DType, Shape};
    use crate::vgpu::descriptor::TensorDescriptor;

    fn sample_spec() -> KernelSpec {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 128, 2048), DType::F16);
        let fc = g.fully_connected("fc", x, 2048, DType::I8).unwrap();
        let node = g.nodes[fc].clone();
        let src_desc = TensorDescriptor::with_default_layout(
            "src",
            g.nodes[x].shape,
            DType::F16,
            StorageType::Texture2D,
        )
        .unwrap();
        let dst_desc = TensorDescriptor::with_default_layout(
            "dst",
            node.shape,
            DType::F16,
            StorageType::Buffer,
        )
        .unwrap();
        KernelSpec {
            name: "fc_decode".into(),
            variant: KernelVariant::FcGemvDequantFused,
            args: vec![
                KernelArg { name: "src".into(), desc: src_desc, is_output: false },
                KernelArg { name: "dst".into(), desc: dst_desc, is_output: true },
            ],
            body: body_for(KernelVariant::FcGemvDequantFused, &node),
            workgroup: [64, 1, 1],
            grid: [8, 1, 1],
            defines: vec![("DEF_OS".into(), 512), ("DEF_IS".into(), 512)],
        }
    }

    #[test]
    fn opencl_emission_has_kernel_and_helpers() {
        let src = emit(Backend::OpenCl, &sample_spec());
        assert!(src.contains("__kernel"));
        assert!(src.contains("reqd_work_group_size(64, 1, 1)"));
        assert!(src.contains("read_imageh"));
        assert!(src.contains("src_Read"));
        assert!(src.contains("#define DEF_OS 512"));
        assert!(src.contains("__global half* dst_buf"));
    }

    #[test]
    fn metal_emission_uses_msl() {
        let src = emit(Backend::Metal, &sample_spec());
        assert!(src.contains("#include <metal_stdlib>"));
        assert!(src.contains("kernel void fc_decode"));
        assert!(src.contains("thread_position_in_grid"));
        assert!(src.contains("texture2d<half"));
    }

    #[test]
    fn wgsl_emission_uses_bindings() {
        let src = emit(Backend::Wgsl, &sample_spec());
        assert!(src.contains("@compute @workgroup_size(64, 1, 1)"));
        assert!(src.contains("@group(0) @binding(0)"));
        assert!(src.contains("const DEF_OS: i32 = 512;"));
    }

    #[test]
    fn all_backends_embed_translation() {
        // The 2D-texture src must translate through (x·batch+b, y·slice+s)
        // — for this shape batch=1 folds, leaving the slice term.
        for b in [Backend::OpenCl, Backend::Metal, Backend::Wgsl] {
            let src = emit(b, &sample_spec());
            assert!(src.contains("_Read"), "{b:?} missing read helper");
        }
    }
}
