//! Device specialization: GPU profiles and the device registry (§3.4).
//!
//! ML Drift determines the optimal GPU object types and kernel variants
//! per device offline, then selects them at initialization from the
//! detected hardware. This module is the "detected hardware" side: a
//! profile database covering every GPU in the paper's evaluation —
//! Qualcomm Adreno 830/750/740, Arm Immortalis-G720 / Mali-G715, Intel
//! Ultra 7 165U / 258V, NVIDIA RTX 4090, and Apple M1 Ultra / M4 Pro.
//!
//! Since no GPU hardware is reachable in this reproduction, profiles
//! additionally carry the *calibrated efficiency factors* the roofline
//! simulator uses (see `DESIGN.md` §6: peak specs from public data, one
//! efficiency fit per device family against a single paper row; all other
//! rows are predictions).

pub mod profile;
pub mod registry;

pub use profile::{Api, DeviceClass, DeviceProfile, Extensions, Vendor};
pub use registry::{all_devices, device, device_names};
