//! The device database: every GPU in the paper's evaluation.
//!
//! Peak numbers come from public spec sheets. `eff_*` factors are
//! calibrated once per device against a single anchor row of the paper's
//! Table 2/4 (Gemma2 2B 8/4/4 — see EXPERIMENTS.md §Calibration); all
//! other (model × quant × stage) points are predictions of the cost model.

use crate::device::profile::{Api, DeviceClass, DeviceProfile, Extensions, Vendor};
use crate::vgpu::object::TextureLimits;

const GIB: u64 = 1 << 30;

/// Phone GPUs can address roughly 62 % of system RAM (OS + apps hold the
/// rest) — this reproduces the paper's Llama-3.1-8B-q8 OOM entries on the
/// 8 GB and 12 GB devices while the 16 GB Adreno 830 phone runs it.
fn phone_budget(ram_gib: u64) -> u64 {
    ram_gib * GIB * 62 / 100
}

fn mobile_limits() -> TextureLimits {
    TextureLimits {
        max_texture_2d: 16384,
        max_texture_3d: 2048,
        max_array_layers: 2048,
        max_image_buffer_texels: 1 << 27,
    }
}

fn desktop_limits() -> TextureLimits {
    TextureLimits {
        max_texture_2d: 32768,
        max_texture_3d: 16384,
        max_array_layers: 2048,
        max_image_buffer_texels: 1 << 28,
    }
}

/// All registered device profiles.
pub fn all_devices() -> Vec<DeviceProfile> {
    vec![
        // ------------------------------------------------- Qualcomm Adreno
        DeviceProfile {
            name: "adreno_830",
            marketing_name: "Qualcomm Adreno 830 (Xiaomi 15 Pro, 16 GB)",
            vendor: Vendor::Qualcomm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 4600.0,
            fp32_gflops: 2300.0,
            int8_gops: 13450.0,
            mem_bw_gbps: 85.4,
            launch_overhead_us: 14.0,
            mem_budget_bytes: phone_budget(16),
            eff_compute: 0.60,
            eff_bandwidth: 0.655,
            texture_cache_boost: 1.20,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: mobile_limits(),
        },
        DeviceProfile {
            name: "adreno_750",
            marketing_name: "Qualcomm Adreno 750 (Samsung S24, 8 GB)",
            vendor: Vendor::Qualcomm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 3800.0,
            fp32_gflops: 1900.0,
            int8_gops: 14200.0,
            mem_bw_gbps: 77.0,
            launch_overhead_us: 15.0,
            mem_budget_bytes: phone_budget(8),
            eff_compute: 0.645,
            eff_bandwidth: 0.72,
            texture_cache_boost: 1.20,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: mobile_limits(),
        },
        DeviceProfile {
            name: "adreno_740",
            marketing_name: "Qualcomm Adreno 740 (Samsung S23 Ultra, 8 GB)",
            vendor: Vendor::Qualcomm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 3500.0,
            fp32_gflops: 1750.0,
            int8_gops: 10800.0,
            mem_bw_gbps: 67.0,
            launch_overhead_us: 16.0,
            mem_budget_bytes: phone_budget(8),
            eff_compute: 0.62,
            eff_bandwidth: 0.72,
            texture_cache_boost: 1.20,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: mobile_limits(),
        },
        // ------------------------------------------------------- Arm Mali
        DeviceProfile {
            name: "immortalis_g720",
            marketing_name: "Arm Immortalis-G720 (Vivo X100 Pro, 16 GB)",
            vendor: Vendor::Arm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 4100.0,
            fp32_gflops: 2050.0,
            int8_gops: 13900.0,
            mem_bw_gbps: 77.0,
            launch_overhead_us: 18.0,
            mem_budget_bytes: phone_budget(16),
            eff_compute: 0.60,
            eff_bandwidth: 0.63,
            texture_cache_boost: 1.05,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: mobile_limits(),
        },
        DeviceProfile {
            name: "mali_g715",
            marketing_name: "Arm Mali-G715 (Google Pixel 9, 12 GB)",
            vendor: Vendor::Arm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 2400.0,
            fp32_gflops: 1200.0,
            int8_gops: 8000.0,
            mem_bw_gbps: 51.2,
            launch_overhead_us: 20.0,
            mem_budget_bytes: phone_budget(12),
            eff_compute: 0.60,
            eff_bandwidth: 0.63,
            texture_cache_boost: 1.05,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: mobile_limits(),
        },
        // ---------------------------------------------------------- Intel
        DeviceProfile {
            name: "intel_165u",
            marketing_name: "Intel Core Ultra 7 165U (Meteor Lake iGPU)",
            vendor: Vendor::Intel,
            class: DeviceClass::Laptop,
            api: Api::OpenCl,
            fp16_gflops: 4300.0,
            fp32_gflops: 2150.0,
            int8_gops: 0.0, // no 8-bit coop-matrix path on Meteor Lake-U OpenCL
            mem_bw_gbps: 89.6,
            // Large per-token driver overhead on Windows/Intel OpenCL —
            // fitted against the q8 vs 8/4/4 decode spread of Table 4.
            launch_overhead_us: 40.0,
            mem_budget_bytes: 11 * GIB,
            eff_compute: 0.57,
            eff_bandwidth: 0.72,
            texture_cache_boost: 1.05,
            extensions: Extensions { fp16_arith: true, ..Default::default() },
            texture_limits: desktop_limits(),
        },
        DeviceProfile {
            name: "intel_258v",
            marketing_name: "Intel Core Ultra 7 258V (Lunar Lake, Xe2 + XMX)",
            vendor: Vendor::Intel,
            class: DeviceClass::Laptop,
            api: Api::OpenCl,
            fp16_gflops: 8100.0,
            fp32_gflops: 4050.0,
            int8_gops: 48000.0, // XMX via 8-bit cooperative-matrix extension
            mem_bw_gbps: 136.5,
            launch_overhead_us: 13.0,
            mem_budget_bytes: 20 * GIB,
            eff_compute: 0.605,
            eff_bandwidth: 0.77,
            texture_cache_boost: 1.05,
            extensions: Extensions {
                int8_dot: true,
                coop_matrix_int8: true,
                fp16_arith: true,
                ..Default::default()
            },
            texture_limits: desktop_limits(),
        },
        // --------------------------------------------------------- NVIDIA
        DeviceProfile {
            name: "rtx_4090",
            marketing_name: "NVIDIA GeForce RTX 4090 (OpenCL, FP32)",
            vendor: Vendor::Nvidia,
            class: DeviceClass::Desktop,
            api: Api::OpenCl,
            fp16_gflops: 82600.0, // not reachable: OpenCL driver lacks fp16
            fp32_gflops: 82600.0,
            int8_gops: 0.0, // tensor cores unreachable from OpenCL (§4.2)
            mem_bw_gbps: 1008.0,
            launch_overhead_us: 5.0,
            mem_budget_bytes: 22 * GIB,
            eff_compute: 0.42,
            eff_bandwidth: 0.62,
            texture_cache_boost: 1.0,
            extensions: Extensions {
                matrix_units_unreachable: true,
                fp16_arith: false,
                ..Default::default()
            },
            texture_limits: desktop_limits(),
        },
        // ---------------------------------------------------------- Apple
        DeviceProfile {
            name: "m1_ultra",
            marketing_name: "Apple M1 Ultra (64-core GPU, Metal)",
            vendor: Vendor::Apple,
            class: DeviceClass::Desktop,
            api: Api::Metal,
            fp16_gflops: 21100.0, // Apple GPUs: fp16 rate == fp32 rate
            fp32_gflops: 21100.0,
            int8_gops: 0.0,
            mem_bw_gbps: 800.0,
            launch_overhead_us: 8.0,
            mem_budget_bytes: 48 * GIB,
            eff_compute: 0.45,
            eff_bandwidth: 0.55,
            texture_cache_boost: 1.10,
            extensions: Extensions { fp16_arith: true, ..Default::default() },
            texture_limits: desktop_limits(),
        },
        DeviceProfile {
            name: "m4_pro",
            marketing_name: "Apple M4 Pro (20-core GPU, Metal)",
            vendor: Vendor::Apple,
            class: DeviceClass::Laptop,
            api: Api::Metal,
            fp16_gflops: 9200.0, // Apple GPUs: fp16 rate == fp32 rate
            fp32_gflops: 9200.0,
            int8_gops: 0.0,
            mem_bw_gbps: 273.0,
            launch_overhead_us: 8.0,
            mem_budget_bytes: 17 * GIB,
            eff_compute: 0.50,
            eff_bandwidth: 0.55,
            texture_cache_boost: 1.10,
            extensions: Extensions { fp16_arith: true, ..Default::default() },
            texture_limits: desktop_limits(),
        },
    ]
}

/// Look up a device by short name.
pub fn device(name: &str) -> Option<DeviceProfile> {
    all_devices().into_iter().find(|d| d.name == name)
}

/// Short names of all registered devices.
pub fn device_names() -> Vec<&'static str> {
    all_devices().iter().map(|d| d.name).collect()
}

/// WebGPU variant of a profile: same silicon, but dispatch overhead is
/// higher and fewer extensions are reachable (paper §4: WebGPU trails
/// OpenCL ~2× on the same Intel iGPU).
pub fn webgpu_variant(base: &DeviceProfile) -> DeviceProfile {
    let mut d = base.clone();
    d.api = Api::WebGpu;
    d.launch_overhead_us *= 2.5;
    d.eff_compute *= 0.62;
    d.eff_bandwidth *= 0.80;
    d.extensions.int8_dot = false;
    d.extensions.coop_matrix_int8 = false;
    d.int8_gops = 0.0;
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_paper_devices_present() {
        let names = device_names();
        for want in [
            "adreno_830",
            "adreno_750",
            "adreno_740",
            "immortalis_g720",
            "mali_g715",
            "intel_165u",
            "intel_258v",
            "rtx_4090",
            "m1_ultra",
            "m4_pro",
        ] {
            assert!(names.contains(&want), "missing device {want}");
        }
    }

    #[test]
    fn oom_budget_reproduces_table2_footnote() {
        // Llama 3.1 8B q8 ≈ 8.5 GB of weights: must NOT fit the 8 GB and
        // 12 GB phones, must fit the 16 GB ones.
        let need: u64 = 8_500_000_000;
        assert!(device("adreno_750").unwrap().mem_budget_bytes < need);
        assert!(device("adreno_740").unwrap().mem_budget_bytes < need);
        assert!(device("mali_g715").unwrap().mem_budget_bytes < need);
        assert!(device("adreno_830").unwrap().mem_budget_bytes > need);
        assert!(device("immortalis_g720").unwrap().mem_budget_bytes > need);
    }

    #[test]
    fn nvidia_has_no_fp16_or_tensor_cores_via_opencl() {
        let d = device("rtx_4090").unwrap();
        assert!(!d.extensions.fp16_arith);
        assert!(d.extensions.matrix_units_unreachable);
        assert_eq!(d.int8_gops, 0.0);
        // fp16 requests fall back to fp32 throughput.
        use crate::device::profile::Precision;
        assert_eq!(d.effective_gflops(Precision::Fp16), d.effective_gflops(Precision::Fp32));
    }

    #[test]
    fn lunar_lake_coop_matrix_beats_meteor_lake() {
        use crate::device::profile::Precision;
        let mtl = device("intel_165u").unwrap();
        let lnl = device("intel_258v").unwrap();
        // Paper Table 4: 258V prefill is ~9× 165U thanks to the 8-bit
        // cooperative-matrix extension.
        let ratio = lnl.effective_gflops(Precision::Int8) / mtl.effective_gflops(Precision::Int8);
        assert!(ratio > 6.0, "258V/165U int8 ratio {ratio}");
    }

    #[test]
    fn webgpu_variant_slower() {
        let base = device("intel_165u").unwrap();
        let web = webgpu_variant(&base);
        assert!(web.eff_compute < base.eff_compute);
        assert!(web.launch_overhead_us > base.launch_overhead_us);
        assert_eq!(web.api, Api::WebGpu);
    }
}
