//! GPU device profiles.

use crate::vgpu::object::{StorageType, TextureLimits};

/// GPU vendor (drives kernel-selection and extension decisions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Vendor {
    Qualcomm,
    Arm,
    Intel,
    Nvidia,
    Apple,
}

/// Graphics/compute API backend used on this device.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Api {
    OpenCl,
    Metal,
    WebGpu,
}

impl Api {
    pub fn name(self) -> &'static str {
        match self {
            Api::OpenCl => "OpenCL",
            Api::Metal => "Metal",
            Api::WebGpu => "WebGPU",
        }
    }
}

/// Device class for reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Mobile,
    Laptop,
    Desktop,
}

/// Vendor extensions relevant to kernel selection (§3.4).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Extensions {
    /// 8-bit dot product instructions reachable from the API
    /// (e.g. `cl_arm_matrix_multiply`, Adreno dot8).
    pub int8_dot: bool,
    /// 8-bit cooperative-matrix / subgroup-matrix extension (Intel XMX via
    /// `cl_intel_subgroup_matrix_multiply_accumulate` on Lunar Lake).
    pub coop_matrix_int8: bool,
    /// Dedicated matrix units exist but are NOT reachable from this API
    /// (NVIDIA tensor cores under OpenCL/WebGPU — paper §4.2 reports a
    /// 4–7× prefill penalty from this).
    pub matrix_units_unreachable: bool,
    /// FP16 arithmetic support (NVIDIA OpenCL lacks it → FP32 fallback).
    pub fp16_arith: bool,
}

/// A GPU device profile: peak capabilities + calibrated efficiencies.
///
/// Peaks come from public spec sheets; `eff_*` factors are the fraction of
/// peak a well-tuned kernel achieves on that device family. They are
/// calibrated once against a single paper measurement per device (see
/// EXPERIMENTS.md) — every other workload point is then a prediction.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub marketing_name: &'static str,
    pub vendor: Vendor,
    pub class: DeviceClass,
    pub api: Api,
    /// Peak half-precision throughput, GFLOP/s.
    pub fp16_gflops: f64,
    /// Peak single-precision throughput, GFLOP/s.
    pub fp32_gflops: f64,
    /// Peak int8 MAC throughput via dot/coop-matrix extensions, GOP/s
    /// (0 when no extension).
    pub int8_gops: f64,
    /// Peak DRAM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Kernel launch + driver overhead per dispatch, microseconds.
    pub launch_overhead_us: f64,
    /// GPU-accessible memory budget, bytes (≈ 62 % of system RAM on
    /// phones — reproduces the paper's Llama-8B-q8 OOM entries).
    pub mem_budget_bytes: u64,
    /// Achievable fraction of peak compute for tuned matmul kernels.
    pub eff_compute: f64,
    /// Achievable fraction of peak bandwidth for streaming kernels.
    pub eff_bandwidth: f64,
    /// Texture path effectiveness: relative speedup of texture reads vs
    /// buffer reads for cache-friendly access (1.0 = no benefit).
    pub texture_cache_boost: f64,
    pub extensions: Extensions,
    pub texture_limits: TextureLimits,
}

impl DeviceProfile {
    /// Effective compute throughput for a given precision, GFLOP/s.
    pub fn effective_gflops(&self, precision: Precision) -> f64 {
        let peak = match precision {
            Precision::Fp16 => {
                if self.extensions.coop_matrix_int8 {
                    // Cooperative-matrix units (Intel XMX) also run fp16
                    // matmuls at half their int8 rate — the Lunar Lake SD
                    // numbers depend on this path.
                    self.fp16_gflops.max(self.int8_gops / 2.0)
                } else if self.extensions.fp16_arith {
                    self.fp16_gflops
                } else {
                    self.fp32_gflops
                }
            }
            Precision::Fp32 => self.fp32_gflops,
            Precision::Int8 => {
                if self.int8_gops > 0.0 {
                    self.int8_gops
                } else if self.extensions.fp16_arith {
                    self.fp16_gflops
                } else {
                    self.fp32_gflops
                }
            }
        };
        peak * self.eff_compute
    }

    /// Effective memory bandwidth, GB/s.
    pub fn effective_bandwidth(&self) -> f64 {
        self.mem_bw_gbps * self.eff_bandwidth
    }

    /// Preferred storage type for activations on this device family.
    /// (Empirically determined offline per the paper: Adreno favours
    /// textures, Mali buffers, Apple/Intel/NVIDIA buffers with images for
    /// spatial workloads.)
    pub fn preferred_activation_storage(&self) -> StorageType {
        match self.vendor {
            Vendor::Qualcomm => StorageType::Texture2D,
            Vendor::Apple => StorageType::Texture2D,
            Vendor::Arm | Vendor::Intel | Vendor::Nvidia => StorageType::Buffer,
        }
    }

    /// Preferred storage for weights.
    pub fn preferred_weight_storage(&self) -> StorageType {
        match self.vendor {
            Vendor::Qualcomm => StorageType::Texture2DArray,
            _ => StorageType::Buffer,
        }
    }
}

/// Arithmetic precision classes used by kernel selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DeviceProfile {
        DeviceProfile {
            name: "test_gpu",
            marketing_name: "Test GPU",
            vendor: Vendor::Qualcomm,
            class: DeviceClass::Mobile,
            api: Api::OpenCl,
            fp16_gflops: 1000.0,
            fp32_gflops: 500.0,
            int8_gops: 2000.0,
            mem_bw_gbps: 100.0,
            launch_overhead_us: 10.0,
            mem_budget_bytes: 4 << 30,
            eff_compute: 0.5,
            eff_bandwidth: 0.7,
            texture_cache_boost: 1.2,
            extensions: Extensions { int8_dot: true, fp16_arith: true, ..Default::default() },
            texture_limits: TextureLimits::default(),
        }
    }

    #[test]
    fn effective_numbers_apply_efficiency() {
        let d = sample();
        assert_eq!(d.effective_gflops(Precision::Fp16), 500.0);
        assert_eq!(d.effective_gflops(Precision::Int8), 1000.0);
        assert_eq!(d.effective_bandwidth(), 70.0);
    }

    #[test]
    fn no_fp16_falls_back_to_fp32() {
        let mut d = sample();
        d.extensions.fp16_arith = false;
        assert_eq!(d.effective_gflops(Precision::Fp16), 250.0);
    }

    #[test]
    fn no_int8_extension_uses_float_path() {
        let mut d = sample();
        d.int8_gops = 0.0;
        assert_eq!(d.effective_gflops(Precision::Int8), 500.0);
    }
}
