//! KV admission policy: how many token positions to gate admission on.
//!
//! Lifetime reservation gates (and claims) the worst case,
//! `prompt + max_new_tokens` — overflow-free, but every token a sequence
//! never generates is internal fragmentation that caps batch occupancy
//! (the gap `KvArenaStats::internal_fragmentation_bytes` reports).
//! Paged admission gates on the *expected* footprint instead: the
//! context that must prefill now, plus the observed mean generation
//! length (×  a safety margin), clamped to the request's own budget.
//! Only the context is actually claimed; decode grows block-by-block,
//! and a wrong guess degrades to preemption (queueing latency), never to
//! a failed request.
//!
//! **Chunked prefill** changes nothing here by design: admission claims
//! the whole context up front even though prefill now deposits it chunk
//! by chunk ([`crate::serving::PrefillChunk`]) — the blocks must exist
//! before any chunk's provisional scatter, and claiming per chunk would
//! let a half-prefilled sequence deadlock against its own later chunks.
//! The *partial-prefill footprint* shows up on the eviction side
//! instead: a sequence evicted between chunks bills exactly its
//! committed [`crate::serving::SeqState::prefill_progress`] positions as
//! re-prefill recompute, not its whole context.

use crate::kv::{KvPool, KvSeqHandle};
use crate::serving::request::InferenceRequest;

/// Survivorship-corrected mean generation length, the signal
/// [`AdmissionPolicy::Expected`] gates on.
///
/// A completed-only mean is biased low during warm-up: short generations
/// finish first, so admission over-admits and preemptions spike exactly
/// when the arena first fills. Every in-flight sequence's
/// generated-so-far count is a *lower bound* on its final length, so the
/// pooled mean over completed ∪ in-flight is a second (often tighter)
/// lower-bound estimate. Taking the max of the two means the blend can
/// only *raise* the estimate — admission never becomes more aggressive
/// than the completed-only form, and rises toward the true mean as the
/// long tail keeps generating.
///
/// `None` until the first completion lands (in-flight lower bounds alone
/// say nothing useful cold — everyone just started — so cold start stays
/// worst-case conservative).
pub fn blended_mean_gen(
    completed: u64,
    completed_tokens: u64,
    inflight: u64,
    inflight_tokens: u64,
) -> Option<f64> {
    if completed == 0 {
        return None;
    }
    let completed_mean = completed_tokens as f64 / completed as f64;
    let pooled = (completed_tokens + inflight_tokens) as f64 / (completed + inflight) as f64;
    Some(completed_mean.max(pooled))
}

/// Admission-footprint policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionPolicy {
    /// Gate on `context + remaining max_new_tokens` (the PR-1 discipline;
    /// pairs with whole-lifetime [`crate::kv::KvArena::claim`]).
    WorstCase,
    /// Gate on `context + min(remaining, ceil(margin × mean_gen))`,
    /// where `mean_gen` is the live mean generation length (e.g.
    /// [`crate::serving::Metrics::mean_gen_tokens`]). Falls back to the
    /// worst case until the first completion lands (cold start admits
    /// conservatively, then the expectation takes over).
    Expected {
        /// Multiplier on the observed mean (≥ 1.0 hedges against
        /// longer-than-average sequences; preemption absorbs the tail).
        safety_margin: f64,
    },
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::Expected { safety_margin: 1.5 }
    }
}

impl AdmissionPolicy {
    /// Token positions admission should require free for a candidate
    /// whose prefill must cover `context_tokens` right now (prompt for a
    /// fresh request, prompt + generated for a re-admitted preempted
    /// sequence). `mean_gen` is the observed mean generation length, if
    /// any completions have been recorded yet.
    ///
    /// All of this math is **token**-denominated, never round-denominated:
    /// under speculative decode a round emits `1 + accepted` tokens, and
    /// both the generated-so-far credit (`context_tokens - prompt`) and
    /// `mean_gen` (fed from per-token counters) grow by accepted tokens —
    /// so the expected footprint stays correct whatever the acceptance
    /// rate does.
    pub fn footprint(
        &self,
        req: &InferenceRequest,
        context_tokens: usize,
        mean_gen: Option<f64>,
    ) -> usize {
        // Tokens this sequence may still generate (generated-so-far is
        // `context - prompt` for re-admissions).
        let already = context_tokens.saturating_sub(req.prompt.len());
        let remaining = req.max_new_tokens.saturating_sub(already);
        let expected_new = match (self, mean_gen) {
            (AdmissionPolicy::WorstCase, _) | (AdmissionPolicy::Expected { .. }, None) => {
                remaining
            }
            (AdmissionPolicy::Expected { safety_margin }, Some(mean)) => {
                let margin = safety_margin.max(1.0);
                ((mean * margin).ceil() as usize).min(remaining)
            }
        };
        context_tokens + expected_new
    }

    /// Gate-and-claim for one admission candidate — the single admission
    /// step both the engine and the serving simulator run (shared for
    /// the same reason as `Scheduler::ensure_round_capacity`: so the
    /// simulator can never drift from the serving policy). Generic over
    /// [`KvPool`]: the simulator admits into the accounting
    /// [`crate::kv::KvArena`], the engine into the device-backed
    /// [`crate::kv::PagedKvStore`] (where a claim commits real region
    /// blocks). Gates on [`footprint`](Self::footprint); on success
    /// claims the whole footprint for
    /// [`WorstCase`](AdmissionPolicy::WorstCase) (lifetime discipline —
    /// growth, and therefore preemption, can never occur) but only
    /// `context_tokens` for [`Expected`](AdmissionPolicy::Expected)
    /// (paged: grow during decode). `None` means defer — backpressure,
    /// never failure.
    pub fn admit<K: KvPool>(
        &self,
        pool: &mut K,
        req: &InferenceRequest,
        context_tokens: usize,
        mean_gen: Option<f64>,
    ) -> Option<KvSeqHandle> {
        self.admit_prefixed(pool, req, context_tokens, mean_gen, &[])
    }

    /// [`admit`](Self::admit) with prefix attachment: the gate asks the
    /// pool whether the expected footprint fits **counting only unique
    /// blocks** — index-matched prefix blocks are free capacity
    /// ([`KvPool::can_claim_prefixed`]), which is exactly how sharing
    /// multiplies admitted concurrency at fixed arena bytes. Pools
    /// without content addressing fall back to the plain gate, so the
    /// policy stays one code path across engine and simulator.
    pub fn admit_prefixed<K: KvPool>(
        &self,
        pool: &mut K,
        req: &InferenceRequest,
        context_tokens: usize,
        mean_gen: Option<f64>,
        prefix: &[crate::kv::PrefixKey],
    ) -> Option<KvSeqHandle> {
        let expected = self.footprint(req, context_tokens, mean_gen);
        if !pool.can_claim_prefixed(expected, prefix) {
            return None;
        }
        let claim_tokens = match self {
            AdmissionPolicy::WorstCase => expected,
            AdmissionPolicy::Expected { .. } => context_tokens,
        };
        pool.claim_prefixed(claim_tokens, prefix).ok()
    }

    /// [`admit_prefixed`](Self::admit_prefixed) plus a **companion
    /// claim** — the fleet-serving admission step. The target pool gates
    /// and claims as usual; the sequence's bound draft pool (if any)
    /// then claims the same context. A companion miss releases the
    /// target claim and defers the whole admission — backpressure, so
    /// the two pools can never disagree about who is admitted.
    /// `companion: None` (no draft bound) is exactly `admit_prefixed`.
    /// The companion claims plainly (never prefixed): draft stores do
    /// not share prefixes.
    pub fn admit_with_companion<K: KvPool, D: KvPool>(
        &self,
        pool: &mut K,
        companion: Option<&mut D>,
        req: &InferenceRequest,
        context_tokens: usize,
        mean_gen: Option<f64>,
        prefix: &[crate::kv::PrefixKey],
    ) -> Option<(KvSeqHandle, Option<KvSeqHandle>)> {
        let h = self.admit_prefixed(pool, req, context_tokens, mean_gen, prefix)?;
        match companion {
            None => Some((h, None)),
            Some(c) => match c.claim(context_tokens) {
                Ok(dh) => Some((h, Some(dh))),
                Err(_) => {
                    pool.release(h);
                    None
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt_len: usize, max_new: usize) -> InferenceRequest {
        InferenceRequest::new(1, vec![0; prompt_len], max_new)
    }

    #[test]
    fn worst_case_is_lifetime_footprint() {
        let r = req(64, 192);
        assert_eq!(AdmissionPolicy::WorstCase.footprint(&r, 64, Some(10.0)), 256);
    }

    #[test]
    fn expected_footprint_tracks_mean_with_margin() {
        let r = req(64, 192);
        let p = AdmissionPolicy::Expected { safety_margin: 1.5 };
        // No history yet: conservative cold start.
        assert_eq!(p.footprint(&r, 64, None), 256);
        // Mean 16 → expect ceil(24) beyond the context.
        assert_eq!(p.footprint(&r, 64, Some(16.0)), 64 + 24);
        // Expectation never exceeds the request's own budget.
        assert_eq!(p.footprint(&r, 64, Some(1000.0)), 256);
    }

    #[test]
    fn readmission_counts_generated_tokens_against_budget() {
        // A preempted sequence re-admitting with 32 tokens generated has
        // context 96 and at most 160 still to come.
        let r = req(64, 192);
        assert_eq!(AdmissionPolicy::WorstCase.footprint(&r, 96, None), 96 + 160);
        let p = AdmissionPolicy::Expected { safety_margin: 1.0 };
        assert_eq!(p.footprint(&r, 96, Some(8.0)), 96 + 8);
    }

    #[test]
    fn footprint_counts_accepted_tokens_not_rounds() {
        // Speculative decode: 20 tokens generated across 5 rounds
        // (acceptance widened every round). The re-admission footprint
        // must charge all 20 generated tokens against the budget — a
        // round-denominated estimate would under-count by the acceptance
        // factor and over-admit exactly when spec decode performs best.
        let r = req(32, 64);
        // context = 32 prompt + 20 generated ⇒ 44 of the budget remain.
        assert_eq!(AdmissionPolicy::WorstCase.footprint(&r, 52, None), 52 + 44);
        let p = AdmissionPolicy::Expected { safety_margin: 1.0 };
        assert_eq!(p.footprint(&r, 52, Some(10.0)), 52 + 10);
        // The expectation still clamps to the remaining token budget.
        assert_eq!(p.footprint(&r, 52, Some(100.0)), 52 + 44);
    }

    #[test]
    fn blended_mean_corrects_survivorship_bias_upward_only() {
        // No completions: stay worst-case conservative regardless of
        // in-flight lower bounds (they say nothing useful cold).
        assert_eq!(blended_mean_gen(0, 0, 8, 16), None);
        // Shorts completed (mean 4) while longs are in flight at 20
        // tokens each: the pooled lower bound pulls the estimate up.
        assert_eq!(blended_mean_gen(4, 16, 4, 80), Some(12.0));
        // A fresh admission wave (tiny in-flight counts) must NOT drag
        // the estimate below the completed mean — the blend only raises.
        assert_eq!(blended_mean_gen(4, 16, 4, 4), Some(4.0));
        // Uniform workloads are unaffected: in-flight lower bounds never
        // exceed the completed mean, so the estimate is unchanged.
        assert_eq!(blended_mean_gen(10, 160, 5, 40), Some(16.0));
    }

    #[test]
    fn admit_claims_footprint_for_worst_case_and_context_for_expected() {
        use crate::kv::{KvArena, KvArenaConfig};
        let arena_cfg = KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 8,
        };
        let r = req(16, 48); // worst case = 64 tokens = 4 blocks
        let mut arena = KvArena::new(arena_cfg);
        let h = AdmissionPolicy::WorstCase.admit(&mut arena, &r, 16, None).unwrap();
        assert_eq!(arena.blocks_in_use(), 4, "lifetime claims the whole footprint");
        arena.release(h);
        let p = AdmissionPolicy::Expected { safety_margin: 1.0 };
        let _h = p.admit(&mut arena, &r, 16, None).unwrap();
        assert_eq!(arena.blocks_in_use(), 1, "paged claims only the context");
        // The gate defers when the expectation does not fit, even though
        // the context alone would.
        let mut tiny = KvArena::new(KvArenaConfig { num_blocks: 2, ..arena_cfg });
        assert!(p.admit(&mut tiny, &r, 16, None).is_none(), "cold start gates worst-case");
        assert!(p.admit(&mut tiny, &r, 16, Some(8.0)).is_some(), "expectation fits");
    }

    #[test]
    fn admit_prefixed_counts_only_unique_blocks() {
        use crate::kv::{shareable_prefix_keys, KvArena, KvArenaConfig};
        let cfg = KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 5,
        };
        let mut arena = KvArena::new(cfg);
        let p = AdmissionPolicy::Expected { safety_margin: 1.0 };
        let prompt: Vec<i32> = (0..64).collect();
        let keys = shareable_prefix_keys(&prompt, 16);
        let r = InferenceRequest::new(1, prompt.clone(), 4);
        // First holder admits cold (nothing published yet) and publishes
        // its committed prefix: 4 blocks in use, 1 free.
        let h = p.admit_prefixed(&mut arena, &r, 64, Some(1.0), &keys).unwrap();
        arena.append(h, 64).unwrap();
        assert_eq!(arena.publish_prefix(h, &keys).unwrap(), 4);
        assert_eq!(arena.blocks_in_use(), 4);

        // A second identical request needs 5 unique blocks — the plain
        // gate defers (1 free), but the prefix-aware gate sees 4 of the
        // 5 already resident and admits with zero fresh claims.
        let r2 = InferenceRequest::new(2, prompt, 4);
        assert!(p.admit(&mut arena, &r2, 64, Some(1.0)).is_none(), "plain gate defers");
        let h2 = p.admit_prefixed(&mut arena, &r2, 64, Some(1.0), &keys).unwrap();
        assert_eq!(arena.blocks_in_use(), 4, "attached blocks cost nothing");
        assert_eq!(arena.shared_blocks(), 4);
        assert_eq!(arena.len(h2), 63, "prefill resumes past the covered prefix");
        arena.verify().unwrap();
    }

    #[test]
    fn companion_admission_is_atomic_across_pools() {
        use crate::kv::{KvArena, KvArenaConfig};
        let cfg = KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 8,
        };
        let r = req(16, 48); // worst case = 64 tokens = 4 blocks
        let p = AdmissionPolicy::WorstCase;

        // No companion bound: exactly admit_prefixed.
        let mut target = KvArena::new(cfg);
        let (h, dh) = p
            .admit_with_companion::<_, KvArena>(&mut target, None, &r, 16, None, &[])
            .unwrap();
        assert!(dh.is_none());
        assert_eq!(target.blocks_in_use(), 4);
        target.release(h);

        // Companion with room: both pools claim.
        let mut draft = KvArena::new(cfg);
        let (h, dh) = p
            .admit_with_companion(&mut target, Some(&mut draft), &r, 16, None, &[])
            .unwrap();
        assert_eq!(target.blocks_in_use(), 4);
        assert_eq!(draft.blocks_in_use(), 1, "companion claims only the context");
        target.release(h);
        draft.release(dh.unwrap());

        // Companion full: the target claim is rolled back and the whole
        // admission defers — neither pool leaks a half-admitted sequence.
        let mut full = KvArena::new(KvArenaConfig { num_blocks: 0, ..cfg });
        assert!(p
            .admit_with_companion(&mut target, Some(&mut full), &r, 16, None, &[])
            .is_none());
        assert_eq!(target.blocks_in_use(), 0, "target claim rolled back");
    }

    #[test]
    fn margin_below_one_is_clamped() {
        let r = req(10, 100);
        let p = AdmissionPolicy::Expected { safety_margin: 0.5 };
        assert_eq!(p.footprint(&r, 10, Some(10.0)), 10 + 10, "margin clamps to 1.0");
    }
}
