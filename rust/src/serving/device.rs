//! The device actor of the truly-async engine.
//!
//! The async worker splits into two actors. The **policy thread** (the
//! engine worker) keeps everything that decides: scheduler, admission,
//! plan, reap. The **device thread** (spawned here) owns everything that
//! executes: the loaded models — PJRT handles are not `Send`, so the
//! runtime is *created on* this thread and never leaves it — plus the
//! dispatch of each round against the shared paged stores.
//!
//! The two talk over a bounded pair of channels:
//!
//! * **submission** (`sync_channel(1)`): fully-bound [`RoundDescriptor`]s
//!   — every token, position, handle, and draft catch-up already
//!   resolved by the policy thread's bind stage. The bound of 1 encodes
//!   the depth-2 structure: decode is token-serial, so at most one round
//!   can ever be in flight ahead of the plan.
//! * **completion**: [`RoundCompletion`]s drain back and are applied by
//!   the policy thread's reap stage — the same if-let-guarded
//!   application the synchronous pipelined loop used, because a plan may
//!   preempt a member while its round sits in the channel or executes.
//!
//! Ordering contract (mirrored by `check::model`'s device actor — the
//! model was extended and re-verified against K1–K6/P1–P3 + K7 before
//! this code was written): the policy thread opens the slot's
//! reservation window **before** the descriptor is submitted and closes
//! it only after the completion is reaped, so every block a descriptor
//! references stays pinned across the channel boundary — a window must
//! outlive cross-thread submission, not just slot reap. A member
//! preempted mid-flight keeps its blocks pinned (deferred free) while
//! its *handle* is released, so the device's generational handle checks
//! turn the stale work into per-member errors, never aliased writes.
//!
//! Store locking: the device locks a store for the duration of one model
//! call (for the PJRT runtime a call spans the whole round — overlap on
//! that path is bounded by lock contention, which DESIGN.md §8 is honest
//! about); modeled device time ([`LmBackend::simulated_device_busy`], the
//! fake-model path the overlap bench measures) is spun **outside** any
//! lock, so plan-stage store work genuinely overlaps it. When a
//! speculative dispatch needs both stores, the target store is locked
//! first, then the draft store — the same order the policy thread uses,
//! so the two actors cannot deadlock.

use std::collections::HashSet;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{DriftError, Result};
use crate::kv::KvSeqHandle;
use crate::runtime::backend::LmBackend;
use crate::runtime::tinylm::{
    PackedPrefillChunk, PagedRoundStep, PrefillChunkOutcome, RoundStepOutcome, SpecStepArgs,
    SpecStepOutcome, TinyLmRuntime,
};
use crate::runtime::Runtime;
use crate::serving::registry::{FleetPolicy, ModelDims, ModelRegistry, SharedKvStore};
use crate::serving::request::RequestId;
use crate::serving::server::{
    build_target_store, slot_jitter_us, EngineConfig, FleetConfig, SampledSpecConfig,
    KV_BLOCK_TOKENS,
};
use crate::util::rng::Pcg32;

/// Resolved fleet state: the registry (target + loaded drafts, each with
/// its own worst-case-sized shared paged store) plus the market and
/// sampling toggles. In serial mode the worker thread owns this whole;
/// in async mode it lives on the device thread and the policy thread
/// gets the [`FleetPolicy`] projection.
pub(crate) struct FleetRuntime<B> {
    pub reg: ModelRegistry<B>,
    pub adaptive_k: bool,
    pub ewma_weight: f64,
    pub sampled: Option<SampledSpecConfig>,
}

/// Load the TinyLM target (and the configured draft fleet) from
/// artifacts. Must run on the thread that will own the result — PJRT
/// handles are not `Send` — which is the worker thread in serial mode
/// and the device thread in async mode.
pub(crate) fn load_tinylm_fleet(
    dir: &str,
    fleet_cfg: Option<FleetConfig>,
    max_active: usize,
) -> Result<FleetRuntime<TinyLmRuntime>> {
    let rt = Runtime::cpu()?;
    let target = TinyLmRuntime::load(&rt, dir)?;
    let dims = ModelDims::of(&target.manifest);
    let mut reg = ModelRegistry::new(target, dims);
    let (adaptive_k, ewma_weight, sampled) = match &fleet_cfg {
        Some(f) => {
            for d in &f.drafts {
                let m = TinyLmRuntime::load(&rt, &d.artifacts_dir)?;
                let dm = ModelDims::of(&m.manifest);
                reg.add_draft(m, dm, d.k_max.max(1), d.cost, max_active, KV_BLOCK_TOKENS);
            }
            (f.adaptive_k, f.ewma_weight, f.sampled)
        }
        None => (false, 0.3, None),
    };
    Ok(FleetRuntime { reg, adaptive_k, ewma_weight, sampled })
}

/// One draft catch-up prefill the bind stage resolved: run it on the
/// device iff the sequence's final prefill chunk (same round) succeeds.
pub(crate) struct DraftPrefillJob {
    pub id: RequestId,
    pub di: usize,
    pub dh: KvSeqHandle,
    /// The whole context (prompt + generated as of bind) — frozen at
    /// bind time, which is sound because a prefilling sequence decodes
    /// nothing between its bind and its reap.
    pub ctx: Vec<i32>,
}

/// A fully-bound round: everything the device needs to execute without
/// consulting policy state. All handles it references are pinned by the
/// slot window the policy opened before submitting — the descriptor
/// must never be built before its window.
pub(crate) struct RoundDescriptor {
    /// Gather-scratch parity for this slot
    /// ([`crate::kv::PagedKvStore::select_scratch_slot`]) — selected by
    /// the device at execution start, NOT at bind, because the previous
    /// round may still be gathering when this one is bound.
    pub scratch_slot: usize,
    /// Plain decode steps (ids parallel to `steps`).
    pub step_ids: Vec<RequestId>,
    pub steps: Vec<PagedRoundStep>,
    /// Speculative members grouped by draft index, one batched dispatch
    /// per group.
    pub spec_groups: Vec<(Vec<RequestId>, Vec<(SpecStepArgs, Vec<i32>)>)>,
    /// The round's packed prefill (ids parallel to `pack`).
    pub pack_ids: Vec<RequestId>,
    pub pack: Vec<PackedPrefillChunk>,
    pub draft_prefills: Vec<DraftPrefillJob>,
}

/// The outcomes of one executed round, drained back to the policy
/// thread's reap stage.
pub(crate) struct RoundCompletion {
    pub decode: Vec<(RequestId, Result<RoundStepOutcome>)>,
    pub spec: Vec<(RequestId, Result<(SpecStepOutcome, f64)>)>,
    pub prefill: Vec<(RequestId, PackedPrefillChunk, Result<PrefillChunkOutcome>)>,
    /// Draft catch-up outcomes: `Ok(context_len)` committed that many
    /// draft rows; `Err` means the policy must downgrade the sequence to
    /// plain decode (release the draft handle) — unless it already
    /// preempted the sequence while this round was in flight.
    pub draft_prefill: Vec<(RequestId, usize, KvSeqHandle, Result<usize>)>,
}

/// What the device thread hands back once loading succeeds: the `Send`
/// planning view plus the shared target store it built.
pub(crate) struct DeviceReady {
    pub fleet: FleetPolicy,
    pub store: SharedKvStore,
    pub adaptive_k: bool,
    pub ewma_weight: f64,
}

/// The policy thread's handle to the device actor.
pub(crate) struct DeviceQueue {
    pub submit: SyncSender<RoundDescriptor>,
    pub completions: Receiver<RoundCompletion>,
    join: Option<JoinHandle<()>>,
}

impl DeviceQueue {
    /// Close the submission channel (ending the device loop) and join
    /// the device thread. Call only after the last completion is reaped.
    pub fn shutdown(self) {
        let DeviceQueue { submit, completions, join } = self;
        drop(submit);
        drop(completions);
        if let Some(j) = join {
            let _ = j.join();
        }
    }
}

/// Spawn the device thread: it runs `loader` (so model handles are born
/// on the thread that owns them), builds the shared target store, hands
/// back the policy view, then serves the submission channel until the
/// policy side drops it.
pub(crate) fn spawn_device<B, L>(loader: L, cfg: EngineConfig) -> Result<(DeviceQueue, DeviceReady)>
where
    B: LmBackend + 'static,
    L: FnOnce() -> Result<FleetRuntime<B>> + Send + 'static,
{
    let (submit, rounds) = sync_channel::<RoundDescriptor>(1);
    let (completion_tx, completions) = channel::<RoundCompletion>();
    let (init_tx, init_rx) = channel::<Result<DeviceReady>>();
    let join = std::thread::Builder::new()
        .name("mldrift-device".into())
        .spawn(move || {
            let fleet = match loader() {
                Ok(f) => f,
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            let store: SharedKvStore =
                Arc::new(Mutex::new(build_target_store(fleet.reg.target().manifest(), &cfg)));
            let ready = DeviceReady {
                fleet: fleet.reg.policy_view(),
                store: Arc::clone(&store),
                adaptive_k: fleet.adaptive_k,
                ewma_weight: fleet.ewma_weight,
            };
            let _ = init_tx.send(Ok(ready));
            device_loop(fleet, store, rounds, completion_tx);
        })
        .map_err(|e| DriftError::Serving(format!("spawn device thread: {e}")))?;
    match init_rx.recv() {
        Ok(Ok(ready)) => Ok((DeviceQueue { submit, completions, join: Some(join) }, ready)),
        Ok(Err(e)) => {
            let _ = join.join();
            Err(e)
        }
        Err(_) => {
            let _ = join.join();
            Err(DriftError::Serving("device thread died during startup".into()))
        }
    }
}

/// Busy-wait for `d` — the realization of modeled device seconds as wall
/// clock. A spin (not a sleep) so the duration is accurate at the
/// sub-millisecond scale the overlap bench measures.
pub(crate) fn spin_wait(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t = Instant::now();
    while t.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// The device loop: dequeue one bound round, execute it against the
/// shared stores (locking per model call; modeled busy time spun outside
/// any lock), send the completion back. FIFO by construction — one
/// thread, one channel — which is exactly the `submitted == executed`
/// gating the drift-check model's `Submit`/`Exec` steps encode.
fn device_loop<B: LmBackend>(
    fleet: FleetRuntime<B>,
    store: SharedKvStore,
    rounds: Receiver<RoundDescriptor>,
    completions: Sender<RoundCompletion>,
) {
    let FleetRuntime { reg, sampled, .. } = fleet;
    let mut spec_rng = sampled.map(|s| Pcg32::seeded(s.seed));
    let jitter_us = slot_jitter_us();
    while let Ok(desc) = rounds.recv() {
        if jitter_us > 0 {
            std::thread::sleep(Duration::from_micros(jitter_us));
        }
        let RoundDescriptor {
            scratch_slot,
            step_ids,
            steps,
            spec_groups,
            pack_ids,
            pack,
            draft_prefills,
        } = desc;
        let decode_members =
            steps.len() + spec_groups.iter().map(|(ids, _)| ids.len()).sum::<usize>();
        let prefill_tokens: usize = pack.iter().map(|c| c.tokens.len()).sum();

        let decode: Vec<(RequestId, Result<RoundStepOutcome>)> = {
            let mut st = store.lock().expect("target store lock poisoned");
            st.select_scratch_slot(scratch_slot);
            let outs = reg.target().decode_round_paged(&mut st, &steps);
            step_ids.into_iter().zip(outs).collect()
        };

        let mut spec: Vec<(RequestId, Result<(SpecStepOutcome, f64)>)> = Vec::new();
        for (di, (ids, group)) in spec_groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            // Lock order: target store, then draft store (matches the
            // policy thread's bind stage — never invert).
            let mut st = store.lock().expect("target store lock poisoned");
            let (target_m, draft_m, mut ds) = reg.spec_parts(di);
            let outs = match (sampled, spec_rng.as_mut()) {
                (Some(sc), Some(rng)) => target_m.spec_round_paged_sampled(
                    draft_m,
                    &mut st,
                    &mut ds,
                    &group,
                    sc.temperature,
                    rng,
                ),
                _ => target_m.spec_round_paged(draft_m, &mut st, &mut ds, &group),
            };
            spec.extend(ids.into_iter().zip(outs));
        }

        let prefill_outs = {
            let mut st = store.lock().expect("target store lock poisoned");
            reg.target().prefill_pack(&mut st, &pack)
        };
        // Draft catch-up runs only for sequences whose final chunk (in
        // this very round) succeeded — the same "once, at the final
        // chunk" rule the serial loop applies.
        let ok_last: HashSet<RequestId> = pack_ids
            .iter()
            .zip(&pack)
            .zip(&prefill_outs)
            .filter(|((_, c), o)| c.last && o.is_ok())
            .map(|((id, _), _)| *id)
            .collect();
        let mut draft_prefill: Vec<(RequestId, usize, KvSeqHandle, Result<usize>)> = Vec::new();
        for job in draft_prefills {
            if !ok_last.contains(&job.id) {
                continue;
            }
            let (_, draft_m, mut ds) = reg.spec_parts(job.di);
            let res = match draft_m.prefill_paged(&job.ctx, &mut ds, job.dh) {
                Ok(_) => {
                    // An append failure leaves the binding usable: the
                    // next round's catch-up covers the shortfall (same
                    // tolerance as the serial loop).
                    if let Err(e) = ds.append(job.dh, job.ctx.len()) {
                        crate::log_error!("draft kv append for request {}: {e}", job.id);
                    }
                    Ok(job.ctx.len())
                }
                Err(e) => Err(e),
            };
            draft_prefill.push((job.id, job.di, job.dh, res));
        }
        let prefill: Vec<(RequestId, PackedPrefillChunk, Result<PrefillChunkOutcome>)> = pack_ids
            .into_iter()
            .zip(pack)
            .zip(prefill_outs)
            .map(|((id, c), o)| (id, c, o))
            .collect();

        // Modeled device time realizes OUTSIDE any store lock: the
        // policy thread's plan for the next round runs against the
        // stores while this spins — the overlap the bench measures.
        if let Some(d) = reg.target().simulated_device_busy(decode_members, prefill_tokens) {
            spin_wait(d);
        }
        if completions.send(RoundCompletion { decode, spec, prefill, draft_prefill }).is_err() {
            break; // policy side gone; nothing left to report to
        }
    }
}
