//! Serving metrics: counters + latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Histogram;

/// Engine-wide metrics, safe to share across threads.
#[derive(Debug)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    ttft: Mutex<Histogram>,
    decode_step: Mutex<Histogram>,
    e2e: Mutex<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            // 100 µs .. ~100 s exponential buckets.
            ttft: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
            decode_step: Mutex::new(Histogram::exponential(1e-5, 1.6, 32)),
            e2e: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
        }
    }
}

impl Metrics {
    pub fn record_submit(&self) {
        self.requests_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, prefill_tokens: usize, gen_tokens: usize, ttft_s: f64, e2e_s: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(gen_tokens as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.ttft.lock().unwrap().record(ttft_s);
        self.e2e.lock().unwrap().record(e2e_s);
    }

    pub fn record_decode_step(&self, s: f64) {
        self.decode_step.lock().unwrap().record(s);
    }

    pub fn ttft_p50_p95(&self) -> (f64, f64) {
        let h = self.ttft.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn decode_step_p50_p95(&self) -> (f64, f64) {
        let h = self.decode_step.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn e2e_mean(&self) -> f64 {
        self.e2e.lock().unwrap().mean()
    }

    /// One-paragraph human report.
    pub fn report(&self) -> String {
        let (t50, t95) = self.ttft_p50_p95();
        let (d50, d95) = self.decode_step_p50_p95();
        format!(
            "requests: {} submitted, {} completed | tokens: {} prefill, {} generated\n\
             ttft p50 {:.1} ms, p95 {:.1} ms | decode step p50 {:.2} ms, p95 {:.2} ms | e2e mean {:.1} ms",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            t50 * 1e3,
            t95 * 1e3,
            d50 * 1e3,
            d95 * 1e3,
            self.e2e_mean() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_submit();
        m.record_submit();
        m.record_completion(64, 16, 0.05, 0.5);
        m.record_decode_step(0.002);
        m.record_decode_step(0.004);
        assert_eq!(m.requests_submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let (p50, p95) = m.decode_step_p50_p95();
        assert!(p50 > 0.0 && p95 >= p50);
        assert!(m.report().contains("requests: 2 submitted"));
    }
}
