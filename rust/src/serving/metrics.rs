//! Serving metrics: counters + latency histograms + round/batch occupancy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Histogram;

/// Engine-wide metrics, safe to share across threads.
#[derive(Debug)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    /// Scheduling rounds executed (only rounds with work).
    pub rounds_executed: AtomicU64,
    /// Paged-KV evictions: sequences bounced back to the re-admission
    /// queue because the arena could not grow mid-round.
    pub preemptions: AtomicU64,
    /// Token positions recomputed because of eviction: a prefilled
    /// victim bills its whole context — prompt + generated so far — to
    /// the re-prefill on re-admission; one evicted before its prefill
    /// ever ran bills nothing. The honest price of thrashing.
    pub reprefill_tokens: AtomicU64,
    ttft: Mutex<Histogram>,
    decode_step: Mutex<Histogram>,
    e2e: Mutex<Histogram>,
    /// Executed decode-batch size per round — how well weight streaming
    /// amortizes.
    batch_occupancy: Mutex<Histogram>,
    /// Generated tokens per round. Can exceed the executed batch:
    /// final-token emissions need no decode step, and speculative decode
    /// will widen the gap further.
    tokens_per_round: Mutex<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            rounds_executed: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            reprefill_tokens: AtomicU64::new(0),
            // 100 µs .. ~100 s exponential buckets.
            ttft: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
            decode_step: Mutex::new(Histogram::exponential(1e-5, 1.6, 32)),
            e2e: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
            // Exact buckets 1..=64 (batch sizes are small integers).
            batch_occupancy: Mutex::new(Histogram::linear(1.0, 1.0, 64)),
            tokens_per_round: Mutex::new(Histogram::linear(1.0, 1.0, 64)),
        }
    }
}

impl Metrics {
    pub fn record_submit(&self) {
        self.requests_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, prefill_tokens: usize, gen_tokens: usize, ttft_s: f64, e2e_s: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(gen_tokens as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.ttft.lock().unwrap().record(ttft_s);
        self.e2e.lock().unwrap().record(e2e_s);
    }

    pub fn record_decode_step(&self, s: f64) {
        self.decode_step.lock().unwrap().record(s);
    }

    /// Record one eviction and the context it will have to re-prefill.
    pub fn record_preemption(&self, reprefill_tokens: usize) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
        self.reprefill_tokens.fetch_add(reprefill_tokens as u64, Ordering::Relaxed);
    }

    /// Mean generated tokens per completed request — the signal
    /// expected-footprint admission gates on
    /// ([`crate::serving::AdmissionPolicy::Expected`]). `None` until the
    /// first completion lands (cold start admits by worst case).
    pub fn mean_gen_tokens(&self) -> Option<f64> {
        let completed = self.requests_completed.load(Ordering::Relaxed);
        if completed == 0 {
            return None;
        }
        Some(self.tokens_generated.load(Ordering::Relaxed) as f64 / completed as f64)
    }

    /// Record one executed round: decode-batch occupancy and generated
    /// tokens. Zero-valued samples (pure-prefill rounds, or emission-only
    /// rounds with no executed step) don't pollute either distribution.
    pub fn record_round(&self, decode_batch: usize, gen_tokens: usize) {
        self.rounds_executed.fetch_add(1, Ordering::Relaxed);
        if decode_batch > 0 {
            self.batch_occupancy.lock().unwrap().record(decode_batch as f64);
        }
        if gen_tokens > 0 {
            self.tokens_per_round.lock().unwrap().record(gen_tokens as f64);
        }
    }

    pub fn ttft_p50_p95(&self) -> (f64, f64) {
        let h = self.ttft.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn decode_step_p50_p95(&self) -> (f64, f64) {
        let h = self.decode_step.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn e2e_mean(&self) -> f64 {
        self.e2e.lock().unwrap().mean()
    }

    /// (mean, p50, max) decode-batch occupancy across rounds.
    pub fn batch_occupancy_summary(&self) -> (f64, f64, f64) {
        let h = self.batch_occupancy.lock().unwrap();
        (h.mean(), h.percentile(50.0), h.max())
    }

    /// Mean generated tokens per round.
    pub fn tokens_per_round_mean(&self) -> f64 {
        self.tokens_per_round.lock().unwrap().mean()
    }

    /// One-paragraph human report.
    pub fn report(&self) -> String {
        let (t50, t95) = self.ttft_p50_p95();
        let (d50, d95) = self.decode_step_p50_p95();
        let (occ_mean, occ_p50, occ_max) = self.batch_occupancy_summary();
        format!(
            "requests: {} submitted, {} completed | tokens: {} prefill, {} generated\n\
             ttft p50 {:.1} ms, p95 {:.1} ms | decode step p50 {:.2} ms, p95 {:.2} ms | e2e mean {:.1} ms\n\
             rounds: {} | batch occupancy mean {:.2}, p50 {:.0}, max {:.0} | tokens/round mean {:.2}\n\
             preemptions: {} | re-prefill tokens: {}",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            t50 * 1e3,
            t95 * 1e3,
            d50 * 1e3,
            d95 * 1e3,
            self.e2e_mean() * 1e3,
            self.rounds_executed.load(Ordering::Relaxed),
            occ_mean,
            occ_p50,
            occ_max,
            self.tokens_per_round_mean(),
            self.preemptions.load(Ordering::Relaxed),
            self.reprefill_tokens.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_submit();
        m.record_submit();
        m.record_completion(64, 16, 0.05, 0.5);
        m.record_decode_step(0.002);
        m.record_decode_step(0.004);
        assert_eq!(m.requests_submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let (p50, p95) = m.decode_step_p50_p95();
        assert!(p50 > 0.0 && p95 >= p50);
        assert!(m.report().contains("requests: 2 submitted"));
    }

    #[test]
    fn preemption_and_mean_gen_tracked() {
        let m = Metrics::default();
        assert_eq!(m.mean_gen_tokens(), None, "no completions: no expectation");
        m.record_completion(64, 10, 0.05, 0.5);
        m.record_completion(64, 20, 0.05, 0.5);
        assert_eq!(m.mean_gen_tokens(), Some(15.0));
        m.record_preemption(72);
        m.record_preemption(40);
        assert_eq!(m.preemptions.load(Ordering::Relaxed), 2);
        assert_eq!(m.reprefill_tokens.load(Ordering::Relaxed), 112);
        assert!(m.report().contains("preemptions: 2"));
    }

    #[test]
    fn round_occupancy_tracked_exactly() {
        let m = Metrics::default();
        m.record_round(4, 4);
        m.record_round(4, 4);
        m.record_round(2, 2);
        m.record_round(0, 0); // pure-prefill round: counted, not sampled
        assert_eq!(m.rounds_executed.load(Ordering::Relaxed), 4);
        let (mean, p50, max) = m.batch_occupancy_summary();
        assert!((mean - 10.0 / 3.0).abs() < 1e-9, "{mean}");
        assert_eq!(p50, 4.0);
        assert_eq!(max, 4.0);
        assert!((m.tokens_per_round_mean() - 10.0 / 3.0).abs() < 1e-9);
        assert!(m.report().contains("batch occupancy"));
    }
}
