//! Serving metrics: counters + latency histograms + round/batch occupancy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Histogram;

/// Engine-wide metrics, safe to share across threads.
#[derive(Debug)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub prefill_tokens: AtomicU64,
    /// Scheduling rounds executed (only rounds with work).
    pub rounds_executed: AtomicU64,
    /// Paged-KV evictions: sequences bounced back to the re-admission
    /// queue because the arena could not grow mid-round.
    pub preemptions: AtomicU64,
    /// Token positions recomputed because of eviction: a prefilled
    /// victim bills its whole context — prompt + generated so far — to
    /// the re-prefill on re-admission; one evicted before its prefill
    /// ever ran bills nothing. The honest price of thrashing.
    pub reprefill_tokens: AtomicU64,
    /// In-flight gauge: sequences currently active or preempted (set by
    /// the engine each round from scheduler state).
    pub inflight_seqs: AtomicU64,
    /// In-flight gauge: tokens generated so far by those sequences —
    /// per-sequence lower bounds the blended estimator folds in.
    pub inflight_gen_tokens: AtomicU64,
    /// Gauge: device bytes currently committed to live KV blocks in the
    /// paged region ([`crate::kv::PagedKvStore::device_bytes_in_use`]).
    pub kv_device_bytes_in_use: AtomicU64,
    /// Gauge: high-water mark of `kv_device_bytes_in_use`.
    pub kv_device_bytes_peak: AtomicU64,
    /// Device bytes released by preemptions (scrubbed region blocks) —
    /// nonzero iff eviction actually lowered the device watermark, which
    /// is exactly what the paged-KV e2e test asserts.
    pub kv_bytes_freed_by_preemption: AtomicU64,
    /// Prefill chunks executed (chunked + packed prefill). With chunking
    /// off this equals the number of prefill executions; with it on, the
    /// ratio to `prefill_chunk_tokens` shows the pack granularity the
    /// engine actually ran at.
    pub prefill_chunks: AtomicU64,
    /// Context positions deposited by prefill chunks (initial prefills
    /// and re-prefills alike — compare with `reprefill_tokens` for the
    /// recompute share).
    pub prefill_chunk_tokens: AtomicU64,
    /// Prefix sharing: prompt positions admission *attached* from
    /// published KV blocks instead of prefilling — each one is prefill
    /// compute the device never ran (compare with `prefill_chunk_tokens`
    /// for the dedup share).
    pub kv_prefix_shared_tokens: AtomicU64,
    /// Gauge: extra references currently held onto shared KV blocks
    /// (Σ `refcount − 1` — the blocks the arena does *not* hold twice).
    pub kv_blocks_shared: AtomicU64,
    /// Gauge: cumulative copy-on-write block copies the store has
    /// performed (a sequence wrote into a block it shared).
    pub kv_cow_copies: AtomicU64,
    /// Gauge: rows the quantized KV region dequantized in-gather
    /// (int8 serving; stays 0 for an fp32 store) — the live signal the
    /// quantized-serving e2e asserts alongside the sharing gauges.
    pub kv_dequant_rows: AtomicU64,
    /// Gauge: configured pipeline depth (1 = the serial round loop).
    pub pipeline_depth: AtomicU64,
    /// Pipeline slots whose *plan* stage ran while the previous slot was
    /// still in flight — the overlap the pipelined executor exists to
    /// create. Structurally 0 at depth 1 (the serial loop never plans
    /// ahead), so a nonzero value is proof the staged path actually
    /// overlapped rather than degenerating to serial.
    pub pipeline_planned_ahead_slots: AtomicU64,
    /// Speculative decode: draft tokens proposed across all rounds.
    pub spec_proposed_tokens: AtomicU64,
    /// Speculative decode: draft tokens accepted by the verify pass. The
    /// ratio to `spec_proposed_tokens` is the live acceptance rate — the
    /// signal the draft-k breakeven math keys on.
    pub spec_accepted_tokens: AtomicU64,
    /// Draft market: speculative steps *planned* (sequence-rounds whose
    /// chosen width was > 0). With the adaptive market on, comparing
    /// against executed decode rounds shows how much traffic the
    /// controller sent down the plain path instead.
    pub spec_planned_rounds: AtomicU64,
    /// Draft market: Σ of the planned widths. `spec_k_sum /
    /// spec_planned_rounds` is the mean k the market actually chose —
    /// pinned at the static config's k when the market is off, sliding
    /// toward 0 on low-α traffic when it is on.
    pub spec_k_sum: AtomicU64,
    ttft: Mutex<Histogram>,
    decode_step: Mutex<Histogram>,
    e2e: Mutex<Histogram>,
    /// Executed decode-batch size per round — how well weight streaming
    /// amortizes.
    batch_occupancy: Mutex<Histogram>,
    /// Generated tokens per round. Can exceed the executed batch:
    /// final-token emissions need no decode step, and speculative decode
    /// will widen the gap further.
    tokens_per_round: Mutex<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            rounds_executed: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            reprefill_tokens: AtomicU64::new(0),
            inflight_seqs: AtomicU64::new(0),
            inflight_gen_tokens: AtomicU64::new(0),
            kv_device_bytes_in_use: AtomicU64::new(0),
            kv_device_bytes_peak: AtomicU64::new(0),
            kv_bytes_freed_by_preemption: AtomicU64::new(0),
            prefill_chunks: AtomicU64::new(0),
            prefill_chunk_tokens: AtomicU64::new(0),
            kv_prefix_shared_tokens: AtomicU64::new(0),
            kv_blocks_shared: AtomicU64::new(0),
            kv_cow_copies: AtomicU64::new(0),
            kv_dequant_rows: AtomicU64::new(0),
            pipeline_depth: AtomicU64::new(1),
            pipeline_planned_ahead_slots: AtomicU64::new(0),
            spec_proposed_tokens: AtomicU64::new(0),
            spec_accepted_tokens: AtomicU64::new(0),
            spec_planned_rounds: AtomicU64::new(0),
            spec_k_sum: AtomicU64::new(0),
            // 100 µs .. ~100 s exponential buckets.
            ttft: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
            decode_step: Mutex::new(Histogram::exponential(1e-5, 1.6, 32)),
            e2e: Mutex::new(Histogram::exponential(1e-4, 1.6, 32)),
            // Exact buckets 1..=64 (batch sizes are small integers).
            batch_occupancy: Mutex::new(Histogram::linear(1.0, 1.0, 64)),
            tokens_per_round: Mutex::new(Histogram::linear(1.0, 1.0, 64)),
        }
    }
}

impl Metrics {
    pub fn record_submit(&self) {
        self.requests_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, prefill_tokens: usize, gen_tokens: usize, ttft_s: f64, e2e_s: f64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(gen_tokens as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(prefill_tokens as u64, Ordering::Relaxed);
        self.ttft.lock().unwrap().record(ttft_s);
        self.e2e.lock().unwrap().record(e2e_s);
    }

    pub fn record_decode_step(&self, s: f64) {
        self.decode_step.lock().unwrap().record(s);
    }

    /// Record one eviction: the context it will have to re-prefill and
    /// the device bytes its released blocks freed.
    pub fn record_preemption(&self, reprefill_tokens: usize, device_bytes_freed: usize) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
        self.reprefill_tokens.fetch_add(reprefill_tokens as u64, Ordering::Relaxed);
        self.kv_bytes_freed_by_preemption.fetch_add(device_bytes_freed as u64, Ordering::Relaxed);
    }

    /// Update the in-flight gauges (engine: once per round, from
    /// [`crate::serving::Scheduler::inflight_gen`]).
    pub fn set_inflight_gen(&self, seqs: u64, gen_tokens: u64) {
        self.inflight_seqs.store(seqs, Ordering::Relaxed);
        self.inflight_gen_tokens.store(gen_tokens, Ordering::Relaxed);
    }

    /// Update the paged-KV device-memory gauges (engine: once per round,
    /// from the store's watermark).
    pub fn set_kv_device_bytes(&self, in_use: u64, peak: u64) {
        self.kv_device_bytes_in_use.store(in_use, Ordering::Relaxed);
        self.kv_device_bytes_peak.store(peak, Ordering::Relaxed);
    }

    /// Mean generation length — the signal expected-footprint admission
    /// gates on ([`crate::serving::AdmissionPolicy::Expected`]). Blends
    /// the completed mean with the in-flight generated-so-far lower
    /// bounds ([`crate::serving::blended_mean_gen`]) to correct the
    /// survivorship bias of completed-only averaging (short generations
    /// finish first, so the early completed mean under-estimates and
    /// admission over-admits during warm-up). `None` until the first
    /// completion lands (cold start admits by worst case).
    pub fn mean_gen_tokens(&self) -> Option<f64> {
        crate::serving::admission::blended_mean_gen(
            self.requests_completed.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.inflight_seqs.load(Ordering::Relaxed),
            self.inflight_gen_tokens.load(Ordering::Relaxed),
        )
    }

    /// Record one admission that attached published prefix blocks:
    /// `tokens` committed positions joined the sequence without any
    /// prefill compute.
    pub fn record_prefix_attach(&self, tokens: usize) {
        self.kv_prefix_shared_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// Update the prefix-sharing gauges (engine: once per round, from
    /// the store's arena — `blocks_shared` is Σ `refcount − 1`,
    /// `cow_copies` the arena's cumulative copy-on-write count).
    pub fn set_kv_sharing(&self, blocks_shared: u64, cow_copies: u64) {
        self.kv_blocks_shared.store(blocks_shared, Ordering::Relaxed);
        self.kv_cow_copies.store(cow_copies, Ordering::Relaxed);
    }

    /// Record one executed prefill chunk and the context positions it
    /// deposited.
    pub fn record_prefill_chunk(&self, tokens: usize) {
        self.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        self.prefill_chunk_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// Update the in-gather dequantization gauge (engine: once per
    /// round, from [`crate::kv::PagedKvStore::dequantized_rows`]).
    pub fn set_kv_dequant(&self, rows: u64) {
        self.kv_dequant_rows.store(rows, Ordering::Relaxed);
    }

    /// Record the configured pipeline depth (engine: once at startup).
    pub fn set_pipeline_depth(&self, depth: u64) {
        self.pipeline_depth.store(depth, Ordering::Relaxed);
    }

    /// Record one plan stage that ran ahead of an in-flight slot. The
    /// serial loop never calls this: at depth 1 the counter stays 0.
    pub fn record_planned_ahead(&self) {
        self.pipeline_planned_ahead_slots.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one speculative draft/verify step: proposals offered and
    /// proposals the verify pass accepted.
    pub fn record_spec(&self, proposed: u64, accepted: u64) {
        self.spec_proposed_tokens.fetch_add(proposed, Ordering::Relaxed);
        self.spec_accepted_tokens.fetch_add(accepted, Ordering::Relaxed);
    }

    /// Record one *planned* speculative step of width `k` (the draft
    /// market chose k > 0 for a sequence-round — called at step
    /// construction, whatever the verify later accepts).
    pub fn record_spec_plan(&self, k: u64) {
        self.spec_planned_rounds.fetch_add(1, Ordering::Relaxed);
        self.spec_k_sum.fetch_add(k, Ordering::Relaxed);
    }

    /// Mean planned draft width across speculative sequence-rounds;
    /// `None` until the first one is planned.
    pub fn mean_planned_k(&self) -> Option<f64> {
        let rounds = self.spec_planned_rounds.load(Ordering::Relaxed);
        if rounds == 0 {
            return None;
        }
        Some(self.spec_k_sum.load(Ordering::Relaxed) as f64 / rounds as f64)
    }

    /// Live draft-acceptance rate (accepted / proposed); `None` until the
    /// first speculative round runs.
    pub fn spec_acceptance(&self) -> Option<f64> {
        let proposed = self.spec_proposed_tokens.load(Ordering::Relaxed);
        if proposed == 0 {
            return None;
        }
        Some(self.spec_accepted_tokens.load(Ordering::Relaxed) as f64 / proposed as f64)
    }

    /// Record one executed round: decode-batch occupancy and generated
    /// tokens. **Per-round**, not at completion — `gen_tokens` is what
    /// this round emitted (final-token emissions plus speculative
    /// acceptance push it past the executed batch size), so the
    /// tokens-per-round histogram stays meaningful once rounds emit more
    /// than one token per sequence. Zero-valued samples (pure-prefill
    /// rounds, or emission-only rounds with no executed step) don't
    /// pollute either distribution.
    pub fn record_round(&self, decode_batch: usize, gen_tokens: usize) {
        self.rounds_executed.fetch_add(1, Ordering::Relaxed);
        if decode_batch > 0 {
            self.batch_occupancy.lock().unwrap().record(decode_batch as f64);
        }
        if gen_tokens > 0 {
            self.tokens_per_round.lock().unwrap().record(gen_tokens as f64);
        }
    }

    pub fn ttft_p50_p95(&self) -> (f64, f64) {
        let h = self.ttft.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn decode_step_p50_p95(&self) -> (f64, f64) {
        let h = self.decode_step.lock().unwrap();
        (h.percentile(50.0), h.percentile(95.0))
    }

    pub fn e2e_mean(&self) -> f64 {
        self.e2e.lock().unwrap().mean()
    }

    /// (mean, p50, max) decode-batch occupancy across rounds.
    pub fn batch_occupancy_summary(&self) -> (f64, f64, f64) {
        let h = self.batch_occupancy.lock().unwrap();
        (h.mean(), h.percentile(50.0), h.max())
    }

    /// Mean generated tokens per round.
    pub fn tokens_per_round_mean(&self) -> f64 {
        self.tokens_per_round.lock().unwrap().mean()
    }

    /// One-paragraph human report.
    pub fn report(&self) -> String {
        let (t50, t95) = self.ttft_p50_p95();
        let (d50, d95) = self.decode_step_p50_p95();
        let (occ_mean, occ_p50, occ_max) = self.batch_occupancy_summary();
        format!(
            "requests: {} submitted, {} completed | tokens: {} prefill, {} generated\n\
             ttft p50 {:.1} ms, p95 {:.1} ms | decode step p50 {:.2} ms, p95 {:.2} ms | e2e mean {:.1} ms\n\
             rounds: {} | batch occupancy mean {:.2}, p50 {:.0}, max {:.0} | tokens/round mean {:.2}\n\
             prefill chunks: {} ({} tokens) | \
             speculative: {} proposed, {} accepted ({}) | \
             preemptions: {} | re-prefill tokens: {} | kv device bytes: {} in use, {} peak, \
             {} freed by preemption\n\
             prefix sharing: {} tokens attached | {} blocks shared | {} cow copies\n\
             pipeline: depth {}, {} slots planned ahead | kv dequant rows: {}\n\
             draft market: {} spec steps planned, mean k {}",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            t50 * 1e3,
            t95 * 1e3,
            d50 * 1e3,
            d95 * 1e3,
            self.e2e_mean() * 1e3,
            self.rounds_executed.load(Ordering::Relaxed),
            occ_mean,
            occ_p50,
            occ_max,
            self.tokens_per_round_mean(),
            self.prefill_chunks.load(Ordering::Relaxed),
            self.prefill_chunk_tokens.load(Ordering::Relaxed),
            self.spec_proposed_tokens.load(Ordering::Relaxed),
            self.spec_accepted_tokens.load(Ordering::Relaxed),
            match self.spec_acceptance() {
                Some(a) => format!("{:.0}%", a * 100.0),
                None => "off".to_string(),
            },
            self.preemptions.load(Ordering::Relaxed),
            self.reprefill_tokens.load(Ordering::Relaxed),
            self.kv_device_bytes_in_use.load(Ordering::Relaxed),
            self.kv_device_bytes_peak.load(Ordering::Relaxed),
            self.kv_bytes_freed_by_preemption.load(Ordering::Relaxed),
            self.kv_prefix_shared_tokens.load(Ordering::Relaxed),
            self.kv_blocks_shared.load(Ordering::Relaxed),
            self.kv_cow_copies.load(Ordering::Relaxed),
            self.pipeline_depth.load(Ordering::Relaxed),
            self.pipeline_planned_ahead_slots.load(Ordering::Relaxed),
            self.kv_dequant_rows.load(Ordering::Relaxed),
            self.spec_planned_rounds.load(Ordering::Relaxed),
            match self.mean_planned_k() {
                Some(k) => format!("{k:.2}"),
                None => "-".to_string(),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_submit();
        m.record_submit();
        m.record_completion(64, 16, 0.05, 0.5);
        m.record_decode_step(0.002);
        m.record_decode_step(0.004);
        assert_eq!(m.requests_submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.tokens_generated.load(Ordering::Relaxed), 16);
        let (p50, p95) = m.decode_step_p50_p95();
        assert!(p50 > 0.0 && p95 >= p50);
        assert!(m.report().contains("requests: 2 submitted"));
    }

    #[test]
    fn preemption_and_mean_gen_tracked() {
        let m = Metrics::default();
        assert_eq!(m.mean_gen_tokens(), None, "no completions: no expectation");
        m.record_completion(64, 10, 0.05, 0.5);
        m.record_completion(64, 20, 0.05, 0.5);
        assert_eq!(m.mean_gen_tokens(), Some(15.0));
        m.record_preemption(72, 4096);
        m.record_preemption(40, 2048);
        assert_eq!(m.preemptions.load(Ordering::Relaxed), 2);
        assert_eq!(m.reprefill_tokens.load(Ordering::Relaxed), 112);
        assert_eq!(m.kv_bytes_freed_by_preemption.load(Ordering::Relaxed), 6144);
        assert!(m.report().contains("preemptions: 2"));
        assert!(m.report().contains("freed by preemption"));
    }

    #[test]
    fn mean_gen_blends_inflight_lower_bounds() {
        // Survivorship-bias regression: two short completions (mean 5)
        // while two long sequences sit in flight at 30 generated each —
        // the blended estimate must rise toward the true mean instead of
        // reporting the biased-low completed mean.
        let m = Metrics::default();
        m.record_completion(64, 5, 0.05, 0.5);
        m.record_completion(64, 5, 0.05, 0.5);
        assert_eq!(m.mean_gen_tokens(), Some(5.0));
        m.set_inflight_gen(2, 60);
        assert_eq!(m.mean_gen_tokens(), Some(17.5), "(10 + 60) / 4");
        // A wave of fresh admissions must never drag the estimate below
        // the completed mean (the blend only corrects upward).
        m.set_inflight_gen(6, 0);
        assert_eq!(m.mean_gen_tokens(), Some(5.0));
        // Cold start stays conservative even with in-flight sequences.
        let cold = Metrics::default();
        cold.set_inflight_gen(4, 8);
        assert_eq!(cold.mean_gen_tokens(), None);
    }

    #[test]
    fn kv_device_byte_gauges_tracked() {
        let m = Metrics::default();
        m.set_kv_device_bytes(1 << 20, 2 << 20);
        assert_eq!(m.kv_device_bytes_in_use.load(Ordering::Relaxed), 1 << 20);
        assert_eq!(m.kv_device_bytes_peak.load(Ordering::Relaxed), 2 << 20);
        assert!(m.report().contains("kv device bytes"));
    }

    #[test]
    fn prefix_sharing_counters_and_gauges_tracked() {
        let m = Metrics::default();
        assert!(m.report().contains("prefix sharing: 0 tokens attached"));
        m.record_prefix_attach(240);
        m.record_prefix_attach(255);
        m.set_kv_sharing(30, 4);
        assert_eq!(m.kv_prefix_shared_tokens.load(Ordering::Relaxed), 495);
        assert_eq!(m.kv_blocks_shared.load(Ordering::Relaxed), 30);
        assert_eq!(m.kv_cow_copies.load(Ordering::Relaxed), 4);
        assert!(m.report().contains("prefix sharing: 495 tokens attached"));
        assert!(m.report().contains("30 blocks shared"));
        assert!(m.report().contains("4 cow copies"));
    }

    #[test]
    fn tokens_per_round_is_recorded_per_round_not_at_completion() {
        // Regression for the speculative-decode seam: the histogram must
        // sample what each *round* emitted (pending + accepted tokens),
        // and completions must not feed it — recording `gen_tokens` at
        // completion would collapse the distribution to per-request
        // totals and make acceptance invisible.
        let m = Metrics::default();
        m.record_round(1, 3); // spec round: pending + 2 accepted
        m.record_round(1, 1); // plain round
        assert!((m.tokens_per_round_mean() - 2.0).abs() < 1e-9);
        m.record_completion(64, 40, 0.05, 0.5);
        assert!(
            (m.tokens_per_round_mean() - 2.0).abs() < 1e-9,
            "completion totals must not leak into the per-round histogram"
        );
    }

    #[test]
    fn prefill_chunk_counters_accumulate() {
        let m = Metrics::default();
        m.record_prefill_chunk(64);
        m.record_prefill_chunk(64);
        m.record_prefill_chunk(16); // a short final chunk
        assert_eq!(m.prefill_chunks.load(Ordering::Relaxed), 3);
        assert_eq!(m.prefill_chunk_tokens.load(Ordering::Relaxed), 144);
        assert!(m.report().contains("prefill chunks: 3 (144 tokens)"));
    }

    #[test]
    fn spec_counters_and_acceptance_rate() {
        let m = Metrics::default();
        assert_eq!(m.spec_acceptance(), None, "no speculative rounds yet");
        assert!(m.report().contains("speculative: 0 proposed, 0 accepted (off)"));
        m.record_spec(4, 3);
        m.record_spec(4, 1);
        assert_eq!(m.spec_proposed_tokens.load(Ordering::Relaxed), 8);
        assert_eq!(m.spec_accepted_tokens.load(Ordering::Relaxed), 4);
        assert_eq!(m.spec_acceptance(), Some(0.5));
        assert!(m.report().contains("speculative: 8 proposed, 4 accepted (50%)"));
    }

    #[test]
    fn spec_plan_counters_and_mean_k() {
        let m = Metrics::default();
        assert_eq!(m.mean_planned_k(), None, "no speculative steps planned yet");
        assert!(m.report().contains("draft market: 0 spec steps planned, mean k -"));
        m.record_spec_plan(4);
        m.record_spec_plan(2);
        m.record_spec_plan(3);
        assert_eq!(m.spec_planned_rounds.load(Ordering::Relaxed), 3);
        assert_eq!(m.spec_k_sum.load(Ordering::Relaxed), 9);
        assert_eq!(m.mean_planned_k(), Some(3.0));
        assert!(m.report().contains("draft market: 3 spec steps planned, mean k 3.00"));
        // The pinned legacy substrings survive the appended segment.
        assert!(m.report().contains("speculative: 0 proposed, 0 accepted (off)"));
    }

    #[test]
    fn pipeline_and_dequant_gauges_tracked() {
        let m = Metrics::default();
        // Defaults: the serial loop (depth 1), nothing planned ahead, no
        // quantized gathers — the state every pre-pipeline engine run
        // reports, so existing metric expectations are untouched.
        assert_eq!(m.pipeline_depth.load(Ordering::Relaxed), 1);
        assert_eq!(m.pipeline_planned_ahead_slots.load(Ordering::Relaxed), 0);
        assert_eq!(m.kv_dequant_rows.load(Ordering::Relaxed), 0);
        assert!(m.report().contains("pipeline: depth 1, 0 slots planned ahead"));
        m.set_pipeline_depth(2);
        m.record_planned_ahead();
        m.record_planned_ahead();
        m.record_planned_ahead();
        m.set_kv_dequant(4096);
        assert_eq!(m.pipeline_depth.load(Ordering::Relaxed), 2);
        assert_eq!(m.pipeline_planned_ahead_slots.load(Ordering::Relaxed), 3);
        assert!(m.report().contains("pipeline: depth 2, 3 slots planned ahead"));
        assert!(m.report().contains("kv dequant rows: 4096"));
    }

    #[test]
    fn round_occupancy_tracked_exactly() {
        let m = Metrics::default();
        m.record_round(4, 4);
        m.record_round(4, 4);
        m.record_round(2, 2);
        m.record_round(0, 0); // pure-prefill round: counted, not sampled
        assert_eq!(m.rounds_executed.load(Ordering::Relaxed), 4);
        let (mean, p50, max) = m.batch_occupancy_summary();
        assert!((mean - 10.0 / 3.0).abs() < 1e-9, "{mean}");
        assert_eq!(p50, 4.0);
        assert_eq!(max, 4.0);
        assert!((m.tokens_per_round_mean() - 10.0 / 3.0).abs() < 1e-9);
        assert!(m.report().contains("batch occupancy"));
    }
}
