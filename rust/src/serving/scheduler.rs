//! Round-based continuous-batching scheduler with decode-first stage
//! awareness.
//!
//! The engine no longer asks "what single thing should I do next" —
//! every call to [`Scheduler::next_round`] plans one **round**: *all*
//! runnable decodes packed into one batch (so weight streaming is paid
//! once per round, the §3.7 bandwidth argument applied across users)
//! plus a **prefill-chunk pack** of up to `max_prefills_per_round`
//! chunk quanta (guarding inter-token latency against prefill bursts).
//! With [`SchedulerConfig::prefill_chunk_tokens`] set, pending prefills
//! are split into fixed-token chunks dealt round-robin across
//! sequences, so one round's pack carries chunks from *multiple*
//! prompts — executed as one flattened GEMM — and a long prompt cannot
//! head-of-line-block later arrivals' TTFT; with it unset (0) each
//! sequence's whole context is a single chunk, the classic behaviour.
//!
//! Invariants (enforced + property-tested):
//! * a request is either waiting, preempted, active, or finished — never
//!   two at once;
//! * at most `max_active` sequences hold KV reservations;
//! * a round never contains more than `max_active` work items and never
//!   names a request twice;
//! * no token is generated past `max_new_tokens`;
//! * every admitted request eventually finishes (no starvation: FIFO
//!   admission, every unfinished active sequence decodes every round,
//!   and eviction is bounded — see below);
//! * admission blocked by KV-arena backpressure defers the request, it
//!   never fails it.
//!
//! **Preemption** (paged KV): when the arena cannot grow mid-round, the
//! engine evicts a victim back to a re-admission queue via
//! [`Scheduler::preempt`]; the victim re-prefills its whole context on
//! re-admission. Starvation from repeated eviction is bounded three ways:
//! * the **oldest active sequence is never a victim**
//!   ([`Scheduler::choose_victim`] skips it), so the FIFO head always
//!   runs to completion and frees its blocks;
//! * a sequence evicted `max_evictions_per_seq` times is **pinned** and
//!   not selected again — unless the head itself cannot grow, in which
//!   case pinning yields ([`Scheduler::choose_victim_ignoring_pins`])
//!   so the head's completion guarantee is unconditional;
//! * preempted sequences are re-admitted **before** the waiting queue.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::kv::{KvPool, KvSeqHandle};
use crate::serving::request::{InferenceRequest, RequestId};

/// Scheduler tuning.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (KV reservations).
    pub max_active: usize,
    /// Admit at most this many prefill **chunks** per scheduling round
    /// (guards decode latency against prefill bursts — the serving-level
    /// analogue of §3.7's stage split). With chunking off each sequence's
    /// whole context is one chunk, so this is the classic
    /// prefills-per-round cap; with chunking on it is the round's pack
    /// budget in chunk quanta (`max_prefills_per_round ×
    /// prefill_chunk_tokens` pack tokens per round).
    pub max_prefills_per_round: usize,
    /// Prefill chunk granule, in tokens. `0` disables chunking: every
    /// sequence prefills its whole context in one chunk, exactly the
    /// pre-chunking behaviour (and the bit-identical compiled-bucket
    /// path in the engine). A positive granule splits each pending
    /// prefill into fixed-token chunks so one round can pack chunks from
    /// *multiple* sequences — a long prompt then no longer
    /// head-of-line-blocks every later arrival's TTFT.
    pub prefill_chunk_tokens: usize,
    /// Evictions a sequence may suffer before it is pinned (never again
    /// selected by [`Scheduler::choose_victim`]) — the starvation bound
    /// for paged-KV preemption. 0 pins everything, disabling *policy*
    /// eviction; the FIFO-head escalation
    /// ([`Scheduler::choose_victim_ignoring_pins`]) can still evict, as
    /// the alternative to the head's progress guarantee is livelock.
    pub max_evictions_per_seq: u32,
    /// Override the engine's KV arena size, in blocks. `None` (default)
    /// sizes the arena for `max_active` worst-case sequences —
    /// preemption-free by construction, the PR-1 safety net. `Some(n)`
    /// fixes the memory budget instead, making KV the contended
    /// resource: expected-footprint admission then buys occupancy, and
    /// exhaustion degrades to preemption. Requests that could never fit
    /// the fixed arena are rejected at submission (so deferral cannot
    /// wedge).
    pub kv_arena_blocks: Option<usize>,
    /// Content-address committed prefill blocks and attach identical
    /// prefixes across sequences (refcounted, copy-on-write on
    /// divergence). On by default: with it off the engine claims every
    /// block privately — bitwise the pre-sharing behaviour.
    pub share_prefix_kv: bool,
    /// TTFT-adaptive chunk sizing: when set (and chunking is on), the
    /// engine compares live TTFT p95 against this profile target each
    /// round and shrinks the prefill granule below
    /// [`default_prefill_chunk_tokens`] while the target is missed —
    /// smaller chunks interleave more arrivals per round, trading pack
    /// efficiency for first-token latency — then grows it back toward
    /// the configured granule once p95 recovers ([`ChunkAutotuner`]).
    /// `None` (default) keeps the granule fixed.
    pub ttft_p95_target_s: Option<f64>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 1,
            prefill_chunk_tokens: 0,
            max_evictions_per_seq: 3,
            kv_arena_blocks: None,
            share_prefix_kv: true,
            ttft_p95_target_s: None,
        }
    }
}

/// Profile-aware default for [`SchedulerConfig::prefill_chunk_tokens`]
/// (DESIGN.md "Chunk sizing vs. launch overhead"): the granule must keep
/// per-chunk launch overhead amortized — `t(chunk) ≫ launch_set` — while
/// staying small enough that a long prompt cannot head-of-line-block a
/// round. Desktop-class parts dispatch cheaply (sub-µs effective launch
/// cost at the bucket sizes we compile), so 32 tokens already puts
/// overhead below 1% of chunk time; phone-class parts carry 10–100× the
/// launch cost and need 64–128-token granules to bury it. Returns the
/// granule in tokens; callers keep `0 = chunking off` semantics by only
/// consulting this when they opt into chunking.
pub fn default_prefill_chunk_tokens(profile: &crate::device::DeviceProfile) -> usize {
    match profile.class {
        crate::device::DeviceClass::Mobile => {
            // The slowest dispatchers need the largest granule to keep
            // launch overhead amortized.
            if profile.launch_overhead_us >= 100.0 {
                128
            } else {
                64
            }
        }
        crate::device::DeviceClass::Laptop | crate::device::DeviceClass::Desktop => 32,
    }
}

/// TTFT-adaptive prefill-granule policy — pure arithmetic shared by the
/// engine loops and the serving simulator so the two shrink identically.
///
/// The control problem: the profile-derived granule
/// ([`default_prefill_chunk_tokens`]) amortizes launch overhead, but
/// under an arrival burst even that granule lets each round's pack budget
/// (`max_prefills_per_round` quanta) be monopolized by few sequences —
/// later arrivals wait whole rounds for their first chunk and TTFT p95
/// blows past the profile target. Shrinking the granule cuts per-chunk
/// latency and spreads the same pack budget across more sequences.
///
/// The policy is a halving/doubling ladder with hysteresis:
/// * observed p95 **above** target → halve the granule (floored at
///   `min_chunk_tokens`, so launch overhead never exceeds the
///   amortization bound the profile floor encodes);
/// * observed p95 **under half** the target → double back toward the
///   configured `base_chunk_tokens` (never beyond it);
/// * in between → hold (the hysteresis band prevents flapping when p95
///   sits near the target).
///
/// Stateless by design: `update` maps (current granule, observed p95) to
/// the next granule, so callers own when to sample (the engine samples
/// its live [`crate::serving::Metrics`] once per round; the simulator
/// its modeled completions).
#[derive(Clone, Copy, Debug)]
pub struct ChunkAutotuner {
    /// The configured granule — the ladder's ceiling.
    pub base_chunk_tokens: usize,
    /// Profile TTFT p95 target, seconds.
    pub target_p95_s: f64,
    /// Smallest granule the ladder may reach (launch-overhead floor).
    pub min_chunk_tokens: usize,
}

impl ChunkAutotuner {
    /// Ladder over `base` with the floor at `base / 4` (clamped to ≥ 8
    /// tokens): two halvings of headroom, never below a granule where
    /// per-chunk launch overhead dominates on any profile we compile.
    pub fn new(base_chunk_tokens: usize, target_p95_s: f64) -> ChunkAutotuner {
        ChunkAutotuner {
            base_chunk_tokens,
            target_p95_s,
            min_chunk_tokens: (base_chunk_tokens / 4).max(8).min(base_chunk_tokens.max(1)),
        }
    }

    /// Next granule given the current one and the observed TTFT p95.
    /// With chunking off (`base == 0`) the tuner is inert.
    pub fn update(&self, current_chunk_tokens: usize, observed_p95_s: f64) -> usize {
        if self.base_chunk_tokens == 0 || self.target_p95_s <= 0.0 {
            return current_chunk_tokens;
        }
        let cur = current_chunk_tokens.clamp(self.min_chunk_tokens, self.base_chunk_tokens);
        if observed_p95_s > self.target_p95_s {
            (cur / 2).max(self.min_chunk_tokens)
        } else if observed_p95_s < 0.5 * self.target_p95_s {
            (cur * 2).min(self.base_chunk_tokens)
        } else {
            cur
        }
    }
}

/// One active sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub request: InferenceRequest,
    pub generated: Vec<i32>,
    /// Next position to decode at (prompt length + generated so far).
    pub pos: usize,
    pub prefill_done: bool,
    /// Context positions whose KV chunked prefill has already committed
    /// (`0 ≤ prefill_progress ≤ context_len()`). The next chunk starts
    /// here; eviction resets it to 0 — a preempted sequence re-prefills
    /// from token 0, and the positions billed as re-prefill recompute are
    /// exactly what this counter had reached.
    pub prefill_progress: usize,
    /// Times this sequence has been evicted (paged-KV preemption).
    pub evictions: u32,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.prefill_done && self.generated.len() >= self.request.max_new_tokens
    }

    /// Token positions prefill must cover for this sequence *now*:
    /// the prompt plus everything generated before a preemption (the
    /// re-prefill recomputes those KV rows; logits over this context
    /// reproduce the next token exactly, so eviction costs work, never
    /// correctness).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated.len()
    }
}

/// One sequence's slice of a round's **prefill pack**: `len` context
/// positions starting at `start`, for request `id`. The executor runs
/// the whole pack as one flattened `(Σ len, d_model)` GEMM
/// ([`crate::runtime::packed_prefill_round`]); each chunk's rows scatter
/// into its own sequence's paged block table, and only the **final**
/// chunk (`last`) produces logits — the sequence's first token exists
/// only after it, which is what per-chunk TTFT attribution keys on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefillChunk {
    pub id: RequestId,
    /// First context position this chunk covers
    /// (== the sequence's committed `prefill_progress`).
    pub start: usize,
    /// Context positions in this chunk (≥ 1, except the degenerate
    /// empty-context chunk, which exists only so the executor can
    /// resolve an empty prefill instead of stranding it).
    pub len: usize,
    /// Final chunk: `start + len == context_len()`; its last-position
    /// logits produce the sequence's first token.
    pub last: bool,
}

impl PrefillChunk {
    /// Context length after this chunk executes.
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// One scheduling round: the prefill-chunk pack to run and the decode
/// batch to execute as a single batched step. Decode runs *first* when
/// the engine executes the round (decode-first latency protection).
///
/// With chunking off ([`SchedulerConfig::prefill_chunk_tokens`] = 0)
/// every entry of `prefills` covers its sequence's whole context in one
/// `last` chunk — the classic one-prefill-per-sequence round. A round
/// never carries two chunks for the same sequence (contiguous quanta
/// merge), so the no-request-named-twice invariant is unchanged.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Round {
    /// Prefill chunks to run this round (≤ `max_prefills_per_round`
    /// chunk quanta in total), at most one chunk per sequence.
    pub prefills: Vec<PrefillChunk>,
    /// Every active, prefilled, unfinished sequence: one decode step each,
    /// batched so the weights stream once.
    pub decode_batch: Vec<RequestId>,
}

impl Round {
    /// Nothing runnable this round.
    pub fn is_idle(&self) -> bool {
        self.prefills.is_empty() && self.decode_batch.is_empty()
    }

    /// Decode batch size (the occupancy metric).
    pub fn batch_size(&self) -> usize {
        self.decode_batch.len()
    }

    /// Total work items planned.
    pub fn work_items(&self) -> usize {
        self.prefills.len() + self.decode_batch.len()
    }

    /// Sequences named by the prefill pack, in pack order.
    pub fn prefill_ids(&self) -> Vec<RequestId> {
        self.prefills.iter().map(|c| c.id).collect()
    }

    /// Context positions the prefill pack covers (the packed GEMM's
    /// flattened row count).
    pub fn prefill_tokens(&self) -> usize {
        self.prefills.iter().map(|c| c.len).sum()
    }

    /// Per-round model selection for a fleet round: partition the decode
    /// batch by the model that serves each sequence. Sequences bound to
    /// draft `i` (and bidding k > 0 this round) batch together — the
    /// draft's weights stream once for the whole group — while everything
    /// else (no draft bound, or the market bid k = 0) decodes plainly on
    /// the target. `num_drafts` fixes the group count so indices stay
    /// aligned with the registry; an assignment outside that range falls
    /// back to the plain batch rather than panicking mid-round.
    pub fn partition_by_model(
        &self,
        num_drafts: usize,
        assign: impl Fn(RequestId) -> Option<usize>,
    ) -> (Vec<RequestId>, Vec<Vec<RequestId>>) {
        let mut plain = Vec::new();
        let mut groups: Vec<Vec<RequestId>> = vec![Vec::new(); num_drafts];
        for &id in &self.decode_batch {
            match assign(id) {
                Some(i) if i < num_drafts => groups[i].push(id),
                _ => plain.push(id),
            }
        }
        (plain, groups)
    }
}

/// The scheduler: owns waiting queue + preempted queue + active set.
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    waiting: VecDeque<InferenceRequest>,
    /// Evicted sequences awaiting re-admission (drained before `waiting`
    /// so eviction degrades to queueing latency, not starvation).
    preempted: VecDeque<SeqState>,
    active: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, ..Default::default() }
    }

    pub fn submit(&mut self, req: InferenceRequest) {
        self.waiting.push_back(req);
    }

    /// Current prefill granule (0 = chunking off).
    pub fn prefill_chunk_tokens(&self) -> usize {
        self.cfg.prefill_chunk_tokens
    }

    /// Retune the prefill granule mid-stream ([`ChunkAutotuner`]). Safe
    /// at any round boundary: chunk starts are derived from each
    /// sequence's committed `prefill_progress`, not from a precomputed
    /// chunk list, so in-flight sequences simply take differently-sized
    /// next chunks — no invariant depends on the granule being constant
    /// over a sequence's lifetime.
    pub fn set_prefill_chunk_tokens(&mut self, tokens: usize) {
        self.cfg.prefill_chunk_tokens = tokens;
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn preempted_len(&self) -> usize {
        self.preempted.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn seq(&self, id: RequestId) -> Option<&SeqState> {
        self.active.iter().find(|s| s.request.id == id)
    }

    pub fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqState> {
        self.active.iter_mut().find(|s| s.request.id == id)
    }

    /// Admission at round start: pull preempted, then waiting, requests
    /// into free slots in FIFO order (continuous batching: join
    /// mid-stream).
    pub fn admit(&mut self) {
        self.admit_where(|_, _| true);
    }

    /// Admission with an external gate: `can_admit(request,
    /// context_tokens)` is called once per candidate in FIFO order and
    /// may claim resources (KV arena blocks) as a side effect.
    /// `context_tokens` is what prefill must cover on admission — the
    /// prompt for a fresh request, prompt + generated-so-far for a
    /// re-admitted preempted sequence (paged admission claims exactly
    /// this and grows during decode). Preempted sequences drain first.
    /// Admission stops at the first rejected candidate rather than
    /// skipping past it — skipping would starve large requests behind a
    /// stream of small ones. A rejection is *backpressure*: the request
    /// stays queued and is retried next round.
    pub fn admit_where(&mut self, mut can_admit: impl FnMut(&InferenceRequest, usize) -> bool) {
        // Like the prefill cap, a limit of 0 would strand the waiting
        // queue forever (nothing admitted ⇒ nothing ever finishes):
        // clamp to at least one concurrent sequence.
        let max_active = self.cfg.max_active.max(1);
        while self.active.len() < max_active {
            if let Some(s) = self.preempted.front() {
                if !can_admit(&s.request, s.context_len()) {
                    return;
                }
                let s = self.preempted.pop_front().expect("front observed above");
                self.active.push(s);
                continue;
            }
            let Some(req) = self.waiting.front() else { break };
            if !can_admit(req, req.prompt.len()) {
                break;
            }
            let req = self.waiting.pop_front().expect("front observed above");
            let pos = req.prompt.len();
            self.active.push(SeqState {
                request: req,
                generated: Vec::new(),
                pos,
                prefill_done: false,
                prefill_progress: 0,
                evictions: 0,
            });
        }
    }

    /// Evict an active sequence back to the re-admission queue (paged-KV
    /// preemption). The caller releases the sequence's arena blocks; the
    /// scheduler marks it un-prefilled so re-admission re-prefills its
    /// whole context ([`SeqState::context_len`]) — recompute semantics,
    /// no state is lost. Returns the re-prefill bill: the token positions
    /// whose KV must be *recomputed* — the context length for a prefilled
    /// sequence, the chunks already committed
    /// ([`SeqState::prefill_progress`]) for one evicted mid-prefill, and
    /// 0 for one evicted before any chunk ran (nothing is wasted then).
    /// A chunked sequence re-prefills **from token 0** on re-admission
    /// (its blocks were scrubbed and released with the handle), so the
    /// progress counter resets here. `None` if `id` isn't active.
    pub fn preempt(&mut self, id: RequestId) -> Option<usize> {
        let i = self.active.iter().position(|s| s.request.id == id)?;
        let mut s = self.active.remove(i);
        let bill = if s.prefill_done { s.context_len() } else { s.prefill_progress };
        s.prefill_done = false;
        s.prefill_progress = 0;
        s.evictions += 1;
        self.preempted.push_back(s);
        Some(bill)
    }

    /// Victim for eviction when the arena cannot grow: the
    /// lowest-progress (fewest generated tokens), youngest sequence.
    /// Never the oldest active sequence — the FIFO head keeps an
    /// eviction-immune claim, so it always runs to completion and frees
    /// its blocks (this is what bounds thrash: serialized to one
    /// sequence, the system degenerates to single-stream serving, never
    /// livelock). Sequences already evicted `max_evictions_per_seq`
    /// times are pinned and skipped.
    pub fn choose_victim(&self) -> Option<RequestId> {
        self.victim(false)
    }

    /// Escalation for when the **FIFO head itself** cannot grow and
    /// [`choose_victim`](Self::choose_victim) came up empty: pinning
    /// yields to the head's progress guarantee (any non-head sequence may
    /// be evicted). Without this, an arena exhausted entirely by pinned
    /// sequences would stall the head forever — with it, serialization to
    /// single-stream serving is the worst case, never livelock.
    pub fn choose_victim_ignoring_pins(&self) -> Option<RequestId> {
        self.victim(true)
    }

    /// Oldest active sequence (the eviction-immune FIFO head), if any.
    pub fn head(&self) -> Option<RequestId> {
        self.active.first().map(|s| s.request.id)
    }

    fn victim(&self, ignore_pins: bool) -> Option<RequestId> {
        // "Youngest" = most recently admitted = highest index in
        // `active` (admission order). Request ids are caller-assigned
        // and say nothing about age.
        self.active
            .iter()
            .enumerate()
            .skip(1) // FIFO head is immune
            .filter(|(_, s)| ignore_pins || s.evictions < self.cfg.max_evictions_per_seq)
            .min_by_key(|&(i, s)| (s.generated.len(), std::cmp::Reverse(i)))
            .map(|(_, s)| s.request.id)
    }

    /// Make room for `rows` more KV rows for every `(id, rows)` in
    /// `needs_rows`, evicting victims when the KV pool cannot grow — the
    /// one growth/preemption loop both the engine and the serving
    /// simulator run, so their policies can never diverge. Generic over
    /// [`KvPool`]: the simulator passes the accounting
    /// [`crate::kv::KvArena`], the engine the device-backed
    /// [`crate::kv::PagedKvStore`] — so in the engine an eviction here
    /// releases (and scrubs) real region bytes.
    ///
    /// Plain decode needs one row per sequence; a **speculative**
    /// sequence needs `k + 1` (the round's provisional draft/verify
    /// scatter — rejected rows are scrubbed after acceptance, but the
    /// blocks must exist before any state advances).
    ///
    /// For each entry in order: [`KvPool::ensure`]`(h, rows)`; on
    /// exhaustion, evict [`choose_victim`](Self::choose_victim)
    /// (escalating past pins only when the FIFO head itself is the one
    /// growing), release the victim's blocks, call `on_evict(victim,
    /// reprefill_bill, device_bytes_freed)` so the caller can park its
    /// runtime state and record metrics, and retry. If no victim exists —
    /// or the grower evicted itself — the sequence is **held out**.
    ///
    /// Returns the held-out set: every evicted victim plus every
    /// capacity-starved grower. Held-out sequences must sit the whole
    /// round out (no emission, no step, no prefill) — an evicted victim
    /// may still be named in the already-planned round.
    pub fn ensure_round_capacity<K: KvPool>(
        &mut self,
        kv: &mut K,
        handles: &mut HashMap<RequestId, KvSeqHandle>,
        needs_rows: &[(RequestId, usize)],
        mut on_evict: impl FnMut(RequestId, usize, usize),
    ) -> HashSet<RequestId> {
        let mut held_out = HashSet::new();
        for &(id, rows) in needs_rows {
            if held_out.contains(&id) {
                continue; // evicted by an earlier member's growth
            }
            let h = handles[&id];
            loop {
                match kv.ensure(h, rows) {
                    Ok(_) => break,
                    Err(_) => {
                        // Pinning yields when the FIFO head itself needs
                        // the blocks — the head's progress guarantee is
                        // what bounds thrash, so it outranks pins.
                        let victim = self.choose_victim().or_else(|| {
                            (self.head() == Some(id))
                                .then(|| self.choose_victim_ignoring_pins())
                                .flatten()
                        });
                        let Some(victim) = victim else {
                            // Nobody evictable: sit this round out; the
                            // head keeps progressing and frees blocks.
                            held_out.insert(id);
                            break;
                        };
                        let bill = self.preempt(victim).expect("victim is active");
                        let mut freed = 0;
                        if let Some(vh) = handles.remove(&victim) {
                            freed = kv.release(vh);
                        }
                        on_evict(victim, bill, freed);
                        held_out.insert(victim);
                        if victim == id {
                            break; // evicted itself: no step this round
                        }
                    }
                }
            }
        }
        held_out
    }

    /// `(sequences, generated-so-far tokens)` across active **and**
    /// preempted sequences. Each in-flight count is a per-sequence lower
    /// bound on its final generation length — the signal the blended
    /// admission estimator
    /// ([`crate::serving::admission::blended_mean_gen`]) folds in to
    /// correct the survivorship bias of completed-only means (short
    /// generations finish first, so the early completed mean is biased
    /// low and admission over-admits exactly during warm-up).
    pub fn inflight_gen(&self) -> (u64, u64) {
        let mut seqs = 0u64;
        let mut tokens = 0u64;
        for s in self.active.iter().chain(self.preempted.iter()) {
            seqs += 1;
            tokens += s.generated.len() as u64;
        }
        (seqs, tokens)
    }

    /// Per-sequence generated-so-far counts across active **and**
    /// preempted sequences — the sample form of
    /// [`inflight_gen`](Self::inflight_gen), for quantile-based
    /// admission estimators
    /// ([`crate::sim::GenLenEstimator::P90`]) that need the
    /// distribution, not just the pooled mean.
    pub fn inflight_gen_lens(&self) -> Vec<usize> {
        self.active
            .iter()
            .chain(self.preempted.iter())
            .map(|s| s.generated.len())
            .collect()
    }

    /// Plan the next round: every decodable sequence joins the decode
    /// batch, and up to `max_prefills_per_round` prefill-chunk quanta are
    /// packed from the admitted-but-unprefilled sequences.
    ///
    /// **Unchunked** (`prefill_chunk_tokens == 0`): each of the first
    /// `max_prefills_per_round` unprefilled sequences (admission order,
    /// so prefill order follows FIFO and nobody is starved) gets one
    /// whole-context chunk — the classic behaviour.
    ///
    /// **Chunked**: chunk quanta are dealt **round-robin** in admission
    /// order — one `prefill_chunk_tokens` quantum per pending sequence
    /// per pass, repeating while budget remains — so a long prompt
    /// cannot head-of-line-block later arrivals' TTFT, yet a lone long
    /// prompt still absorbs the whole budget (no throughput lost to
    /// fairness when there is nobody to be fair to). A sequence's quanta
    /// within one round are contiguous and merge into a single chunk.
    pub fn next_round(&self) -> Round {
        // A cap of 0 would strand admitted sequences forever (admitted but
        // never prefilled ⇒ never decodable ⇒ livelock): always allow at
        // least one prefill quantum per round.
        let prefill_cap = self.cfg.max_prefills_per_round.max(1);
        let chunk = self.cfg.prefill_chunk_tokens;
        let mut round = Round::default();
        let mut pending: Vec<(RequestId, usize, usize)> = Vec::new(); // (id, progress, ctx)
        for s in &self.active {
            if !s.prefill_done {
                pending.push((s.request.id, s.prefill_progress, s.context_len()));
            } else if !s.finished() {
                round.decode_batch.push(s.request.id);
            }
        }
        if chunk == 0 {
            // Whole-context chunks, capped per round.
            for &(id, progress, ctx) in pending.iter().take(prefill_cap) {
                round
                    .prefills
                    .push(PrefillChunk { id, start: progress, len: ctx - progress, last: true });
            }
            return round;
        }
        // Round-robin quanta; `granted[i]` accumulates tokens for
        // pending[i] this round.
        let mut granted = vec![0usize; pending.len()];
        let mut budget = prefill_cap;
        while budget > 0 {
            let mut dealt = false;
            for (i, &(_, progress, ctx)) in pending.iter().enumerate() {
                if budget == 0 {
                    break;
                }
                let remaining = ctx - progress - granted[i];
                if remaining == 0 {
                    continue;
                }
                granted[i] += remaining.min(chunk);
                budget -= 1;
                dealt = true;
            }
            if !dealt {
                break; // every pending sequence fully covered this round
            }
        }
        for (i, &(id, progress, ctx)) in pending.iter().enumerate() {
            // `progress == ctx` is the degenerate empty-context case: no
            // quantum is ever granted, so emit an explicit zero-length
            // final chunk instead of stranding the sequence unprefilled
            // forever (the executor resolves it exactly like the legacy
            // empty-prefill path did).
            if granted[i] > 0 || progress == ctx {
                round.prefills.push(PrefillChunk {
                    id,
                    start: progress,
                    len: granted[i],
                    last: progress + granted[i] == ctx,
                });
            }
        }
        round
    }

    /// Remove and return finished sequences.
    pub fn reap_finished(&mut self) -> Vec<SeqState> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.preempted.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{KvArena, KvArenaConfig};
    use crate::util::propcheck::{check, Config};

    fn req(id: u64, prompt_len: usize, gen: usize) -> InferenceRequest {
        InferenceRequest::new(id, vec![1; prompt_len], gen)
    }

    #[test]
    fn partition_by_model_covers_batch_exactly_once() {
        let round = Round {
            prefills: Vec::new(),
            decode_batch: vec![1, 2, 3, 4, 5],
        };
        // 1, 4 → draft 0; 3 → draft 1; 2 unbound; 5 assigned out of range.
        let (plain, groups) = round.partition_by_model(2, |id| match id {
            1 | 4 => Some(0),
            3 => Some(1),
            5 => Some(7),
            _ => None,
        });
        assert_eq!(plain, vec![2, 5]);
        assert_eq!(groups, vec![vec![1, 4], vec![3]]);
        let total: usize = plain.len() + groups.iter().map(Vec::len).sum::<usize>();
        assert_eq!(total, round.batch_size(), "every sequence lands in exactly one group");
        // Zero drafts degrades to the single-model round.
        let (plain, groups) = round.partition_by_model(0, |_| Some(0));
        assert_eq!(plain, vec![1, 2, 3, 4, 5]);
        assert!(groups.is_empty());
    }

    /// Execute one planned round against the scheduler state, the way the
    /// engine does: decode batch first, then the prefill-chunk pack.
    fn execute_round(s: &mut Scheduler, round: &Round) {
        for &id in &round.decode_batch {
            let seq = s.seq_mut(id).unwrap();
            assert!(
                seq.generated.len() < seq.request.max_new_tokens,
                "seq {id} decoded past its budget"
            );
            seq.generated.push(0);
            seq.pos += 1;
        }
        for c in &round.prefills {
            let seq = s.seq_mut(c.id).unwrap();
            assert_eq!(
                c.start, seq.prefill_progress,
                "chunk must resume at the committed progress: {c:?}"
            );
            seq.prefill_progress += c.len;
            assert!(seq.prefill_progress <= seq.context_len(), "{c:?}");
            if c.last {
                assert_eq!(seq.prefill_progress, seq.context_len(), "{c:?}");
                seq.prefill_done = true;
            }
        }
    }

    #[test]
    fn admits_up_to_max_active() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        for i in 0..5 {
            s.submit(req(i, 16, 4));
        }
        s.admit();
        assert_eq!(s.active_len(), 2);
        assert_eq!(s.waiting_len(), 3);
    }

    #[test]
    fn prefill_before_decode_per_sequence() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(1, 16, 2));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefill_ids(), vec![1]);
        assert_eq!(r.prefills, vec![PrefillChunk { id: 1, start: 0, len: 16, last: true }]);
        assert!(r.decode_batch.is_empty(), "no decode before prefill: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        assert_eq!(r.decode_batch, vec![1]);
        assert!(r.prefills.is_empty());
    }

    #[test]
    fn decode_batch_packs_all_runnable_sequences() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 4,
            ..Default::default()
        });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // all four prefill
        let r = s.next_round();
        assert_eq!(r.batch_size(), 4, "all decodes batch into one round: {r:?}");
        assert_eq!(r.decode_batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prefills_capped_per_round_decodes_are_not() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 1,
            ..Default::default()
        });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        // Four rounds of capped prefill; decode batch grows behind it.
        for expect_batch in 0..4usize {
            let r = s.next_round();
            assert_eq!(r.prefills.len(), 1, "{r:?}");
            assert_eq!(r.batch_size(), expect_batch, "{r:?}");
            execute_round(&mut s, &r);
        }
        let r = s.next_round();
        assert!(r.prefills.is_empty());
        assert_eq!(r.batch_size(), 4);
    }

    #[test]
    fn zero_max_active_still_makes_progress() {
        // Regression: a (mis)configured max_active of 0 must not leave the
        // waiting queue stranded (the engine would busy-spin forever).
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 0,
            max_prefills_per_round: 1,
            ..Default::default()
        });
        s.submit(req(1, 8, 1));
        s.admit();
        assert_eq!(s.active_len(), 1, "clamped to one concurrent sequence");
        let r = s.next_round();
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn zero_prefill_cap_still_makes_progress() {
        // Regression: a (mis)configured cap of 0 must not strand admitted
        // sequences in the never-prefilled state forever.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 0,
            ..Default::default()
        });
        s.submit(req(1, 8, 1));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefill_ids(), vec![1], "at least one prefill per round: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn finished_sequences_reaped() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(7, 8, 1));
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // decode the single token
        let done = s.reap_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 7);
        assert!(s.is_idle());
    }

    #[test]
    fn full_arena_defers_admission_instead_of_erroring() {
        // Regression: a request that does not fit the arena *now* stays
        // waiting and is admitted after capacity frees up.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 4,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 2,
            heads_kv: 2,
            head_dim: 32,
            block_tokens: 16,
            num_blocks: 4, // 64 tokens total
        });
        s.submit(req(0, 32, 16)); // 48 tokens → 3 blocks
        s.submit(req(1, 32, 16)); // would need 3 more → must wait
        let mut handles = std::collections::HashMap::new();
        s.admit_where(|r, _ctx| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "second request deferred, not failed");
        assert_eq!(s.waiting_len(), 1);

        // Drive request 0 to completion; its release unblocks request 1.
        while s.seq(0).is_some() {
            let r = s.next_round();
            execute_round(&mut s, &r);
            for done in s.reap_finished() {
                arena.release(handles[&done.request.id]);
            }
        }
        s.admit_where(|r, _ctx| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "freed capacity admits the deferred request");
        assert_eq!(s.waiting_len(), 0);
        arena.verify().unwrap();
    }

    #[test]
    fn preempt_requeues_and_readmits_before_waiting() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        s.submit(req(0, 8, 4));
        s.submit(req(1, 8, 4));
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // both decode one token
        let ctx = s.preempt(1).expect("active sequence evicts");
        assert_eq!(ctx, 9, "re-prefill bill = prompt 8 + 1 generated");
        assert_eq!(s.active_len(), 1);
        assert_eq!(s.preempted_len(), 1);
        assert!(!s.is_idle());
        assert!(s.preempt(1).is_none(), "already evicted: no-op");

        // A later submission must NOT jump ahead of the evicted sequence.
        s.submit(req(2, 8, 4));
        s.admit();
        assert!(s.seq(1).is_some(), "preempted sequence re-admitted first");
        assert!(s.seq(2).is_none(), "fresh request waits behind it");
        let seq1 = s.seq(1).unwrap();
        assert!(!seq1.prefill_done, "re-admission re-prefills the context");
        assert_eq!(seq1.generated.len(), 1, "generated tokens survive eviction");
        assert_eq!(seq1.evictions, 1);
        // It shows up as a prefill, then rejoins the decode batch.
        let r = s.next_round();
        assert!(r.prefill_ids().contains(&1), "{r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        assert!(r.decode_batch.contains(&1), "{r:?}");
    }

    #[test]
    fn chunked_prefill_packs_chunks_from_multiple_sequences() {
        // Round-robin quanta: a 64-token prompt and a 16-token prompt
        // share one round's pack — the short one *completes* its prefill
        // in the same round the long one makes partial progress, which is
        // the whole TTFT point of chunking.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 4,
            prefill_chunk_tokens: 16,
            ..Default::default()
        });
        s.submit(req(0, 64, 4));
        s.submit(req(1, 16, 4));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefill_tokens(), 4 * 16, "budget = cap × chunk quanta");
        assert_eq!(
            r.prefills,
            vec![
                // Pass 1 gives each sequence one quantum; passes 2–3 give
                // the long prompt two more (merged into one chunk).
                PrefillChunk { id: 0, start: 0, len: 48, last: false },
                PrefillChunk { id: 1, start: 0, len: 16, last: true },
            ]
        );
        execute_round(&mut s, &r);
        assert!(s.seq(1).unwrap().prefill_done, "short prompt done in round 1");
        assert_eq!(s.seq(0).unwrap().prefill_progress, 48);
        // Next round: the long prompt's final chunk, and the short one
        // decodes alongside it.
        let r = s.next_round();
        assert_eq!(
            r.prefills,
            vec![PrefillChunk { id: 0, start: 48, len: 16, last: true }]
        );
        assert_eq!(r.decode_batch, vec![1]);
        execute_round(&mut s, &r);
        assert!(s.seq(0).unwrap().prefill_done);
    }

    #[test]
    fn chunked_prefill_does_not_let_a_long_prompt_block_later_arrivals() {
        // The HOL shape: a long prompt at the FIFO head, short prompts
        // behind it. Unchunked with cap 1, the shorts wait one full
        // prefill round each behind the long; chunked, every short
        // completes its prefill within the first rounds while the long
        // streams its chunks alongside.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 3,
            prefill_chunk_tokens: 16,
            ..Default::default()
        });
        s.submit(req(0, 128, 4)); // the blocker
        s.submit(req(1, 16, 4));
        s.submit(req(2, 16, 4));
        s.admit();
        let r = s.next_round();
        // One quantum each: both shorts finish in round 1.
        assert_eq!(
            r.prefills,
            vec![
                PrefillChunk { id: 0, start: 0, len: 16, last: false },
                PrefillChunk { id: 1, start: 0, len: 16, last: true },
                PrefillChunk { id: 2, start: 0, len: 16, last: true },
            ]
        );
        execute_round(&mut s, &r);
        assert!(s.seq(1).unwrap().prefill_done && s.seq(2).unwrap().prefill_done);
        // The long prompt now absorbs the whole budget per round.
        let r = s.next_round();
        assert_eq!(
            r.prefills,
            vec![PrefillChunk { id: 0, start: 16, len: 48, last: false }]
        );
        assert_eq!(r.decode_batch, vec![1, 2], "shorts decode while the long prefills");
    }

    #[test]
    fn chunk_preemption_bills_committed_progress_and_restarts_from_zero() {
        // A sequence evicted *between chunks* has committed KV for
        // exactly `prefill_progress` positions — that is the re-prefill
        // bill — and its next chunk after re-admission starts at token 0
        // (the blocks were scrubbed with the handle).
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            prefill_chunk_tokens: 16,
            ..Default::default()
        });
        s.submit(req(0, 64, 4));
        s.admit();
        let r = s.next_round();
        assert_eq!(
            r.prefills,
            vec![PrefillChunk { id: 0, start: 0, len: 32, last: false }]
        );
        execute_round(&mut s, &r);
        assert_eq!(s.seq(0).unwrap().prefill_progress, 32);
        let bill = s.preempt(0).expect("active sequence evicts");
        assert_eq!(bill, 32, "mid-prefill eviction bills the committed chunks only");
        s.admit(); // re-admit from the preempted queue
        let seq = s.seq(0).unwrap();
        assert!(!seq.prefill_done);
        assert_eq!(seq.prefill_progress, 0, "re-prefill restarts from token 0");
        let r = s.next_round();
        assert_eq!(
            r.prefills,
            vec![PrefillChunk { id: 0, start: 0, len: 32, last: false }]
        );
        // An eviction before ANY chunk ran still bills nothing.
        let mut s2 = Scheduler::new(SchedulerConfig {
            prefill_chunk_tokens: 16,
            ..Default::default()
        });
        s2.submit(req(7, 64, 4));
        s2.admit();
        assert_eq!(s2.preempt(7), Some(0), "no committed chunks, no recompute bill");
    }

    #[test]
    fn victim_selection_skips_head_and_pinned() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 3,
            max_prefills_per_round: 3,
            max_evictions_per_seq: 1,
            ..Default::default()
        });
        for i in 0..3 {
            s.submit(req(i, 8, 8));
        }
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // all prefill
        // Give seq 1 more progress than seq 2.
        s.seq_mut(1).unwrap().generated.push(0);
        s.seq_mut(1).unwrap().generated.push(0);
        // Victim: lowest progress among non-head → seq 2 (0 tokens).
        assert_eq!(s.choose_victim(), Some(2));
        s.preempt(2).unwrap();
        // Next victim: seq 1 (head seq 0 is immune).
        assert_eq!(s.choose_victim(), Some(1));
        s.preempt(1).unwrap();
        // Only the head remains: nobody to evict.
        assert_eq!(s.choose_victim(), None);
        s.admit(); // re-admit 2 then 1 (FIFO over the preempted queue)
        assert_eq!(s.active_len(), 3);
        // Both re-admitted sequences are now pinned (max_evictions 1):
        // victim selection must come up empty, not starve them again.
        assert_eq!(s.choose_victim(), None, "pinned sequences are immune");
        // ... except to the head's escalation: if the head itself cannot
        // grow, pins yield (lowest-progress, youngest first) so the head
        // always completes — serialization, never livelock.
        assert_eq!(s.head(), Some(0));
        assert_eq!(s.choose_victim_ignoring_pins(), Some(2));
    }

    #[test]
    fn growth_can_evict_a_same_round_prefill_candidate() {
        // Regression for the round-planning race: a fresh admission has
        // zero progress, making it the *preferred* victim — yet it can
        // already be named in the same round's prefill list. The
        // held-out set returned by `ensure_round_capacity` must cover
        // it, so the round executor skips its prefill instead of
        // panicking on a sequence that is no longer active.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 3,
        });
        let mut handles = std::collections::HashMap::new();
        s.submit(req(0, 16, 64));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        let r = s.next_round();
        assert_eq!(r.prefill_ids(), vec![0]);
        execute_round(&mut s, &r);
        arena.append(handles[&0], 16).unwrap(); // prefill wrote the prompt

        s.submit(req(1, 32, 8));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        assert_eq!(s.active_len(), 2);
        assert_eq!(arena.blocks_free(), 0);

        // This round decodes seq 0 (which must grow) and plans seq 1's
        // prefill — but seq 0's growth can only succeed by evicting 1.
        let round = s.next_round();
        assert_eq!(round.decode_batch, vec![0]);
        assert_eq!(round.prefill_ids(), vec![1]);
        let needs: Vec<(RequestId, usize)> =
            round.decode_batch.iter().map(|&id| (id, 1)).collect();
        let mut evicted = Vec::new();
        let held_out = s.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &needs,
            |v, bill, freed| {
                evicted.push((v, bill));
                assert!(freed > 0, "evicting a claimed sequence must free bytes");
            },
        );
        assert_eq!(evicted, vec![(1, 0)], "unprefilled victim bills no recompute");
        assert!(held_out.contains(&1), "held-out must cover the planned prefill");
        assert!(s.seq(1).is_none(), "victim left the active set");
        assert_eq!(s.preempted_len(), 1, "victim awaits re-admission");
        assert!(!handles.contains_key(&1), "victim handle released");
        // Seq 0 got its block: the KV-row append cannot overflow now.
        arena.append(handles[&0], 1).unwrap();
        arena.verify().unwrap();
    }

    #[test]
    fn speculative_multi_row_growth_follows_the_same_eviction_policy() {
        // A speculative sequence needs k+1 provisional rows before the
        // round runs; exhaustion mid-growth must pick the same victims
        // as plain single-row growth (policy shared, not duplicated).
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 3,
        });
        let mut handles = std::collections::HashMap::new();
        s.submit(req(0, 16, 64));
        s.submit(req(1, 32, 8));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        arena.append(handles[&0], 16).unwrap();
        arena.append(handles[&1], 32).unwrap();
        assert_eq!(arena.blocks_free(), 0);

        // Seq 0 speculates with k = 4 ⇒ needs 5 rows; only evicting seq 1
        // (2 blocks) makes room.
        let mut evicted = Vec::new();
        let held_out = s.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &[(0, 5)],
            |v, bill, _freed| evicted.push((v, bill)),
        );
        assert_eq!(evicted, vec![(1, 32)], "victim bills its prefilled context");
        assert!(held_out.contains(&1));
        assert!(!held_out.contains(&0), "the grower got its rows");
        arena.append(handles[&0], 5).unwrap();
        arena.verify().unwrap();
    }

    #[test]
    fn inflight_gen_counts_active_and_preempted() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        s.submit(req(0, 8, 4));
        s.submit(req(1, 8, 4));
        assert_eq!(s.inflight_gen(), (0, 0), "waiting requests are not in flight");
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // both decode one token
        assert_eq!(s.inflight_gen(), (2, 2));
        s.preempt(1).unwrap();
        // Eviction must not erase a sequence's lower bound — that would
        // re-bias the estimator exactly when preemptions spike.
        assert_eq!(s.inflight_gen(), (2, 2), "preempted sequences still count");
    }

    #[test]
    fn property_no_starvation_under_preemption() {
        // Random decode/preempt interleavings: every request still
        // finishes (the head-immunity + pinning + readmit-first rules
        // bound eviction), and generated counts never regress.
        check("preemption starves nobody", Config::cases(40), |rng| {
            let n = 2 + rng.gen_range(8) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active: 2 + rng.gen_range(3) as usize,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                max_evictions_per_seq: rng.gen_range(3) as u32,
                ..Default::default()
            });
            for i in 0..n {
                s.submit(req(i as u64, 4, 1 + rng.gen_range(6) as usize));
            }
            let mut finished = 0usize;
            let mut rounds = 0usize;
            while finished < n {
                s.admit();
                // Adversarial arena stand-in: evict the policy's victim
                // with probability 1/3 before executing the round.
                if rng.gen_range(3) == 0 {
                    if let Some(v) = s.choose_victim() {
                        let before = s.seq(v).unwrap();
                        // The bill is recompute work: the full context for
                        // a prefilled victim, nothing for one whose
                        // prefill never ran.
                        let expect =
                            if before.prefill_done { 4 + before.generated.len() } else { 0 };
                        let bill = s.preempt(v).expect("victim is active");
                        if bill != expect {
                            return Err(format!("bill {bill} != expected {expect}"));
                        }
                    }
                }
                let round = s.next_round();
                execute_round(&mut s, &round);
                finished += s.reap_finished().len();
                rounds += 1;
                if rounds > 10_000 {
                    return Err(format!("starvation: {finished}/{n} after {rounds} rounds"));
                }
            }
            if !s.is_idle() {
                return Err("finished everything but scheduler not idle".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_conservation_and_termination() {
        check("scheduler conserves requests and terminates", Config::cases(50), |rng| {
            let n = 1 + rng.gen_range(12) as usize;
            let max_active = 1 + rng.gen_range(4) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                ..Default::default()
            });
            for i in 0..n {
                s.submit(req(i as u64, 8, 1 + rng.gen_range(5) as usize));
            }
            let mut finished = 0usize;
            let mut rounds = 0usize;
            loop {
                s.admit();
                if s.active_len() > max_active {
                    return Err(format!("active {} > max {max_active}", s.active_len()));
                }
                let round = s.next_round();
                // Round invariants: bounded size, no request named twice.
                if round.work_items() > max_active {
                    return Err(format!("round exceeds max_active: {round:?}"));
                }
                let mut ids: Vec<RequestId> = round.prefill_ids();
                ids.extend(&round.decode_batch);
                ids.sort_unstable();
                ids.dedup();
                if ids.len() != round.work_items() {
                    return Err(format!("request appears twice in a round: {round:?}"));
                }
                for &id in &round.decode_batch {
                    let seq = s.seq(id).unwrap();
                    if seq.generated.len() >= seq.request.max_new_tokens {
                        return Err(format!("seq {id} scheduled past its budget"));
                    }
                }
                for &id in &round.decode_batch {
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for c in &round.prefills {
                    let seq = s.seq_mut(c.id).unwrap();
                    seq.prefill_progress += c.len;
                    if c.last {
                        seq.prefill_done = true;
                    }
                }
                finished += s.reap_finished().len();
                if s.is_idle() {
                    break;
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err("scheduler did not terminate".into());
                }
            }
            if finished != n {
                return Err(format!("finished {finished} != submitted {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_no_starvation_under_batching_with_arena() {
        // Random arrivals + KV-arena backpressure: every request finishes,
        // no arena block is ever double-claimed, and requests with equal
        // token budgets finish in submission order (FIFO fairness).
        check("batched rounds starve nobody", Config::cases(40), |rng| {
            let max_active = 1 + rng.gen_range(4) as usize;
            let gen_tokens = 1 + rng.gen_range(6) as usize; // shared budget
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                ..Default::default()
            });
            let mut arena = KvArena::new(KvArenaConfig {
                layers: 2,
                heads_kv: 2,
                head_dim: 32,
                block_tokens: 8,
                num_blocks: 2 + rng.gen_range(10) as usize,
            });
            let total = 1 + rng.gen_range(10) as usize;
            let prompt_len = 8usize;
            if !arena.can_claim(prompt_len + gen_tokens) {
                return Ok(()); // arena smaller than one request: uninteresting draw
            }
            let mut submitted = 0u64;
            let mut handles = std::collections::HashMap::new();
            let mut finish_order = Vec::new();
            let mut rounds = 0usize;
            while finish_order.len() < total {
                if (submitted as usize) < total && rng.gen_bool(0.6) {
                    s.submit(req(submitted, prompt_len, gen_tokens));
                    submitted += 1;
                }
                s.admit_where(|r, _ctx| {
                    let tokens = r.prompt.len() + r.max_new_tokens;
                    match arena.claim(tokens) {
                        Ok(h) => {
                            handles.insert(r.id, h);
                            true
                        }
                        Err(_) => false,
                    }
                });
                let round = s.next_round();
                for &id in &round.decode_batch {
                    arena.append(handles[&id], 1).map_err(|e| e.to_string())?;
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for c in &round.prefills {
                    let seq = s.seq_mut(c.id).unwrap();
                    seq.prefill_progress += c.len;
                    if c.last {
                        seq.prefill_done = true;
                    }
                    arena.append(handles[&c.id], c.len).map_err(|e| e.to_string())?;
                }
                arena.verify().map_err(|e| e.to_string())?;
                for done in s.reap_finished() {
                    arena.release(handles[&done.request.id]);
                    finish_order.push(done.request.id);
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err(format!(
                        "starvation: {} of {total} finished after {rounds} rounds",
                        finish_order.len()
                    ));
                }
            }
            // Equal budgets ⇒ FIFO admission implies FIFO completion.
            let mut sorted = finish_order.clone();
            sorted.sort();
            if finish_order != sorted {
                return Err(format!("completion out of order: {finish_order:?}"));
            }
            if arena.blocks_in_use() != 0 {
                return Err("arena leaked blocks after drain".into());
            }
            Ok(())
        });
    }

    #[test]
    fn profile_aware_prefill_chunk_granule() {
        use crate::device::device;
        // Phone-class dispatch costs 10–20µs: a 64-token granule keeps
        // launch overhead well under the chunk's compute time.
        assert_eq!(default_prefill_chunk_tokens(&device("adreno_750").unwrap()), 64);
        assert_eq!(default_prefill_chunk_tokens(&device("mali_g715").unwrap()), 64);
        // Laptop/desktop dispatch is cheap: 32 tokens already puts
        // overhead below 1% (DESIGN.md chunk-sizing numbers).
        assert_eq!(default_prefill_chunk_tokens(&device("m4_pro").unwrap()), 32);
        assert_eq!(default_prefill_chunk_tokens(&device("rtx_4090").unwrap()), 32);
        // Pathologically slow dispatchers (e.g. WebGPU-wrapped phones
        // past 100µs) double the granule to keep the ratio.
        let mut slow = device("mali_g715").unwrap();
        slow.launch_overhead_us = 120.0;
        assert_eq!(default_prefill_chunk_tokens(&slow), 128);
    }

    #[test]
    fn chunk_autotuner_halves_on_missed_target_and_recovers_with_hysteresis() {
        let t = ChunkAutotuner::new(64, 0.100);
        assert_eq!(t.min_chunk_tokens, 16, "floor is base/4");
        // Missed target: halve, floored.
        assert_eq!(t.update(64, 0.150), 32);
        assert_eq!(t.update(32, 0.150), 16);
        assert_eq!(t.update(16, 0.500), 16, "never below the launch-overhead floor");
        // Hysteresis band [target/2, target]: hold.
        assert_eq!(t.update(32, 0.080), 32);
        assert_eq!(t.update(32, 0.051), 32);
        // Comfortably under: double back toward base, capped there.
        assert_eq!(t.update(16, 0.020), 32);
        assert_eq!(t.update(32, 0.020), 64);
        assert_eq!(t.update(64, 0.020), 64, "never above the configured granule");
        // Out-of-ladder current values clamp before stepping.
        assert_eq!(t.update(1024, 0.150), 32);
        assert_eq!(t.update(0, 0.020), 32);
        // Inert configurations.
        assert_eq!(ChunkAutotuner::new(0, 0.1).update(0, 9.0), 0, "chunking off stays off");
        assert_eq!(ChunkAutotuner::new(64, 0.0).update(64, 9.0), 64, "no target: fixed");
        // A tiny base keeps the floor at the base itself, not above it.
        let tiny = ChunkAutotuner::new(4, 0.1);
        assert_eq!(tiny.min_chunk_tokens, 4);
        assert_eq!(tiny.update(4, 9.0), 4);
    }

    #[test]
    fn retuning_the_granule_mid_stream_keeps_chunk_progress_consistent() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 1,
            prefill_chunk_tokens: 16,
            ..Default::default()
        });
        s.submit(req(1, 40, 2));
        s.admit();
        assert_eq!(s.prefill_chunk_tokens(), 16);
        let r = s.next_round();
        assert_eq!(r.prefills, vec![PrefillChunk { id: 1, start: 0, len: 16, last: false }]);
        execute_round(&mut s, &r);
        // Shrink mid-prefill: the next chunk starts at the committed
        // progress and simply takes the new granule.
        s.set_prefill_chunk_tokens(8);
        let r = s.next_round();
        assert_eq!(r.prefills, vec![PrefillChunk { id: 1, start: 16, len: 8, last: false }]);
        execute_round(&mut s, &r);
        // Grow mid-prefill: a larger tail chunk, clamped at context end.
        s.set_prefill_chunk_tokens(64);
        let r = s.next_round();
        assert_eq!(r.prefills, vec![PrefillChunk { id: 1, start: 24, len: 16, last: true }]);
    }
}
