//! Round-based continuous-batching scheduler with decode-first stage
//! awareness.
//!
//! The engine no longer asks "what single thing should I do next" —
//! every call to [`Scheduler::next_round`] plans one **round**: *all*
//! runnable decodes packed into one batch (so weight streaming is paid
//! once per round, the §3.7 bandwidth argument applied across users)
//! plus up to `max_prefills_per_round` prefills (guarding inter-token
//! latency against prefill bursts).
//!
//! Invariants (enforced + property-tested):
//! * a request is either waiting, active, or finished — never two at once;
//! * at most `max_active` sequences hold KV reservations;
//! * a round never contains more than `max_active` work items and never
//!   names a request twice;
//! * no token is generated past `max_new_tokens`;
//! * every admitted request eventually finishes (no starvation: FIFO
//!   admission, and every unfinished active sequence decodes every round);
//! * admission blocked by KV-arena backpressure defers the request, it
//!   never fails it.

use std::collections::VecDeque;

use crate::serving::request::{InferenceRequest, RequestId};

/// Scheduler tuning.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (KV reservations).
    pub max_active: usize,
    /// Admit at most this many prefills per scheduling round (guards
    /// decode latency against prefill bursts — the serving-level analogue
    /// of §3.7's stage split).
    pub max_prefills_per_round: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 4, max_prefills_per_round: 1 }
    }
}

/// One active sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub request: InferenceRequest,
    pub generated: Vec<i32>,
    /// Next position to decode at (prompt length + generated so far).
    pub pos: usize,
    pub prefill_done: bool,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.prefill_done && self.generated.len() >= self.request.max_new_tokens
    }
}

/// One scheduling round: the prefills to run and the decode batch to
/// execute as a single batched step. Decode runs *first* when the engine
/// executes the round (decode-first latency protection).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Round {
    /// Requests to prefill this round (≤ `max_prefills_per_round`).
    pub prefills: Vec<RequestId>,
    /// Every active, prefilled, unfinished sequence: one decode step each,
    /// batched so the weights stream once.
    pub decode_batch: Vec<RequestId>,
}

impl Round {
    /// Nothing runnable this round.
    pub fn is_idle(&self) -> bool {
        self.prefills.is_empty() && self.decode_batch.is_empty()
    }

    /// Decode batch size (the occupancy metric).
    pub fn batch_size(&self) -> usize {
        self.decode_batch.len()
    }

    /// Total work items planned.
    pub fn work_items(&self) -> usize {
        self.prefills.len() + self.decode_batch.len()
    }
}

/// The scheduler: owns waiting queue + active set.
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    waiting: VecDeque<InferenceRequest>,
    active: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, ..Default::default() }
    }

    pub fn submit(&mut self, req: InferenceRequest) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn seq(&self, id: RequestId) -> Option<&SeqState> {
        self.active.iter().find(|s| s.request.id == id)
    }

    pub fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqState> {
        self.active.iter_mut().find(|s| s.request.id == id)
    }

    /// Admission at round start: pull waiting requests into free slots in
    /// FIFO order (continuous batching: join mid-stream).
    pub fn admit(&mut self) {
        self.admit_where(|_| true);
    }

    /// Admission with an external gate: `can_admit` is called once per
    /// candidate in FIFO order and may claim resources (KV arena blocks)
    /// as a side effect. Admission stops at the first rejected candidate
    /// rather than skipping past it — skipping would starve large
    /// requests behind a stream of small ones. A rejection is
    /// *backpressure*: the request stays queued and is retried next round.
    pub fn admit_where(&mut self, mut can_admit: impl FnMut(&InferenceRequest) -> bool) {
        // Like the prefill cap, a limit of 0 would strand the waiting
        // queue forever (nothing admitted ⇒ nothing ever finishes):
        // clamp to at least one concurrent sequence.
        let max_active = self.cfg.max_active.max(1);
        while self.active.len() < max_active {
            let Some(req) = self.waiting.front() else { break };
            if !can_admit(req) {
                break;
            }
            let req = self.waiting.pop_front().expect("front observed above");
            let pos = req.prompt.len();
            self.active.push(SeqState {
                request: req,
                generated: Vec::new(),
                pos,
                prefill_done: false,
            });
        }
    }

    /// Plan the next round: every decodable sequence joins the decode
    /// batch; up to `max_prefills_per_round` admitted-but-unprefilled
    /// sequences get their prefill (in admission order, so prefill order
    /// follows FIFO and nobody is starved).
    pub fn next_round(&self) -> Round {
        // A cap of 0 would strand admitted sequences forever (admitted but
        // never prefilled ⇒ never decodable ⇒ livelock): always allow at
        // least one prefill per round.
        let prefill_cap = self.cfg.max_prefills_per_round.max(1);
        let mut round = Round::default();
        for s in &self.active {
            if !s.prefill_done {
                if round.prefills.len() < prefill_cap {
                    round.prefills.push(s.request.id);
                }
            } else if !s.finished() {
                round.decode_batch.push(s.request.id);
            }
        }
        round
    }

    /// Remove and return finished sequences.
    pub fn reap_finished(&mut self) -> Vec<SeqState> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{KvArena, KvArenaConfig};
    use crate::util::propcheck::{check, Config};

    fn req(id: u64, prompt_len: usize, gen: usize) -> InferenceRequest {
        InferenceRequest::new(id, vec![1; prompt_len], gen)
    }

    /// Execute one planned round against the scheduler state, the way the
    /// engine does: decode batch first, then prefills.
    fn execute_round(s: &mut Scheduler, round: &Round) {
        for &id in &round.decode_batch {
            let seq = s.seq_mut(id).unwrap();
            assert!(
                seq.generated.len() < seq.request.max_new_tokens,
                "seq {id} decoded past its budget"
            );
            seq.generated.push(0);
            seq.pos += 1;
        }
        for &id in &round.prefills {
            s.seq_mut(id).unwrap().prefill_done = true;
        }
    }

    #[test]
    fn admits_up_to_max_active() {
        let mut s = Scheduler::new(SchedulerConfig { max_active: 2, max_prefills_per_round: 2 });
        for i in 0..5 {
            s.submit(req(i, 16, 4));
        }
        s.admit();
        assert_eq!(s.active_len(), 2);
        assert_eq!(s.waiting_len(), 3);
    }

    #[test]
    fn prefill_before_decode_per_sequence() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(1, 16, 2));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefills, vec![1]);
        assert!(r.decode_batch.is_empty(), "no decode before prefill: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        assert_eq!(r.decode_batch, vec![1]);
        assert!(r.prefills.is_empty());
    }

    #[test]
    fn decode_batch_packs_all_runnable_sequences() {
        let mut s = Scheduler::new(SchedulerConfig { max_active: 4, max_prefills_per_round: 4 });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // all four prefill
        let r = s.next_round();
        assert_eq!(r.batch_size(), 4, "all decodes batch into one round: {r:?}");
        assert_eq!(r.decode_batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prefills_capped_per_round_decodes_are_not() {
        let mut s = Scheduler::new(SchedulerConfig { max_active: 4, max_prefills_per_round: 1 });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        // Four rounds of capped prefill; decode batch grows behind it.
        for expect_batch in 0..4usize {
            let r = s.next_round();
            assert_eq!(r.prefills.len(), 1, "{r:?}");
            assert_eq!(r.batch_size(), expect_batch, "{r:?}");
            execute_round(&mut s, &r);
        }
        let r = s.next_round();
        assert!(r.prefills.is_empty());
        assert_eq!(r.batch_size(), 4);
    }

    #[test]
    fn zero_max_active_still_makes_progress() {
        // Regression: a (mis)configured max_active of 0 must not leave the
        // waiting queue stranded (the engine would busy-spin forever).
        let mut s = Scheduler::new(SchedulerConfig { max_active: 0, max_prefills_per_round: 1 });
        s.submit(req(1, 8, 1));
        s.admit();
        assert_eq!(s.active_len(), 1, "clamped to one concurrent sequence");
        let r = s.next_round();
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn zero_prefill_cap_still_makes_progress() {
        // Regression: a (mis)configured cap of 0 must not strand admitted
        // sequences in the never-prefilled state forever.
        let mut s = Scheduler::new(SchedulerConfig { max_active: 2, max_prefills_per_round: 0 });
        s.submit(req(1, 8, 1));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefills, vec![1], "at least one prefill per round: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn finished_sequences_reaped() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(7, 8, 1));
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // decode the single token
        let done = s.reap_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 7);
        assert!(s.is_idle());
    }

    #[test]
    fn full_arena_defers_admission_instead_of_erroring() {
        // Regression: a request that does not fit the arena *now* stays
        // waiting and is admitted after capacity frees up.
        let mut s = Scheduler::new(SchedulerConfig { max_active: 4, max_prefills_per_round: 4 });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 2,
            heads_kv: 2,
            head_dim: 32,
            block_tokens: 16,
            num_blocks: 4, // 64 tokens total
        });
        s.submit(req(0, 32, 16)); // 48 tokens → 3 blocks
        s.submit(req(1, 32, 16)); // would need 3 more → must wait
        let mut handles = std::collections::HashMap::new();
        s.admit_where(|r| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "second request deferred, not failed");
        assert_eq!(s.waiting_len(), 1);

        // Drive request 0 to completion; its release unblocks request 1.
        while s.seq(0).is_some() {
            let r = s.next_round();
            execute_round(&mut s, &r);
            for done in s.reap_finished() {
                arena.release(handles[&done.request.id]);
            }
        }
        s.admit_where(|r| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "freed capacity admits the deferred request");
        assert_eq!(s.waiting_len(), 0);
        arena.verify().unwrap();
    }

    #[test]
    fn property_conservation_and_termination() {
        check("scheduler conserves requests and terminates", Config::cases(50), |rng| {
            let n = 1 + rng.gen_range(12) as usize;
            let max_active = 1 + rng.gen_range(4) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
            });
            for i in 0..n {
                s.submit(req(i as u64, 8, 1 + rng.gen_range(5) as usize));
            }
            let mut finished = 0usize;
            let mut rounds = 0usize;
            loop {
                s.admit();
                if s.active_len() > max_active {
                    return Err(format!("active {} > max {max_active}", s.active_len()));
                }
                let round = s.next_round();
                // Round invariants: bounded size, no request named twice.
                if round.work_items() > max_active {
                    return Err(format!("round exceeds max_active: {round:?}"));
                }
                let mut ids: Vec<_> =
                    round.prefills.iter().chain(&round.decode_batch).collect();
                ids.sort();
                ids.dedup();
                if ids.len() != round.work_items() {
                    return Err(format!("request appears twice in a round: {round:?}"));
                }
                for &id in &round.decode_batch {
                    let seq = s.seq(id).unwrap();
                    if seq.generated.len() >= seq.request.max_new_tokens {
                        return Err(format!("seq {id} scheduled past its budget"));
                    }
                }
                for &id in &round.decode_batch {
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for &id in &round.prefills {
                    s.seq_mut(id).unwrap().prefill_done = true;
                }
                finished += s.reap_finished().len();
                if s.is_idle() {
                    break;
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err("scheduler did not terminate".into());
                }
            }
            if finished != n {
                return Err(format!("finished {finished} != submitted {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_no_starvation_under_batching_with_arena() {
        // Random arrivals + KV-arena backpressure: every request finishes,
        // no arena block is ever double-claimed, and requests with equal
        // token budgets finish in submission order (FIFO fairness).
        check("batched rounds starve nobody", Config::cases(40), |rng| {
            let max_active = 1 + rng.gen_range(4) as usize;
            let gen_tokens = 1 + rng.gen_range(6) as usize; // shared budget
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
            });
            let mut arena = KvArena::new(KvArenaConfig {
                layers: 2,
                heads_kv: 2,
                head_dim: 32,
                block_tokens: 8,
                num_blocks: 2 + rng.gen_range(10) as usize,
            });
            let total = 1 + rng.gen_range(10) as usize;
            let prompt_len = 8usize;
            if !arena.can_claim(prompt_len + gen_tokens) {
                return Ok(()); // arena smaller than one request: uninteresting draw
            }
            let mut submitted = 0u64;
            let mut handles = std::collections::HashMap::new();
            let mut finish_order = Vec::new();
            let mut rounds = 0usize;
            while finish_order.len() < total {
                if (submitted as usize) < total && rng.gen_bool(0.6) {
                    s.submit(req(submitted, prompt_len, gen_tokens));
                    submitted += 1;
                }
                s.admit_where(|r| {
                    let tokens = r.prompt.len() + r.max_new_tokens;
                    match arena.claim(tokens) {
                        Ok(h) => {
                            handles.insert(r.id, h);
                            true
                        }
                        Err(_) => false,
                    }
                });
                let round = s.next_round();
                for &id in &round.decode_batch {
                    arena.append(handles[&id], 1).map_err(|e| e.to_string())?;
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for &id in &round.prefills {
                    let seq = s.seq_mut(id).unwrap();
                    let n = seq.request.prompt.len();
                    seq.prefill_done = true;
                    arena.append(handles[&id], n).map_err(|e| e.to_string())?;
                }
                arena.verify().map_err(|e| e.to_string())?;
                for done in s.reap_finished() {
                    arena.release(handles[&done.request.id]);
                    finish_order.push(done.request.id);
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err(format!(
                        "starvation: {} of {total} finished after {rounds} rounds",
                        finish_order.len()
                    ));
                }
            }
            // Equal budgets ⇒ FIFO admission implies FIFO completion.
            let mut sorted = finish_order.clone();
            sorted.sort();
            if finish_order != sorted {
                return Err(format!("completion out of order: {finish_order:?}"));
            }
            if arena.blocks_in_use() != 0 {
                return Err("arena leaked blocks after drain".into());
            }
            Ok(())
        });
    }
}
