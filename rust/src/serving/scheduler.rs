//! Round-based continuous-batching scheduler with decode-first stage
//! awareness.
//!
//! The engine no longer asks "what single thing should I do next" —
//! every call to [`Scheduler::next_round`] plans one **round**: *all*
//! runnable decodes packed into one batch (so weight streaming is paid
//! once per round, the §3.7 bandwidth argument applied across users)
//! plus up to `max_prefills_per_round` prefills (guarding inter-token
//! latency against prefill bursts).
//!
//! Invariants (enforced + property-tested):
//! * a request is either waiting, preempted, active, or finished — never
//!   two at once;
//! * at most `max_active` sequences hold KV reservations;
//! * a round never contains more than `max_active` work items and never
//!   names a request twice;
//! * no token is generated past `max_new_tokens`;
//! * every admitted request eventually finishes (no starvation: FIFO
//!   admission, every unfinished active sequence decodes every round,
//!   and eviction is bounded — see below);
//! * admission blocked by KV-arena backpressure defers the request, it
//!   never fails it.
//!
//! **Preemption** (paged KV): when the arena cannot grow mid-round, the
//! engine evicts a victim back to a re-admission queue via
//! [`Scheduler::preempt`]; the victim re-prefills its whole context on
//! re-admission. Starvation from repeated eviction is bounded three ways:
//! * the **oldest active sequence is never a victim**
//!   ([`Scheduler::choose_victim`] skips it), so the FIFO head always
//!   runs to completion and frees its blocks;
//! * a sequence evicted `max_evictions_per_seq` times is **pinned** and
//!   not selected again — unless the head itself cannot grow, in which
//!   case pinning yields ([`Scheduler::choose_victim_ignoring_pins`])
//!   so the head's completion guarantee is unconditional;
//! * preempted sequences are re-admitted **before** the waiting queue.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::kv::{KvPool, KvSeqHandle};
use crate::serving::request::{InferenceRequest, RequestId};

/// Scheduler tuning.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (KV reservations).
    pub max_active: usize,
    /// Admit at most this many prefills per scheduling round (guards
    /// decode latency against prefill bursts — the serving-level analogue
    /// of §3.7's stage split).
    pub max_prefills_per_round: usize,
    /// Evictions a sequence may suffer before it is pinned (never again
    /// selected by [`Scheduler::choose_victim`]) — the starvation bound
    /// for paged-KV preemption. 0 pins everything, disabling *policy*
    /// eviction; the FIFO-head escalation
    /// ([`Scheduler::choose_victim_ignoring_pins`]) can still evict, as
    /// the alternative to the head's progress guarantee is livelock.
    pub max_evictions_per_seq: u32,
    /// Override the engine's KV arena size, in blocks. `None` (default)
    /// sizes the arena for `max_active` worst-case sequences —
    /// preemption-free by construction, the PR-1 safety net. `Some(n)`
    /// fixes the memory budget instead, making KV the contended
    /// resource: expected-footprint admission then buys occupancy, and
    /// exhaustion degrades to preemption. Requests that could never fit
    /// the fixed arena are rejected at submission (so deferral cannot
    /// wedge).
    pub kv_arena_blocks: Option<usize>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 1,
            max_evictions_per_seq: 3,
            kv_arena_blocks: None,
        }
    }
}

/// One active sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub request: InferenceRequest,
    pub generated: Vec<i32>,
    /// Next position to decode at (prompt length + generated so far).
    pub pos: usize,
    pub prefill_done: bool,
    /// Times this sequence has been evicted (paged-KV preemption).
    pub evictions: u32,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.prefill_done && self.generated.len() >= self.request.max_new_tokens
    }

    /// Token positions prefill must cover for this sequence *now*:
    /// the prompt plus everything generated before a preemption (the
    /// re-prefill recomputes those KV rows; logits over this context
    /// reproduce the next token exactly, so eviction costs work, never
    /// correctness).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated.len()
    }
}

/// One scheduling round: the prefills to run and the decode batch to
/// execute as a single batched step. Decode runs *first* when the engine
/// executes the round (decode-first latency protection).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Round {
    /// Requests to prefill this round (≤ `max_prefills_per_round`).
    pub prefills: Vec<RequestId>,
    /// Every active, prefilled, unfinished sequence: one decode step each,
    /// batched so the weights stream once.
    pub decode_batch: Vec<RequestId>,
}

impl Round {
    /// Nothing runnable this round.
    pub fn is_idle(&self) -> bool {
        self.prefills.is_empty() && self.decode_batch.is_empty()
    }

    /// Decode batch size (the occupancy metric).
    pub fn batch_size(&self) -> usize {
        self.decode_batch.len()
    }

    /// Total work items planned.
    pub fn work_items(&self) -> usize {
        self.prefills.len() + self.decode_batch.len()
    }
}

/// The scheduler: owns waiting queue + preempted queue + active set.
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    waiting: VecDeque<InferenceRequest>,
    /// Evicted sequences awaiting re-admission (drained before `waiting`
    /// so eviction degrades to queueing latency, not starvation).
    preempted: VecDeque<SeqState>,
    active: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, ..Default::default() }
    }

    pub fn submit(&mut self, req: InferenceRequest) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn preempted_len(&self) -> usize {
        self.preempted.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn seq(&self, id: RequestId) -> Option<&SeqState> {
        self.active.iter().find(|s| s.request.id == id)
    }

    pub fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqState> {
        self.active.iter_mut().find(|s| s.request.id == id)
    }

    /// Admission at round start: pull preempted, then waiting, requests
    /// into free slots in FIFO order (continuous batching: join
    /// mid-stream).
    pub fn admit(&mut self) {
        self.admit_where(|_, _| true);
    }

    /// Admission with an external gate: `can_admit(request,
    /// context_tokens)` is called once per candidate in FIFO order and
    /// may claim resources (KV arena blocks) as a side effect.
    /// `context_tokens` is what prefill must cover on admission — the
    /// prompt for a fresh request, prompt + generated-so-far for a
    /// re-admitted preempted sequence (paged admission claims exactly
    /// this and grows during decode). Preempted sequences drain first.
    /// Admission stops at the first rejected candidate rather than
    /// skipping past it — skipping would starve large requests behind a
    /// stream of small ones. A rejection is *backpressure*: the request
    /// stays queued and is retried next round.
    pub fn admit_where(&mut self, mut can_admit: impl FnMut(&InferenceRequest, usize) -> bool) {
        // Like the prefill cap, a limit of 0 would strand the waiting
        // queue forever (nothing admitted ⇒ nothing ever finishes):
        // clamp to at least one concurrent sequence.
        let max_active = self.cfg.max_active.max(1);
        while self.active.len() < max_active {
            if let Some(s) = self.preempted.front() {
                if !can_admit(&s.request, s.context_len()) {
                    return;
                }
                let s = self.preempted.pop_front().expect("front observed above");
                self.active.push(s);
                continue;
            }
            let Some(req) = self.waiting.front() else { break };
            if !can_admit(req, req.prompt.len()) {
                break;
            }
            let req = self.waiting.pop_front().expect("front observed above");
            let pos = req.prompt.len();
            self.active.push(SeqState {
                request: req,
                generated: Vec::new(),
                pos,
                prefill_done: false,
                evictions: 0,
            });
        }
    }

    /// Evict an active sequence back to the re-admission queue (paged-KV
    /// preemption). The caller releases the sequence's arena blocks; the
    /// scheduler marks it un-prefilled so re-admission re-prefills its
    /// whole context ([`SeqState::context_len`]) — recompute semantics,
    /// no state is lost. Returns the re-prefill bill: the token positions
    /// whose KV must be *recomputed* (the context length for a prefilled
    /// sequence, 0 for one evicted before its prefill ever ran — nothing
    /// is wasted then). `None` if `id` isn't active.
    pub fn preempt(&mut self, id: RequestId) -> Option<usize> {
        let i = self.active.iter().position(|s| s.request.id == id)?;
        let mut s = self.active.remove(i);
        let bill = if s.prefill_done { s.context_len() } else { 0 };
        s.prefill_done = false;
        s.evictions += 1;
        self.preempted.push_back(s);
        Some(bill)
    }

    /// Victim for eviction when the arena cannot grow: the
    /// lowest-progress (fewest generated tokens), youngest sequence.
    /// Never the oldest active sequence — the FIFO head keeps an
    /// eviction-immune claim, so it always runs to completion and frees
    /// its blocks (this is what bounds thrash: serialized to one
    /// sequence, the system degenerates to single-stream serving, never
    /// livelock). Sequences already evicted `max_evictions_per_seq`
    /// times are pinned and skipped.
    pub fn choose_victim(&self) -> Option<RequestId> {
        self.victim(false)
    }

    /// Escalation for when the **FIFO head itself** cannot grow and
    /// [`choose_victim`](Self::choose_victim) came up empty: pinning
    /// yields to the head's progress guarantee (any non-head sequence may
    /// be evicted). Without this, an arena exhausted entirely by pinned
    /// sequences would stall the head forever — with it, serialization to
    /// single-stream serving is the worst case, never livelock.
    pub fn choose_victim_ignoring_pins(&self) -> Option<RequestId> {
        self.victim(true)
    }

    /// Oldest active sequence (the eviction-immune FIFO head), if any.
    pub fn head(&self) -> Option<RequestId> {
        self.active.first().map(|s| s.request.id)
    }

    fn victim(&self, ignore_pins: bool) -> Option<RequestId> {
        // "Youngest" = most recently admitted = highest index in
        // `active` (admission order). Request ids are caller-assigned
        // and say nothing about age.
        self.active
            .iter()
            .enumerate()
            .skip(1) // FIFO head is immune
            .filter(|(_, s)| ignore_pins || s.evictions < self.cfg.max_evictions_per_seq)
            .min_by_key(|&(i, s)| (s.generated.len(), std::cmp::Reverse(i)))
            .map(|(_, s)| s.request.id)
    }

    /// Make room for `rows` more KV rows for every `(id, rows)` in
    /// `needs_rows`, evicting victims when the KV pool cannot grow — the
    /// one growth/preemption loop both the engine and the serving
    /// simulator run, so their policies can never diverge. Generic over
    /// [`KvPool`]: the simulator passes the accounting
    /// [`crate::kv::KvArena`], the engine the device-backed
    /// [`crate::kv::PagedKvStore`] — so in the engine an eviction here
    /// releases (and scrubs) real region bytes.
    ///
    /// Plain decode needs one row per sequence; a **speculative**
    /// sequence needs `k + 1` (the round's provisional draft/verify
    /// scatter — rejected rows are scrubbed after acceptance, but the
    /// blocks must exist before any state advances).
    ///
    /// For each entry in order: [`KvPool::ensure`]`(h, rows)`; on
    /// exhaustion, evict [`choose_victim`](Self::choose_victim)
    /// (escalating past pins only when the FIFO head itself is the one
    /// growing), release the victim's blocks, call `on_evict(victim,
    /// reprefill_bill, device_bytes_freed)` so the caller can park its
    /// runtime state and record metrics, and retry. If no victim exists —
    /// or the grower evicted itself — the sequence is **held out**.
    ///
    /// Returns the held-out set: every evicted victim plus every
    /// capacity-starved grower. Held-out sequences must sit the whole
    /// round out (no emission, no step, no prefill) — an evicted victim
    /// may still be named in the already-planned round.
    pub fn ensure_round_capacity<K: KvPool>(
        &mut self,
        kv: &mut K,
        handles: &mut HashMap<RequestId, KvSeqHandle>,
        needs_rows: &[(RequestId, usize)],
        mut on_evict: impl FnMut(RequestId, usize, usize),
    ) -> HashSet<RequestId> {
        let mut held_out = HashSet::new();
        for &(id, rows) in needs_rows {
            if held_out.contains(&id) {
                continue; // evicted by an earlier member's growth
            }
            let h = handles[&id];
            loop {
                match kv.ensure(h, rows) {
                    Ok(_) => break,
                    Err(_) => {
                        // Pinning yields when the FIFO head itself needs
                        // the blocks — the head's progress guarantee is
                        // what bounds thrash, so it outranks pins.
                        let victim = self.choose_victim().or_else(|| {
                            (self.head() == Some(id))
                                .then(|| self.choose_victim_ignoring_pins())
                                .flatten()
                        });
                        let Some(victim) = victim else {
                            // Nobody evictable: sit this round out; the
                            // head keeps progressing and frees blocks.
                            held_out.insert(id);
                            break;
                        };
                        let bill = self.preempt(victim).expect("victim is active");
                        let mut freed = 0;
                        if let Some(vh) = handles.remove(&victim) {
                            freed = kv.release(vh);
                        }
                        on_evict(victim, bill, freed);
                        held_out.insert(victim);
                        if victim == id {
                            break; // evicted itself: no step this round
                        }
                    }
                }
            }
        }
        held_out
    }

    /// `(sequences, generated-so-far tokens)` across active **and**
    /// preempted sequences. Each in-flight count is a per-sequence lower
    /// bound on its final generation length — the signal the blended
    /// admission estimator
    /// ([`crate::serving::admission::blended_mean_gen`]) folds in to
    /// correct the survivorship bias of completed-only means (short
    /// generations finish first, so the early completed mean is biased
    /// low and admission over-admits exactly during warm-up).
    pub fn inflight_gen(&self) -> (u64, u64) {
        let mut seqs = 0u64;
        let mut tokens = 0u64;
        for s in self.active.iter().chain(self.preempted.iter()) {
            seqs += 1;
            tokens += s.generated.len() as u64;
        }
        (seqs, tokens)
    }

    /// Plan the next round: every decodable sequence joins the decode
    /// batch; up to `max_prefills_per_round` admitted-but-unprefilled
    /// sequences get their prefill (in admission order, so prefill order
    /// follows FIFO and nobody is starved).
    pub fn next_round(&self) -> Round {
        // A cap of 0 would strand admitted sequences forever (admitted but
        // never prefilled ⇒ never decodable ⇒ livelock): always allow at
        // least one prefill per round.
        let prefill_cap = self.cfg.max_prefills_per_round.max(1);
        let mut round = Round::default();
        for s in &self.active {
            if !s.prefill_done {
                if round.prefills.len() < prefill_cap {
                    round.prefills.push(s.request.id);
                }
            } else if !s.finished() {
                round.decode_batch.push(s.request.id);
            }
        }
        round
    }

    /// Remove and return finished sequences.
    pub fn reap_finished(&mut self) -> Vec<SeqState> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.preempted.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{KvArena, KvArenaConfig};
    use crate::util::propcheck::{check, Config};

    fn req(id: u64, prompt_len: usize, gen: usize) -> InferenceRequest {
        InferenceRequest::new(id, vec![1; prompt_len], gen)
    }

    /// Execute one planned round against the scheduler state, the way the
    /// engine does: decode batch first, then prefills.
    fn execute_round(s: &mut Scheduler, round: &Round) {
        for &id in &round.decode_batch {
            let seq = s.seq_mut(id).unwrap();
            assert!(
                seq.generated.len() < seq.request.max_new_tokens,
                "seq {id} decoded past its budget"
            );
            seq.generated.push(0);
            seq.pos += 1;
        }
        for &id in &round.prefills {
            s.seq_mut(id).unwrap().prefill_done = true;
        }
    }

    #[test]
    fn admits_up_to_max_active() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        for i in 0..5 {
            s.submit(req(i, 16, 4));
        }
        s.admit();
        assert_eq!(s.active_len(), 2);
        assert_eq!(s.waiting_len(), 3);
    }

    #[test]
    fn prefill_before_decode_per_sequence() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(1, 16, 2));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefills, vec![1]);
        assert!(r.decode_batch.is_empty(), "no decode before prefill: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        assert_eq!(r.decode_batch, vec![1]);
        assert!(r.prefills.is_empty());
    }

    #[test]
    fn decode_batch_packs_all_runnable_sequences() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 4,
            ..Default::default()
        });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // all four prefill
        let r = s.next_round();
        assert_eq!(r.batch_size(), 4, "all decodes batch into one round: {r:?}");
        assert_eq!(r.decode_batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prefills_capped_per_round_decodes_are_not() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 1,
            ..Default::default()
        });
        for i in 0..4 {
            s.submit(req(i, 16, 10));
        }
        s.admit();
        // Four rounds of capped prefill; decode batch grows behind it.
        for expect_batch in 0..4usize {
            let r = s.next_round();
            assert_eq!(r.prefills.len(), 1, "{r:?}");
            assert_eq!(r.batch_size(), expect_batch, "{r:?}");
            execute_round(&mut s, &r);
        }
        let r = s.next_round();
        assert!(r.prefills.is_empty());
        assert_eq!(r.batch_size(), 4);
    }

    #[test]
    fn zero_max_active_still_makes_progress() {
        // Regression: a (mis)configured max_active of 0 must not leave the
        // waiting queue stranded (the engine would busy-spin forever).
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 0,
            max_prefills_per_round: 1,
            ..Default::default()
        });
        s.submit(req(1, 8, 1));
        s.admit();
        assert_eq!(s.active_len(), 1, "clamped to one concurrent sequence");
        let r = s.next_round();
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn zero_prefill_cap_still_makes_progress() {
        // Regression: a (mis)configured cap of 0 must not strand admitted
        // sequences in the never-prefilled state forever.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 0,
            ..Default::default()
        });
        s.submit(req(1, 8, 1));
        s.admit();
        let r = s.next_round();
        assert_eq!(r.prefills, vec![1], "at least one prefill per round: {r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        execute_round(&mut s, &r);
        assert_eq!(s.reap_finished().len(), 1);
        assert!(s.is_idle());
    }

    #[test]
    fn finished_sequences_reaped() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(7, 8, 1));
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // decode the single token
        let done = s.reap_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 7);
        assert!(s.is_idle());
    }

    #[test]
    fn full_arena_defers_admission_instead_of_erroring() {
        // Regression: a request that does not fit the arena *now* stays
        // waiting and is admitted after capacity frees up.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            max_prefills_per_round: 4,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 2,
            heads_kv: 2,
            head_dim: 32,
            block_tokens: 16,
            num_blocks: 4, // 64 tokens total
        });
        s.submit(req(0, 32, 16)); // 48 tokens → 3 blocks
        s.submit(req(1, 32, 16)); // would need 3 more → must wait
        let mut handles = std::collections::HashMap::new();
        s.admit_where(|r, _ctx| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "second request deferred, not failed");
        assert_eq!(s.waiting_len(), 1);

        // Drive request 0 to completion; its release unblocks request 1.
        while s.seq(0).is_some() {
            let r = s.next_round();
            execute_round(&mut s, &r);
            for done in s.reap_finished() {
                arena.release(handles[&done.request.id]);
            }
        }
        s.admit_where(|r, _ctx| {
            let tokens = r.prompt.len() + r.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(r.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        assert_eq!(s.active_len(), 1, "freed capacity admits the deferred request");
        assert_eq!(s.waiting_len(), 0);
        arena.verify().unwrap();
    }

    #[test]
    fn preempt_requeues_and_readmits_before_waiting() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        s.submit(req(0, 8, 4));
        s.submit(req(1, 8, 4));
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // both decode one token
        let ctx = s.preempt(1).expect("active sequence evicts");
        assert_eq!(ctx, 9, "re-prefill bill = prompt 8 + 1 generated");
        assert_eq!(s.active_len(), 1);
        assert_eq!(s.preempted_len(), 1);
        assert!(!s.is_idle());
        assert!(s.preempt(1).is_none(), "already evicted: no-op");

        // A later submission must NOT jump ahead of the evicted sequence.
        s.submit(req(2, 8, 4));
        s.admit();
        assert!(s.seq(1).is_some(), "preempted sequence re-admitted first");
        assert!(s.seq(2).is_none(), "fresh request waits behind it");
        let seq1 = s.seq(1).unwrap();
        assert!(!seq1.prefill_done, "re-admission re-prefills the context");
        assert_eq!(seq1.generated.len(), 1, "generated tokens survive eviction");
        assert_eq!(seq1.evictions, 1);
        // It shows up as a prefill, then rejoins the decode batch.
        let r = s.next_round();
        assert!(r.prefills.contains(&1), "{r:?}");
        execute_round(&mut s, &r);
        let r = s.next_round();
        assert!(r.decode_batch.contains(&1), "{r:?}");
    }

    #[test]
    fn victim_selection_skips_head_and_pinned() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 3,
            max_prefills_per_round: 3,
            max_evictions_per_seq: 1,
            ..Default::default()
        });
        for i in 0..3 {
            s.submit(req(i, 8, 8));
        }
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // all prefill
        // Give seq 1 more progress than seq 2.
        s.seq_mut(1).unwrap().generated.push(0);
        s.seq_mut(1).unwrap().generated.push(0);
        // Victim: lowest progress among non-head → seq 2 (0 tokens).
        assert_eq!(s.choose_victim(), Some(2));
        s.preempt(2).unwrap();
        // Next victim: seq 1 (head seq 0 is immune).
        assert_eq!(s.choose_victim(), Some(1));
        s.preempt(1).unwrap();
        // Only the head remains: nobody to evict.
        assert_eq!(s.choose_victim(), None);
        s.admit(); // re-admit 2 then 1 (FIFO over the preempted queue)
        assert_eq!(s.active_len(), 3);
        // Both re-admitted sequences are now pinned (max_evictions 1):
        // victim selection must come up empty, not starve them again.
        assert_eq!(s.choose_victim(), None, "pinned sequences are immune");
        // ... except to the head's escalation: if the head itself cannot
        // grow, pins yield (lowest-progress, youngest first) so the head
        // always completes — serialization, never livelock.
        assert_eq!(s.head(), Some(0));
        assert_eq!(s.choose_victim_ignoring_pins(), Some(2));
    }

    #[test]
    fn growth_can_evict_a_same_round_prefill_candidate() {
        // Regression for the round-planning race: a fresh admission has
        // zero progress, making it the *preferred* victim — yet it can
        // already be named in the same round's prefill list. The
        // held-out set returned by `ensure_round_capacity` must cover
        // it, so the round executor skips its prefill instead of
        // panicking on a sequence that is no longer active.
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 3,
        });
        let mut handles = std::collections::HashMap::new();
        s.submit(req(0, 16, 64));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        let r = s.next_round();
        assert_eq!(r.prefills, vec![0]);
        execute_round(&mut s, &r);
        arena.append(handles[&0], 16).unwrap(); // prefill wrote the prompt

        s.submit(req(1, 32, 8));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        assert_eq!(s.active_len(), 2);
        assert_eq!(arena.blocks_free(), 0);

        // This round decodes seq 0 (which must grow) and plans seq 1's
        // prefill — but seq 0's growth can only succeed by evicting 1.
        let round = s.next_round();
        assert_eq!(round.decode_batch, vec![0]);
        assert_eq!(round.prefills, vec![1]);
        let needs: Vec<(RequestId, usize)> =
            round.decode_batch.iter().map(|&id| (id, 1)).collect();
        let mut evicted = Vec::new();
        let held_out = s.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &needs,
            |v, bill, freed| {
                evicted.push((v, bill));
                assert!(freed > 0, "evicting a claimed sequence must free bytes");
            },
        );
        assert_eq!(evicted, vec![(1, 0)], "unprefilled victim bills no recompute");
        assert!(held_out.contains(&1), "held-out must cover the planned prefill");
        assert!(s.seq(1).is_none(), "victim left the active set");
        assert_eq!(s.preempted_len(), 1, "victim awaits re-admission");
        assert!(!handles.contains_key(&1), "victim handle released");
        // Seq 0 got its block: the KV-row append cannot overflow now.
        arena.append(handles[&0], 1).unwrap();
        arena.verify().unwrap();
    }

    #[test]
    fn speculative_multi_row_growth_follows_the_same_eviction_policy() {
        // A speculative sequence needs k+1 provisional rows before the
        // round runs; exhaustion mid-growth must pick the same victims
        // as plain single-row growth (policy shared, not duplicated).
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 64,
            block_tokens: 16,
            num_blocks: 3,
        });
        let mut handles = std::collections::HashMap::new();
        s.submit(req(0, 16, 64));
        s.submit(req(1, 32, 8));
        s.admit_where(|r, ctx| match arena.claim(ctx) {
            Ok(h) => {
                handles.insert(r.id, h);
                true
            }
            Err(_) => false,
        });
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        arena.append(handles[&0], 16).unwrap();
        arena.append(handles[&1], 32).unwrap();
        assert_eq!(arena.blocks_free(), 0);

        // Seq 0 speculates with k = 4 ⇒ needs 5 rows; only evicting seq 1
        // (2 blocks) makes room.
        let mut evicted = Vec::new();
        let held_out = s.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &[(0, 5)],
            |v, bill, _freed| evicted.push((v, bill)),
        );
        assert_eq!(evicted, vec![(1, 32)], "victim bills its prefilled context");
        assert!(held_out.contains(&1));
        assert!(!held_out.contains(&0), "the grower got its rows");
        arena.append(handles[&0], 5).unwrap();
        arena.verify().unwrap();
    }

    #[test]
    fn inflight_gen_counts_active_and_preempted() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 2,
            max_prefills_per_round: 2,
            ..Default::default()
        });
        s.submit(req(0, 8, 4));
        s.submit(req(1, 8, 4));
        assert_eq!(s.inflight_gen(), (0, 0), "waiting requests are not in flight");
        s.admit();
        let r = s.next_round();
        execute_round(&mut s, &r); // both prefill
        let r = s.next_round();
        execute_round(&mut s, &r); // both decode one token
        assert_eq!(s.inflight_gen(), (2, 2));
        s.preempt(1).unwrap();
        // Eviction must not erase a sequence's lower bound — that would
        // re-bias the estimator exactly when preemptions spike.
        assert_eq!(s.inflight_gen(), (2, 2), "preempted sequences still count");
    }

    #[test]
    fn property_no_starvation_under_preemption() {
        // Random decode/preempt interleavings: every request still
        // finishes (the head-immunity + pinning + readmit-first rules
        // bound eviction), and generated counts never regress.
        check("preemption starves nobody", Config::cases(40), |rng| {
            let n = 2 + rng.gen_range(8) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active: 2 + rng.gen_range(3) as usize,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                max_evictions_per_seq: rng.gen_range(3) as u32,
                ..Default::default()
            });
            for i in 0..n {
                s.submit(req(i as u64, 4, 1 + rng.gen_range(6) as usize));
            }
            let mut finished = 0usize;
            let mut rounds = 0usize;
            while finished < n {
                s.admit();
                // Adversarial arena stand-in: evict the policy's victim
                // with probability 1/3 before executing the round.
                if rng.gen_range(3) == 0 {
                    if let Some(v) = s.choose_victim() {
                        let before = s.seq(v).unwrap();
                        // The bill is recompute work: the full context for
                        // a prefilled victim, nothing for one whose
                        // prefill never ran.
                        let expect =
                            if before.prefill_done { 4 + before.generated.len() } else { 0 };
                        let bill = s.preempt(v).expect("victim is active");
                        if bill != expect {
                            return Err(format!("bill {bill} != expected {expect}"));
                        }
                    }
                }
                let round = s.next_round();
                execute_round(&mut s, &round);
                finished += s.reap_finished().len();
                rounds += 1;
                if rounds > 10_000 {
                    return Err(format!("starvation: {finished}/{n} after {rounds} rounds"));
                }
            }
            if !s.is_idle() {
                return Err("finished everything but scheduler not idle".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_conservation_and_termination() {
        check("scheduler conserves requests and terminates", Config::cases(50), |rng| {
            let n = 1 + rng.gen_range(12) as usize;
            let max_active = 1 + rng.gen_range(4) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                ..Default::default()
            });
            for i in 0..n {
                s.submit(req(i as u64, 8, 1 + rng.gen_range(5) as usize));
            }
            let mut finished = 0usize;
            let mut rounds = 0usize;
            loop {
                s.admit();
                if s.active_len() > max_active {
                    return Err(format!("active {} > max {max_active}", s.active_len()));
                }
                let round = s.next_round();
                // Round invariants: bounded size, no request named twice.
                if round.work_items() > max_active {
                    return Err(format!("round exceeds max_active: {round:?}"));
                }
                let mut ids: Vec<_> =
                    round.prefills.iter().chain(&round.decode_batch).collect();
                ids.sort();
                ids.dedup();
                if ids.len() != round.work_items() {
                    return Err(format!("request appears twice in a round: {round:?}"));
                }
                for &id in &round.decode_batch {
                    let seq = s.seq(id).unwrap();
                    if seq.generated.len() >= seq.request.max_new_tokens {
                        return Err(format!("seq {id} scheduled past its budget"));
                    }
                }
                for &id in &round.decode_batch {
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for &id in &round.prefills {
                    s.seq_mut(id).unwrap().prefill_done = true;
                }
                finished += s.reap_finished().len();
                if s.is_idle() {
                    break;
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err("scheduler did not terminate".into());
                }
            }
            if finished != n {
                return Err(format!("finished {finished} != submitted {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_no_starvation_under_batching_with_arena() {
        // Random arrivals + KV-arena backpressure: every request finishes,
        // no arena block is ever double-claimed, and requests with equal
        // token budgets finish in submission order (FIFO fairness).
        check("batched rounds starve nobody", Config::cases(40), |rng| {
            let max_active = 1 + rng.gen_range(4) as usize;
            let gen_tokens = 1 + rng.gen_range(6) as usize; // shared budget
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
                ..Default::default()
            });
            let mut arena = KvArena::new(KvArenaConfig {
                layers: 2,
                heads_kv: 2,
                head_dim: 32,
                block_tokens: 8,
                num_blocks: 2 + rng.gen_range(10) as usize,
            });
            let total = 1 + rng.gen_range(10) as usize;
            let prompt_len = 8usize;
            if !arena.can_claim(prompt_len + gen_tokens) {
                return Ok(()); // arena smaller than one request: uninteresting draw
            }
            let mut submitted = 0u64;
            let mut handles = std::collections::HashMap::new();
            let mut finish_order = Vec::new();
            let mut rounds = 0usize;
            while finish_order.len() < total {
                if (submitted as usize) < total && rng.gen_bool(0.6) {
                    s.submit(req(submitted, prompt_len, gen_tokens));
                    submitted += 1;
                }
                s.admit_where(|r, _ctx| {
                    let tokens = r.prompt.len() + r.max_new_tokens;
                    match arena.claim(tokens) {
                        Ok(h) => {
                            handles.insert(r.id, h);
                            true
                        }
                        Err(_) => false,
                    }
                });
                let round = s.next_round();
                for &id in &round.decode_batch {
                    arena.append(handles[&id], 1).map_err(|e| e.to_string())?;
                    let seq = s.seq_mut(id).unwrap();
                    seq.generated.push(0);
                    seq.pos += 1;
                }
                for &id in &round.prefills {
                    let seq = s.seq_mut(id).unwrap();
                    let n = seq.request.prompt.len();
                    seq.prefill_done = true;
                    arena.append(handles[&id], n).map_err(|e| e.to_string())?;
                }
                arena.verify().map_err(|e| e.to_string())?;
                for done in s.reap_finished() {
                    arena.release(handles[&done.request.id]);
                    finish_order.push(done.request.id);
                }
                rounds += 1;
                if rounds > 10_000 {
                    return Err(format!(
                        "starvation: {} of {total} finished after {rounds} rounds",
                        finish_order.len()
                    ));
                }
            }
            // Equal budgets ⇒ FIFO admission implies FIFO completion.
            let mut sorted = finish_order.clone();
            sorted.sort();
            if finish_order != sorted {
                return Err(format!("completion out of order: {finish_order:?}"));
            }
            if arena.blocks_in_use() != 0 {
                return Err("arena leaked blocks after drain".into());
            }
            Ok(())
        });
    }
}
