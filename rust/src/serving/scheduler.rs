//! Continuous-batching scheduler with decode-first stage awareness.
//!
//! Invariants (enforced + property-tested):
//! * a request is either waiting, active, or finished — never two at once;
//! * at most `max_active` sequences hold KV slots;
//! * no token is generated past `max_new_tokens`;
//! * every admitted request eventually finishes (no starvation: FIFO
//!   admission).

use std::collections::VecDeque;

use crate::serving::request::{InferenceRequest, RequestId};

/// Scheduler tuning.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (KV slots).
    pub max_active: usize,
    /// Admit at most this many prefills per scheduling round (guards
    /// decode latency against prefill bursts — the serving-level analogue
    /// of §3.7's stage split).
    pub max_prefills_per_round: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 4, max_prefills_per_round: 1 }
    }
}

/// One active sequence.
#[derive(Clone, Debug)]
pub struct SeqState {
    pub request: InferenceRequest,
    pub generated: Vec<i32>,
    /// Next position to decode at (prompt length + generated so far).
    pub pos: usize,
    pub prefill_done: bool,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.prefill_done && self.generated.len() >= self.request.max_new_tokens
    }
}

/// What the engine should do next for one scheduling round.
#[derive(Debug, PartialEq, Eq)]
pub enum Action {
    /// Run prefill for this request id.
    Prefill(RequestId),
    /// Run one decode step for this request id.
    Decode(RequestId),
    /// Nothing runnable.
    Idle,
}

/// The scheduler: owns waiting queue + active set.
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    waiting: VecDeque<InferenceRequest>,
    active: Vec<SeqState>,
    prefills_this_round: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, ..Default::default() }
    }

    pub fn submit(&mut self, req: InferenceRequest) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn seq(&self, id: RequestId) -> Option<&SeqState> {
        self.active.iter().find(|s| s.request.id == id)
    }

    pub fn seq_mut(&mut self, id: RequestId) -> Option<&mut SeqState> {
        self.active.iter_mut().find(|s| s.request.id == id)
    }

    /// Decide the next action. Decode-first: active sequences with pending
    /// tokens are served round-robin before new prefills are admitted,
    /// except that up to `max_prefills_per_round` prefills interleave per
    /// round so waiting requests cannot starve while decodes stream.
    pub fn next_action(&mut self) -> Action {
        // 1. Any admitted-but-not-prefilled sequence runs its prefill.
        if let Some(s) = self.active.iter().find(|s| !s.prefill_done) {
            return Action::Prefill(s.request.id);
        }
        // 2. Decode: round-robin the active, unfinished sequences.
        if let Some(idx) = self.active.iter().position(|s| !s.finished()) {
            // Rotate so the chosen sequence moves to the back (fairness).
            let s = self.active.remove(idx);
            let id = s.request.id;
            self.active.push(s);
            self.prefills_this_round = 0;
            return Action::Decode(id);
        }
        // 3. Admit a waiting request if a KV slot is free.
        if self.active.len() < self.cfg.max_active
            && self.prefills_this_round < self.cfg.max_prefills_per_round
        {
            if let Some(req) = self.waiting.pop_front() {
                let pos = req.prompt.len();
                self.active.push(SeqState {
                    request: req,
                    generated: Vec::new(),
                    pos,
                    prefill_done: false,
                });
                self.prefills_this_round += 1;
                let id = self.active.last().unwrap().request.id;
                return Action::Prefill(id);
            }
        }
        Action::Idle
    }

    /// Admission check each round start: pull waiting requests into free
    /// slots (continuous batching: join mid-stream).
    pub fn admit(&mut self) {
        self.prefills_this_round = 0;
        while self.active.len() < self.cfg.max_active {
            match self.waiting.pop_front() {
                Some(req) => {
                    let pos = req.prompt.len();
                    self.active.push(SeqState {
                        request: req,
                        generated: Vec::new(),
                        pos,
                        prefill_done: false,
                    });
                }
                None => break,
            }
        }
    }

    /// Remove and return finished sequences.
    pub fn reap_finished(&mut self) -> Vec<SeqState> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};

    fn req(id: u64, prompt_len: usize, gen: usize) -> InferenceRequest {
        InferenceRequest::new(id, vec![1; prompt_len], gen)
    }

    #[test]
    fn admits_up_to_max_active() {
        let mut s = Scheduler::new(SchedulerConfig { max_active: 2, max_prefills_per_round: 2 });
        for i in 0..5 {
            s.submit(req(i, 16, 4));
        }
        s.admit();
        assert_eq!(s.active_len(), 2);
        assert_eq!(s.waiting_len(), 3);
    }

    #[test]
    fn prefill_before_decode_per_sequence() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(1, 16, 2));
        s.admit();
        assert_eq!(s.next_action(), Action::Prefill(1));
        s.seq_mut(1).unwrap().prefill_done = true;
        assert_eq!(s.next_action(), Action::Decode(1));
    }

    #[test]
    fn round_robin_across_sequences() {
        let mut s = Scheduler::new(SchedulerConfig { max_active: 2, max_prefills_per_round: 2 });
        s.submit(req(1, 16, 10));
        s.submit(req(2, 16, 10));
        s.admit();
        for id in [1, 2] {
            s.seq_mut(id).unwrap().prefill_done = true;
        }
        let a = s.next_action();
        let b = s.next_action();
        assert_ne!(a, b, "round robin must alternate: {a:?} then {b:?}");
    }

    #[test]
    fn finished_sequences_reaped() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(7, 8, 1));
        s.admit();
        s.seq_mut(7).unwrap().prefill_done = true;
        s.seq_mut(7).unwrap().generated.push(42);
        let done = s.reap_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 7);
        assert!(s.is_idle());
    }

    #[test]
    fn property_conservation_and_termination() {
        check("scheduler conserves requests and terminates", Config::cases(50), |rng| {
            let n = 1 + rng.gen_range(12) as usize;
            let max_active = 1 + rng.gen_range(4) as usize;
            let mut s = Scheduler::new(SchedulerConfig {
                max_active,
                max_prefills_per_round: 1 + rng.gen_range(2) as usize,
            });
            for i in 0..n {
                s.submit(req(i as u64, 8, 1 + rng.gen_range(5) as usize));
            }
            let mut finished = 0usize;
            let mut steps = 0usize;
            loop {
                s.admit();
                if s.active_len() > max_active {
                    return Err(format!("active {} > max {max_active}", s.active_len()));
                }
                match s.next_action() {
                    Action::Prefill(id) => {
                        s.seq_mut(id).unwrap().prefill_done = true;
                    }
                    Action::Decode(id) => {
                        let seq = s.seq_mut(id).unwrap();
                        if seq.generated.len() >= seq.request.max_new_tokens {
                            return Err(format!("seq {id} decoded past its budget"));
                        }
                        seq.generated.push(0);
                        seq.pos += 1;
                    }
                    Action::Idle => {}
                }
                finished += s.reap_finished().len();
                if s.is_idle() {
                    break;
                }
                steps += 1;
                if steps > 10_000 {
                    return Err("scheduler did not terminate".into());
                }
            }
            if finished != n {
                return Err(format!("finished {finished} != submitted {n}"));
            }
            Ok(())
        });
    }
}
