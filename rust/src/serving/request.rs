//! Request/response types.

use std::time::Instant;

/// Monotonically assigned request id.
pub type RequestId = u64;

/// One inference request (token ids in; the tokenizer is out of scope —
/// the paper benchmarks token-level throughput).
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
}

impl InferenceRequest {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        InferenceRequest { id, prompt, max_new_tokens, arrival: Instant::now() }
    }
}

/// Completed response with the latency split the benchmarks report.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Queue wait before prefill started.
    pub queue_s: f64,
    pub prefill_s: f64,
    /// Sum of decode step times.
    pub decode_s: f64,
    /// Time to first token: arrival → first emitted token, including any
    /// round-scheduling gaps (queue + prefill when no decode round ran,
    /// i.e. `max_new_tokens ≤ 1`).
    pub ttft_s: f64,
    /// Wall-clock end-to-end.
    pub total_s: f64,
    /// Why the request failed (rejected or errored mid-flight); `None`
    /// for a successful generation. Failed requests still get a response
    /// so one bad request cannot wedge a caller draining a whole batch.
    pub error: Option<String>,
}

impl InferenceResponse {
    /// Decode throughput over the steps that actually ran: the first
    /// token comes straight from prefill logits, so `N` emitted tokens
    /// took `N − 1` decode steps; 0 when no step ran.
    pub fn decode_tokens_per_s(&self) -> f64 {
        let steps = self.tokens.len().saturating_sub(1);
        if self.decode_s <= 0.0 || steps == 0 {
            return 0.0;
        }
        steps as f64 / self.decode_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_throughput() {
        let r = InferenceResponse {
            id: 1,
            tokens: vec![1; 10],
            queue_s: 0.0,
            prefill_s: 0.1,
            decode_s: 0.5,
            ttft_s: 0.15,
            total_s: 0.6,
            error: None,
        };
        // 10 tokens = 9 decode steps (the first came from prefill).
        assert!((r.decode_tokens_per_s() - 18.0).abs() < 1e-9);
    }
}
