//! Model registry + adaptive draft market.
//!
//! The engine stops assuming "one target, at most one draft" here: a
//! [`ModelRegistry`] owns N loaded models — the target plus zero or more
//! draft models, each with its own worst-case-sized paged KV store — and
//! the per-round planning layers on top of it decide, **per sequence and
//! per round**, which draft (if any) proposes and how many tokens it may
//! propose.
//!
//! The market mechanism is Leviathan et al.'s acceptance analysis run
//! against *live* acceptance instead of a static config:
//!
//! * [`AcceptanceEwma`] — a per-sequence exponentially weighted estimate
//!   of the draft/target agreement rate α, fed by every speculative
//!   round's `accepted / proposed` ratio (the same counters
//!   [`crate::serving::Metrics::record_spec`] aggregates engine-wide).
//! * [`SpecRoundCost`] — the three prices the breakeven needs: one draft
//!   decode step, the verify pass at `k = 0` (which IS the plain decode
//!   round, [`crate::sim::exec::verify_time_s`]), and the marginal cost
//!   of each extra verified row. Only *ratios* matter to the decision
//!   (goodput argmax is scale-invariant), so the engine can feed
//!   configured relative costs while the simulator feeds exact
//!   plan-derived ones ([`SpecRoundCost::from_plans`]).
//! * [`DraftController`] — `choose_k` maximizes expected decode goodput
//!   `(1 + E[a](k, α)) / (E[steps](k, α)·D + V(k))` over `k ∈ 0..=k_max`
//!   ([`crate::sim::exec::expected_accepted_tokens`] /
//!   [`expected_draft_steps`]) for a round that must fund its own
//!   weight stream; `choose_k_in_round` prices a member of a
//!   **co-scheduled** round at its marginal cost instead (the stream is
//!   already paid once for the whole round). `k = 0` is plain decode:
//!   low-α traffic stops paying draft overhead entirely — the behaviour
//!   the phone-class (Adreno) profiles need to gate, where a draft
//!   round is a large fraction of a target round.
//!
//! Weight-streaming cost is **billed once per co-scheduled round**: a
//! round's speculative members are grouped by draft index and each group
//! dispatches as one batch against its model, while the target's single
//! mixed-width verify pass covers every group plus the plain-decode
//! members ([`crate::sim::exec::mixed_verify_time_s`]) — so the market
//! prices bids against that shared pass, never charging the stream per
//! dispatch group. The registry only owns models and draft stores — the
//! target's store stays with the engine loop, because it carries
//! engine-level policy (quantized blocks, prefix retention) the drafts
//! never use.
//!
//! **Two-actor split**: the async engine runs planning on a policy
//! thread while the models live on a device thread (PJRT handles are
//! not `Send`). Draft stores are therefore [`SharedKvStore`]s — the
//! policy side claims/releases draft context through the mutex while
//! the device side locks per model call — and [`FleetPolicy`] is the
//! `Send` projection of the registry (dims, widths, prices, store
//! handles, no models) the policy thread plans against.

use std::sync::{Arc, Mutex, MutexGuard};

use crate::kv::{KvArenaConfig, KvSeqHandle, PagedKvStore};
use crate::runtime::tinylm::TinyLmManifest;
use crate::sim::exec::{
    expected_accepted_tokens, expected_draft_steps, simulate_batched, verify_time_s, ExecutionPlan,
};
use crate::util::div_ceil;

/// A paged KV store shared across the policy/device thread boundary.
/// Lock discipline: lock for the duration of one model call or one
/// policy pass, never across a channel send/recv; when a speculative
/// dispatch needs both stores, lock the **target store first**, then
/// the draft store.
pub type SharedKvStore = Arc<Mutex<PagedKvStore>>;

/// The KV-relevant dimensions of a registered model — what store sizing
/// and per-sequence capacity checks need, decoupled from the runtime
/// type so the registry (and its tests) work without PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub layers: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    /// Longest context (prompt + generated) a sequence may reach on this
    /// model — the per-sequence admission ceiling and the worst-case
    /// store-sizing input.
    pub cache_capacity: usize,
}

impl ModelDims {
    /// Dimensions of a loaded TinyLM artifact set.
    pub fn of(m: &TinyLmManifest) -> ModelDims {
        ModelDims {
            layers: m.layers,
            heads_kv: m.heads_kv,
            head_dim: m.head_dim,
            cache_capacity: m.cache_capacity,
        }
    }
}

/// Exponentially weighted estimate of the per-token draft/target
/// agreement rate α for **one sequence**, fed one observation per
/// speculative round.
///
/// The observation is the round's `accepted / proposed` ratio. For a
/// longest-prefix accept with `k` proposals that ratio's expectation is
/// `E[a](k, α) / k ≤ α`, so the estimate is a *downward-biased* α — the
/// controller therefore errs toward smaller `k`, which is the safe
/// direction (under-speculating costs rounds, over-speculating costs
/// wasted draft and verify work on phone-class profiles).
#[derive(Clone, Copy, Debug)]
pub struct AcceptanceEwma {
    weight: f64,
    value: Option<f64>,
}

impl AcceptanceEwma {
    /// `weight` ∈ (0, 1]: how much one round moves the estimate
    /// (1.0 = last round only).
    pub fn new(weight: f64) -> AcceptanceEwma {
        AcceptanceEwma { weight: weight.clamp(1e-3, 1.0), value: None }
    }

    /// Fold in one speculative round's outcome. Rounds that proposed
    /// nothing carry no acceptance information and are ignored.
    pub fn observe(&mut self, proposed: usize, accepted: usize) {
        if proposed == 0 {
            return;
        }
        let obs = (accepted.min(proposed)) as f64 / proposed as f64;
        self.value = Some(match self.value {
            Some(v) => self.weight * obs + (1.0 - self.weight) * v,
            None => obs,
        });
    }

    /// Current α estimate; `None` until the first observed round (the
    /// controller then falls back to its configured prior).
    pub fn estimate(&self) -> Option<f64> {
        self.value
    }
}

/// The three prices the draft-k breakeven is computed from. All the
/// controller consumes are *ratios*, so any consistent unit works:
/// the simulator builds exact roofline seconds from the plans
/// ([`SpecRoundCost::from_plans`]); the engine, which cannot decompose a
/// measured speculative step into draft/verify shares, feeds configured
/// relative costs ([`SpecRoundCost::relative`]).
#[derive(Clone, Copy, Debug)]
pub struct SpecRoundCost {
    /// One draft decode step (at the round's draft-batch width).
    pub draft_step_s: f64,
    /// The verify pass at `k = 0` — exactly the plain decode round
    /// ([`crate::sim::cost::KernelCost::speculative_verify_total`]).
    pub verify_base_s: f64,
    /// Marginal cost of each extra verified row beyond the base.
    pub verify_row_s: f64,
}

impl SpecRoundCost {
    /// Relative costs for the engine side: the plain round is the unit,
    /// each extra verified row costs `verify_row` of it, and a draft
    /// step costs `draft_step` of it. The B=1 CPU artifact scores verify
    /// positions sequentially, so `verify_row = 1.0` is its honest
    /// setting; roofline GPU profiles sit far below 1.
    pub fn relative(draft_step: f64, verify_row: f64) -> SpecRoundCost {
        SpecRoundCost {
            draft_step_s: draft_step.max(0.0),
            verify_base_s: 1.0,
            verify_row_s: verify_row.max(0.0),
        }
    }

    /// Exact roofline prices at batch width `batch`: one draft round,
    /// the `k = 0` verify pass, and the secant slope of the verify cost
    /// over `k ∈ [0, k_max]` (the verify curve is concave in `k` —
    /// weights stream once — so the secant under-prices small `k`
    /// slightly, again the conservative direction).
    pub fn from_plans(
        draft_plan: &ExecutionPlan,
        target_decode_plan: &ExecutionPlan,
        batch: usize,
        k_max: usize,
    ) -> SpecRoundCost {
        let base = verify_time_s(target_decode_plan, batch, 0);
        let k = k_max.max(1);
        let slope = (verify_time_s(target_decode_plan, batch, k) - base) / k as f64;
        SpecRoundCost {
            draft_step_s: simulate_batched(draft_plan, batch).total_s,
            verify_base_s: base,
            verify_row_s: slope.max(0.0),
        }
    }

    /// Verify-pass price at draft width `k`.
    pub fn verify_s(&self, k: usize) -> f64 {
        self.verify_base_s + k as f64 * self.verify_row_s
    }

    /// Expected whole-round price at width `k`, acceptance `alpha`:
    /// `E[steps](k, α) · D + V(k)` — the same split as
    /// [`crate::sim::exec::speculative_round_time_s`]. `k = 0` is the
    /// plain round exactly.
    pub fn round_s(&self, k: usize, alpha: f64) -> f64 {
        expected_draft_steps(k, alpha) * self.draft_step_s + self.verify_s(k)
    }

    /// Expected emitted tokens per second of round time at width `k`:
    /// `(1 + E[a](k, α)) / round_s(k, α)`.
    pub fn goodput(&self, k: usize, alpha: f64) -> f64 {
        let t = self.round_s(k, alpha);
        if t <= 0.0 {
            return 0.0;
        }
        (1.0 + expected_accepted_tokens(k, alpha)) / t
    }

    /// Verify cost of width `k` **beyond the round's base pass**: in a
    /// co-scheduled round the target streams its weights once for the
    /// whole mixed batch ([`crate::sim::exec::mixed_verify_time_s`]), so
    /// a member's width only adds `k` marginal rows — the base pass is
    /// the plain decode the member runs regardless of its bid.
    pub fn verify_marginal_s(&self, k: usize) -> f64 {
        k as f64 * self.verify_row_s
    }

    /// Marginal whole-round price of width `k` when the target's base
    /// pass (its weight stream) is already billed to the co-scheduled
    /// round: draft steps plus marginal verify rows only. `k = 0` is
    /// free — the member rides the round it was going to decode in
    /// anyway.
    pub fn round_s_shared(&self, k: usize, alpha: f64) -> f64 {
        expected_draft_steps(k, alpha) * self.draft_step_s + self.verify_marginal_s(k)
    }
}

/// Per-sequence draft-width controller: the pure breakeven math shared
/// by the engine loops and the fleet serving simulator, so the two can
/// never disagree about when speculation pays.
#[derive(Clone, Copy, Debug)]
pub struct DraftController {
    /// Largest width the draft's config allows.
    pub k_max: usize,
    /// α assumed before the first observed round (optimism here buys the
    /// signal: a sequence must speculate at least once for the EWMA to
    /// learn anything).
    pub prior_alpha: f64,
    /// A speculative width must beat plain decode's goodput by this
    /// factor to be chosen (> 1 adds hysteresis so borderline traffic
    /// does not flap between `k = 0` and `k = 1` on EWMA noise).
    pub hysteresis: f64,
}

impl Default for DraftController {
    fn default() -> Self {
        DraftController { k_max: 4, prior_alpha: 0.6, hysteresis: 1.05 }
    }
}

impl DraftController {
    /// Pick the width maximizing expected goodput at the live α
    /// estimate; `0` means this round decodes plainly. Ties and
    /// within-hysteresis wins go to the *smaller* k.
    pub fn choose_k(&self, alpha: Option<f64>, cost: &SpecRoundCost) -> usize {
        let a = alpha.unwrap_or(self.prior_alpha).clamp(0.0, 1.0);
        let plain = cost.goodput(0, a);
        let mut best_k = 0;
        let mut best = plain * self.hysteresis.max(1.0);
        for k in 1..=self.k_max {
            let g = cost.goodput(k, a);
            if g > best {
                best = g;
                best_k = k;
            }
        }
        best_k
    }

    /// Width choice for a member of a **co-scheduled round**. With
    /// `target_stream_paid` the target's weight stream is already billed
    /// once for the whole round — plain members and every draft group
    /// share one mixed verify pass — so the member's bid is priced at
    /// its *marginal* cost ([`SpecRoundCost::round_s_shared`]): width
    /// `k` buys `E[a](k, α)` extra tokens for `E[steps]·D + k·rows`
    /// extra seconds. The chosen width maximizes the net token yield at
    /// the plain round's exchange rate (one token per `verify_base_s`),
    /// under the same hysteresis margin; `k = 0` (net zero) wins unless
    /// some width clears it. Without `target_stream_paid` — a dedicated
    /// round that must fund its own weight stream — this is exactly
    /// [`choose_k`](Self::choose_k).
    ///
    /// Every width [`choose_k`](Self::choose_k) accepts clears the
    /// shared test too (the dedicated price includes the base the
    /// shared price omits), so switching a round to shared pricing can
    /// only move traffic *into* speculation, never out of it.
    pub fn choose_k_in_round(
        &self,
        alpha: Option<f64>,
        cost: &SpecRoundCost,
        target_stream_paid: bool,
    ) -> usize {
        if !target_stream_paid {
            return self.choose_k(alpha, cost);
        }
        let a = alpha.unwrap_or(self.prior_alpha).clamp(0.0, 1.0);
        let base = cost.verify_s(0);
        if base <= 0.0 {
            return 0;
        }
        let h = self.hysteresis.max(1.0);
        let mut best_k = 0;
        let mut best = 0.0; // net gain of riding the round plainly
        for k in 1..=self.k_max {
            let gain = expected_accepted_tokens(k, a) - h * cost.round_s_shared(k, a) / base;
            if gain > best {
                best = gain;
                best_k = k;
            }
        }
        best_k
    }
}

/// One registered draft: the loaded model, its KV dimensions, its own
/// paged store, and the market parameters the controller prices it with.
pub struct DraftSlot<M> {
    pub model: M,
    pub dims: ModelDims,
    /// Width ceiling for this draft.
    pub k_max: usize,
    /// Relative (or plan-derived) round prices for this draft.
    pub cost: SpecRoundCost,
    /// The draft's own paged KV store, worst-case sized at registration
    /// (`max_active` full-capacity sequences) so draft growth can never
    /// be the thing that preempts — the target store stays the contended
    /// resource. Shared so the policy thread can claim/release draft
    /// context while the device thread owns the model.
    pub store: SharedKvStore,
}

/// Owner of the N loaded models a fleet-serving engine runs: the target
/// plus zero or more drafts (each with its own store). Generic over the
/// model type so the policy layer is unit-testable without PJRT.
pub struct ModelRegistry<M> {
    target: M,
    target_dims: ModelDims,
    drafts: Vec<DraftSlot<M>>,
}

impl<M> ModelRegistry<M> {
    pub fn new(target: M, target_dims: ModelDims) -> ModelRegistry<M> {
        ModelRegistry { target, target_dims, drafts: Vec::new() }
    }

    /// Register a draft and build its worst-case-sized paged store
    /// (`max_active × ceil(cache_capacity / block_tokens)` blocks — the
    /// same sizing rule the single-draft engine used). Registration
    /// order is assignment priority ([`assign_draft`](Self::assign_draft)).
    /// Returns the draft's index.
    pub fn add_draft(
        &mut self,
        model: M,
        dims: ModelDims,
        k_max: usize,
        cost: SpecRoundCost,
        max_active: usize,
        block_tokens: usize,
    ) -> usize {
        let store = Arc::new(Mutex::new(PagedKvStore::new(KvArenaConfig {
            layers: dims.layers,
            heads_kv: dims.heads_kv,
            head_dim: dims.head_dim,
            block_tokens,
            num_blocks: max_active.max(1) * div_ceil(dims.cache_capacity.max(1), block_tokens),
        })));
        self.drafts.push(DraftSlot { model, dims, k_max: k_max.max(1), cost, store });
        self.drafts.len() - 1
    }

    pub fn target(&self) -> &M {
        &self.target
    }

    pub fn target_dims(&self) -> ModelDims {
        self.target_dims
    }

    pub fn num_drafts(&self) -> usize {
        self.drafts.len()
    }

    pub fn draft_dims(&self, i: usize) -> ModelDims {
        self.drafts[i].dims
    }

    pub fn draft_k_max(&self, i: usize) -> usize {
        self.drafts[i].k_max
    }

    /// Assign a draft for a sequence whose context may reach
    /// `total_tokens`: the first registered draft whose capacity covers
    /// it (registration order is priority — callers list preferred
    /// drafts first). `None` → the sequence decodes plainly for life.
    pub fn assign_draft(&self, total_tokens: usize) -> Option<usize> {
        self.drafts.iter().position(|d| total_tokens <= d.dims.cache_capacity)
    }

    /// Width for one sequence's next round on draft `i`: static `k_max`
    /// when the market is off, otherwise the controller's breakeven
    /// argmax at the sequence's live α estimate. Engine rounds always
    /// co-schedule the member with the round's base verify pass (the
    /// pending token decodes this round whatever the bid), so the
    /// market prices the bid at its marginal cost
    /// ([`DraftController::choose_k_in_round`] with the target's weight
    /// stream already paid) — never once per dispatch group.
    pub fn plan_k(&self, i: usize, alpha: Option<f64>, adaptive: bool) -> usize {
        let d = &self.drafts[i];
        if !adaptive {
            return d.k_max;
        }
        DraftController { k_max: d.k_max, ..DraftController::default() }
            .choose_k_in_round(alpha, &d.cost, true)
    }

    /// Lock draft `i`'s store for one policy pass or model call. The
    /// guard derefs to the store, so `reg.draft_store(i).len(h)` reads
    /// as before; hold it only within one stage, never across a channel.
    pub fn draft_store(&self, i: usize) -> MutexGuard<'_, PagedKvStore> {
        self.drafts[i].store.lock().expect("draft store lock poisoned")
    }

    /// The shared handle to draft `i`'s store (for a policy view or a
    /// cross-thread companion claim).
    pub fn draft_store_arc(&self, i: usize) -> SharedKvStore {
        Arc::clone(&self.drafts[i].store)
    }

    /// One draft group's dispatch parts: the target model, draft `i`'s
    /// model, and the locked draft store (the target's own store lives
    /// with the caller; lock it before calling this).
    pub fn spec_parts(&self, i: usize) -> (&M, &M, MutexGuard<'_, PagedKvStore>) {
        let d = &self.drafts[i];
        (&self.target, &d.model, d.store.lock().expect("draft store lock poisoned"))
    }

    /// Release a sequence's blocks in draft `i`'s store; returns freed
    /// device bytes.
    pub fn release_draft(&self, i: usize, h: KvSeqHandle) -> usize {
        self.draft_store(i).release(h)
    }

    /// The `Send` projection the async engine's policy thread plans
    /// against: every per-draft decision input (dims, width ceiling,
    /// round prices, the shared store) without the models.
    pub fn policy_view(&self) -> FleetPolicy {
        FleetPolicy {
            target_dims: self.target_dims,
            drafts: self
                .drafts
                .iter()
                .map(|d| DraftPolicy {
                    dims: d.dims,
                    k_max: d.k_max,
                    cost: d.cost,
                    store: Arc::clone(&d.store),
                })
                .collect(),
        }
    }
}

/// Policy-side view of one registered draft: everything
/// [`ModelRegistry`] knows about it except the model.
#[derive(Clone)]
pub struct DraftPolicy {
    pub dims: ModelDims,
    pub k_max: usize,
    pub cost: SpecRoundCost,
    pub store: SharedKvStore,
}

/// The `Send` half of a [`ModelRegistry`]: the async engine's policy
/// thread holds this (assignment, width planning, draft-store claims
/// and releases) while the device thread holds the registry itself —
/// the models never cross the boundary, the store handles do.
#[derive(Clone)]
pub struct FleetPolicy {
    target_dims: ModelDims,
    drafts: Vec<DraftPolicy>,
}

impl FleetPolicy {
    pub fn target_dims(&self) -> ModelDims {
        self.target_dims
    }

    pub fn num_drafts(&self) -> usize {
        self.drafts.len()
    }

    /// Same first-fit rule as [`ModelRegistry::assign_draft`].
    pub fn assign_draft(&self, total_tokens: usize) -> Option<usize> {
        self.drafts.iter().position(|d| total_tokens <= d.dims.cache_capacity)
    }

    /// Same market rule as [`ModelRegistry::plan_k`] (shared-round
    /// pricing: the round's weight stream is billed once, not per
    /// dispatch group).
    pub fn plan_k(&self, i: usize, alpha: Option<f64>, adaptive: bool) -> usize {
        let d = &self.drafts[i];
        if !adaptive {
            return d.k_max;
        }
        DraftController { k_max: d.k_max, ..DraftController::default() }
            .choose_k_in_round(alpha, &d.cost, true)
    }

    pub fn draft_store(&self, i: usize) -> MutexGuard<'_, PagedKvStore> {
        self.drafts[i].store.lock().expect("draft store lock poisoned")
    }

    pub fn draft_store_arc(&self, i: usize) -> SharedKvStore {
        Arc::clone(&self.drafts[i].store)
    }

    pub fn release_draft(&self, i: usize, h: KvSeqHandle) -> usize {
        self.draft_store(i).release(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(cap: usize) -> ModelDims {
        ModelDims { layers: 2, heads_kv: 2, head_dim: 8, cache_capacity: cap }
    }

    /// A registry of unit models: the policy under test never touches
    /// the model values.
    fn registry(caps: &[usize]) -> ModelRegistry<()> {
        let mut reg = ModelRegistry::new((), dims(256));
        for &c in caps {
            reg.add_draft((), dims(c), 4, SpecRoundCost::relative(0.2, 0.3), 4, 16);
        }
        reg
    }

    #[test]
    fn ewma_tracks_acceptance_and_starts_empty() {
        let mut e = AcceptanceEwma::new(0.5);
        assert_eq!(e.estimate(), None);
        e.observe(4, 0); // a fully-rejected round IS information: α ≈ 0
        assert_eq!(e.estimate(), Some(0.0));
        e.observe(4, 4);
        assert_eq!(e.estimate(), Some(0.5));
        e.observe(4, 4);
        assert_eq!(e.estimate(), Some(0.75));
        // Zero-proposal rounds carry no information.
        e.observe(0, 0);
        assert_eq!(e.estimate(), Some(0.75));
        // Converges to a steady observed rate.
        let mut c = AcceptanceEwma::new(0.3);
        for _ in 0..64 {
            c.observe(4, 3);
        }
        assert!((c.estimate().unwrap() - 0.75).abs() < 1e-6);
    }

    #[test]
    fn controller_speculates_on_high_alpha_and_drops_to_plain_on_low() {
        // A cheap draft (20% of a round per step, 30% per verify row).
        let cost = SpecRoundCost::relative(0.2, 0.3);
        let ctl = DraftController { k_max: 4, prior_alpha: 0.6, hysteresis: 1.05 };
        let hi = ctl.choose_k(Some(0.9), &cost);
        assert!(hi >= 2, "high acceptance should buy width, got {hi}");
        assert_eq!(ctl.choose_k(Some(0.05), &cost), 0, "low-α traffic decodes plainly");
        assert_eq!(ctl.choose_k(Some(0.0), &cost), 0);
        // Monotone-ish: width never shrinks when acceptance rises.
        let mut prev = 0;
        for a in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let k = ctl.choose_k(Some(a), &cost);
            assert!(k >= prev, "k({a}) = {k} < k(prev) = {prev}");
            prev = k;
        }
    }

    #[test]
    fn controller_refuses_an_expensive_draft_even_at_decent_alpha() {
        // Phone-class shape: a draft step costs 90% of a target round
        // and every verify row is a full sequential step. Speculation
        // cannot pay at moderate acceptance — the market must sit out.
        let cost = SpecRoundCost::relative(0.9, 1.0);
        let ctl = DraftController { k_max: 4, prior_alpha: 0.6, hysteresis: 1.05 };
        assert_eq!(ctl.choose_k(Some(0.6), &cost), 0);
        // Near-perfect acceptance still wins: (1 + E[a]) grows while the
        // catch-up term stays bounded.
        assert!(ctl.choose_k(Some(0.99), &cost) >= 1);
    }

    #[test]
    fn goodput_at_k0_is_the_plain_round_exactly() {
        let cost = SpecRoundCost::relative(0.25, 0.4);
        assert!((cost.round_s(0, 0.7) - cost.verify_base_s).abs() < 1e-12);
        assert!((cost.goodput(0, 0.7) - 1.0 / cost.verify_base_s).abs() < 1e-12);
    }

    #[test]
    fn shared_round_pricing_flips_borderline_alpha_into_speculation() {
        // A cheap draft at modest acceptance: a dedicated round cannot
        // fund the target's weight stream, so `choose_k` sits out — but
        // in a co-scheduled round the stream is already paid and the
        // marginal price of one proposal row clears.
        let cost = SpecRoundCost::relative(0.1, 0.1);
        let ctl = DraftController { k_max: 4, prior_alpha: 0.6, hysteresis: 1.05 };
        let a = Some(0.25);
        assert_eq!(ctl.choose_k(a, &cost), 0, "dedicated pricing sits out");
        assert_eq!(
            ctl.choose_k_in_round(a, &cost, false),
            0,
            "unshared mode must match choose_k exactly"
        );
        assert_eq!(ctl.choose_k_in_round(a, &cost, true), 1, "marginal pricing bids width 1");
        // One-way containment: any α the dedicated market speculates at,
        // the shared market does too (its price omits the paid base).
        for a in [0.05, 0.15, 0.25, 0.35, 0.5, 0.65, 0.8, 0.9, 0.99] {
            let dedicated = ctl.choose_k(Some(a), &cost);
            let shared = ctl.choose_k_in_round(Some(a), &cost, true);
            assert!(
                dedicated == 0 || shared >= 1,
                "α = {a}: dedicated bid {dedicated} but shared sat out"
            );
        }
        // k = 0 is free in a co-scheduled round; the marginal prices are
        // exactly the row/draft terms.
        assert_eq!(cost.round_s_shared(0, 0.7), 0.0);
        assert!((cost.verify_marginal_s(3) - 0.3).abs() < 1e-12);
        assert!((cost.round_s(2, 0.5) - cost.round_s_shared(2, 0.5) - cost.verify_base_s).abs()
            < 1e-12);
    }

    #[test]
    fn prior_alpha_drives_the_cold_start() {
        let cost = SpecRoundCost::relative(0.2, 0.3);
        let optimist = DraftController { k_max: 4, prior_alpha: 0.9, hysteresis: 1.0 };
        let pessimist = DraftController { k_max: 4, prior_alpha: 0.0, hysteresis: 1.0 };
        assert!(optimist.choose_k(None, &cost) >= 1, "optimistic prior buys the signal");
        assert_eq!(pessimist.choose_k(None, &cost), 0);
    }

    #[test]
    fn assign_draft_is_first_fit_in_registration_order() {
        let reg = registry(&[64, 256]);
        assert_eq!(reg.assign_draft(32), Some(0), "first draft fits: preferred");
        assert_eq!(reg.assign_draft(128), Some(1), "too long for draft 0, fits draft 1");
        assert_eq!(reg.assign_draft(1024), None, "nobody fits: plain decode for life");
        assert_eq!(registry(&[]).assign_draft(1), None, "no drafts registered");
    }

    #[test]
    fn draft_stores_are_worst_case_sized_per_draft() {
        let reg = registry(&[64, 250]);
        // max_active (4) × ceil(cap / block_tokens (16)) blocks each.
        assert_eq!(reg.draft_store(0).config().num_blocks, 4 * 4);
        assert_eq!(reg.draft_store(1).config().num_blocks, 4 * 16);
    }

    #[test]
    fn spec_parts_yields_models_plus_locked_store_and_claims_work() {
        let reg = registry(&[64]);
        let h = reg.draft_store(0).claim(32).unwrap();
        let (_target, _draft, mut store) = reg.spec_parts(0);
        store.append(h, 16).unwrap();
        drop(store); // non-reentrant lock: release before re-locking below
        assert_eq!(reg.draft_store(0).len(h), 16);
        let freed = reg.release_draft(0, h);
        assert!(freed > 0, "releasing a claimed sequence frees device bytes");
    }

    #[test]
    fn policy_view_mirrors_the_registry_and_shares_its_stores() {
        let reg = registry(&[64, 256]);
        let view = reg.policy_view();
        // The view is Send — the property the device split depends on.
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&view);
        assert_eq!(view.num_drafts(), 2);
        assert_eq!(view.assign_draft(32), reg.assign_draft(32));
        assert_eq!(view.assign_draft(128), reg.assign_draft(128));
        assert_eq!(view.assign_draft(1024), None);
        assert_eq!(view.plan_k(0, Some(0.95), true), reg.plan_k(0, Some(0.95), true));
        assert_eq!(view.plan_k(0, Some(0.01), false), reg.plan_k(0, Some(0.01), false));
        assert_eq!(view.target_dims(), reg.target_dims());
        // Same store, not a copy: a claim through the view is visible
        // through the registry.
        let h = view.draft_store(0).claim(16).unwrap();
        assert_eq!(reg.draft_store(0).len(h), 0);
        view.draft_store(0).append(h, 8).unwrap();
        assert_eq!(reg.draft_store(0).len(h), 8);
        assert!(view.release_draft(0, h) > 0);
    }

    #[test]
    fn plan_k_static_vs_adaptive() {
        let mut reg = ModelRegistry::new((), dims(256));
        reg.add_draft((), dims(256), 4, SpecRoundCost::relative(0.2, 0.3), 4, 16);
        assert_eq!(reg.plan_k(0, Some(0.01), false), 4, "market off: static k_max");
        assert_eq!(reg.plan_k(0, Some(0.01), true), 0, "market on: low α sits out");
        assert!(reg.plan_k(0, Some(0.95), true) >= 2);
    }
}
