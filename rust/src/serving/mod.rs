//! The L3 serving coordinator: request routing, continuous batching,
//! stage-aware scheduling, and metrics.
//!
//! The paper's contribution is the inference engine; this layer is the
//! coordinator a deployment wraps around it (the vLLM-router shape):
//!
//! * [`request`] — request/response types with per-stage timing.
//! * [`admission`] — KV admission policy: worst-case (lifetime) vs
//!   **expected-footprint** gating (mean generation length × safety
//!   margin), the knob that converts internal fragmentation into batch
//!   occupancy.
//! * [`scheduler`] — a **round-based** continuous-batching scheduler:
//!   each round packs *all* runnable decodes into one batch (weights
//!   stream once per round) plus a capped number of prefills,
//!   decode-first to protect inter-token latency — mirroring §3.7's
//!   prefill/decode split at the serving level.
//! * [`server`] — the policy actor: a thread-based engine that runs
//!   scheduling, admission, round planning, and reaping over a shared
//!   **paged** KV arena ([`crate::kv::KvArena`]: prompt-only claims,
//!   on-demand block growth, preemption on exhaustion) with
//!   backpressure-gated admission, and serves a channel of requests (no
//!   Python, no async runtime).
//! * [`device`] — the device actor: at `pipeline_depth ≥ 2` the model
//!   runtimes live on a dedicated thread fed fully-bound round
//!   descriptors over a bounded channel, so round N+1's host-side plan
//!   genuinely overlaps round N's execution in wall-clock time.
//! * [`registry`] — the multi-model fleet: a registry owning the target
//!   plus zero-or-more draft models (each with its own worst-case-sized
//!   paged store), and the **adaptive draft market** — a per-sequence
//!   EWMA acceptance estimate bid against the speculative-round
//!   breakeven to pick draft/k per round (k = 0 ⇒ plain decode).
//! * [`metrics`] — TTFT / latency / throughput / batch-occupancy
//!   accounting.

pub mod admission;
pub mod device;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod registry;
pub mod metrics;

pub use admission::{blended_mean_gen, AdmissionPolicy};
pub use request::{InferenceRequest, InferenceResponse, RequestId};
pub use scheduler::{
    default_prefill_chunk_tokens, ChunkAutotuner, PrefillChunk, Round, Scheduler, SchedulerConfig,
    SeqState,
};
pub use server::{
    DraftModelConfig, EngineConfig, FleetConfig, SampledSpecConfig, ServerStats, ServingEngine,
    SpecConfig,
};
pub use registry::{
    AcceptanceEwma, DraftController, DraftPolicy, FleetPolicy, ModelDims, ModelRegistry,
    SharedKvStore, SpecRoundCost,
};
pub use metrics::Metrics;
