//! The serving engine: a worker thread owning the PJRT runtime, a
//! round-based continuous-batching scheduler, a shared KV arena, and
//! per-sequence KV state.
//!
//! Each iteration of the worker loop executes one scheduling **round**:
//! the decode batch first (one step for every active sequence — weights
//! stream once per round on the simulated GPU), then the round's
//! **prefill pack** — up to `max_prefills_per_round` chunk quanta, from
//! multiple sequences when [`SchedulerConfig::prefill_chunk_tokens`]
//! enables chunking, executed as one flattened GEMM
//! ([`TinyLmRuntime::prefill_pack`]). Partial chunks deposit KV rows
//! through the provisional-scatter seam and commit at chunk boundaries;
//! only the final chunk's logits produce the sequence's first token, so
//! TTFT attributes to the round that ran it — and a long prompt no
//! longer head-of-line-blocks every later arrival's first token.
//!
//! KV is **paged and device-resident**: every sequence's K/V rows live
//! in one shared contiguous block region ([`PagedKvStore`]) addressed
//! through per-sequence block tables — there are no dense per-sequence
//! KV tensors anywhere in the engine. Admission claims (and commits)
//! only the context that must prefill now (the prompt, or prompt +
//! generated for a re-admitted sequence), gated by the *expected*
//! footprint ([`AdmissionPolicy`]) fed the survivorship-corrected
//! blended mean; each decode step gathers the sequence's blocks into the
//! dense §3.8 layouts (bit-identical to the dense path), scatters the
//! new row back through the block table, and grows the reservation
//! block-by-block ([`PagedKvStore::ensure`]). A request whose expected
//! footprint does not fit is *deferred* (stays queued), never failed;
//! genuine exhaustion mid-round **preempts** a victim (lowest-progress,
//! youngest, never the FIFO head) back to the re-admission queue — and
//! because the store backs blocks with real storage, that eviction
//! scrubs and releases real device bytes (watched by the
//! `kv_device_bytes_*` gauges), not just arena accounting. The victim
//! re-prefills its whole context on re-admission — recompute semantics,
//! so eviction costs latency, never tokens.
//!
//! **Speculative decoding** ([`ServingEngine::start_speculative`]): a
//! draft model registered next to the target proposes `k` tokens per
//! sequence per round; the target verifies all `k + 1` positions and the
//! longest matching prefix is emitted in one round (tokens/round >
//! batch occupancy — the gap `Metrics::tokens_per_round` exists to
//! show). Draft KV lives in its own worst-case-sized paged store;
//! rejected provisional rows are scrubbed via the
//! [`PagedKvStore::commit_provisional`] rollback seam; admission claims
//! draft context alongside target context; eviction and reap release
//! both. Output is token-identical to plain greedy decode by
//! construction — see [`crate::runtime::speculative_step_greedy`].
//!
//! **Fleet serving** ([`FleetConfig`]): the engine generalizes from
//! "one target, at most one draft" to a [`ModelRegistry`] owning the
//! target plus zero-or-more drafts, each with its own worst-case-sized
//! paged store. A sequence binds to at most one draft for its lifetime
//! (first registered draft whose capacity covers it); the per-round
//! width comes from the **adaptive draft market** — a per-sequence
//! [`AcceptanceEwma`] over live `accepted/proposed` bid against the
//! draft's [`SpecRoundCost`] breakeven, so low-α traffic drops to plain
//! decode (`k = 0`) instead of paying draft overhead. Speculative
//! members are grouped by draft index and each group dispatches as one
//! batch against its model — weight-streaming cost is shared only
//! within a model's batch. With [`FleetConfig::sampled`] set, verify
//! runs the sampling-correct rejection rule (`min(1, p_t/p_d)` +
//! residual resampling, [`crate::runtime::speculative_step_sampled`])
//! so temperature traffic is served speculatively too; greedy traffic
//! (`sampled: None`) stays bit-identical to plain decode.
//!
//! **Truly-async execution** (`pipeline_depth ≥ 2`, or
//! [`EngineConfig::force_async`]): the worker splits into two actors —
//! this thread keeps scheduler/admission/plan/reap (the policy side of
//! the `KvPool` seam) while a dedicated **device thread**
//! ([`crate::serving::device`]) owns the loaded models and executes
//! fully-bound round descriptors from a bounded submission channel, so
//! plan for round N+1 genuinely overlaps execution of round N in wall
//! clock. Depth 1 without `force_async` still routes to the untouched
//! serial loop.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{DriftError, Result};
use crate::kv::{
    shareable_prefix_keys, KvArenaConfig, KvSeqHandle, KvSlotWindow, PagedKvStore, PrefixKey,
};
use crate::runtime::backend::{FakeLmBackend, FakeLmConfig, LmBackend};
use crate::runtime::tinylm::{
    PackedPrefillChunk, PagedRoundStep, SpecStepArgs, TinyLmManifest,
};
use crate::serving::admission::AdmissionPolicy;
use crate::serving::device::{self, DraftPrefillJob, FleetRuntime, RoundDescriptor};
use crate::serving::metrics::Metrics;
use crate::serving::registry::{
    AcceptanceEwma, ModelDims, ModelRegistry, SpecRoundCost,
};
use crate::serving::request::{InferenceRequest, InferenceResponse, RequestId};
use crate::serving::scheduler::{ChunkAutotuner, Scheduler, SchedulerConfig};
use crate::util::rng::Pcg32;

/// KV-arena allocation granule (token positions per block). 16 divides
/// every prefill bucket and keeps worst-case internal fragmentation to
/// 15 positions per sequence.
pub(crate) const KV_BLOCK_TOKENS: usize = 16;

enum Msg {
    Request(InferenceRequest, Sender<InferenceResponse>),
    Shutdown,
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub tokens_generated: u64,
    pub report: String,
}

/// Speculative-decode configuration: a draft model registered next to
/// the target. Greedy draft-k: each round the draft proposes `draft_k`
/// tokens per sequence, the target verifies all `k + 1` positions, the
/// longest matching prefix is accepted and rejected KV rows are rolled
/// back — output is token-identical to plain greedy decode whatever the
/// draft proposes ([`crate::runtime::speculative_step_greedy`]).
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Artifacts directory of the draft model (a truncated/distilled
    /// TinyLM; pointing it at the target's own artifacts gives
    /// acceptance = k by construction — the e2e identity fixture).
    pub draft_artifacts_dir: String,
    /// Draft proposals per sequence per round (clamped to ≥ 1).
    pub draft_k: usize,
}

/// One draft model in a fleet: its artifacts, its width ceiling, and
/// the relative round prices the adaptive controller bids with.
#[derive(Clone, Debug)]
pub struct DraftModelConfig {
    /// Artifacts directory of this draft model.
    pub artifacts_dir: String,
    /// Width ceiling for this draft (clamped to ≥ 1).
    pub k_max: usize,
    /// Round prices for the draft/k breakeven. The engine cannot
    /// decompose a measured speculative step into draft/verify shares,
    /// so it feeds configured *relative* costs
    /// ([`SpecRoundCost::relative`]; the B=1 CPU artifact scores verify
    /// rows sequentially — `relative(d, 1.0)` is its honest setting).
    pub cost: SpecRoundCost,
}

/// Sampling-correct speculative verification: draft proposals are
/// sampled at `temperature`, and verify accepts each with probability
/// `min(1, p_target/p_draft)` (residual resampling on rejection —
/// [`crate::runtime::speculative_step_sampled`]), so the emitted stream
/// is distributed exactly as target-only sampling. `temperature ≈ 0`
/// degenerates to bitwise greedy.
#[derive(Clone, Copy, Debug)]
pub struct SampledSpecConfig {
    pub temperature: f64,
    /// Seed for the engine's deterministic sampling RNG.
    pub seed: u64,
}

/// Multi-model fleet configuration: the draft models registered next to
/// the target and the market/sampling toggles. Supersedes [`SpecConfig`]
/// (which maps onto a one-draft static greedy fleet internally).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Draft models in assignment-priority order: a sequence binds to
    /// the FIRST draft whose capacity covers its lifetime context.
    pub drafts: Vec<DraftModelConfig>,
    /// `true` — the adaptive draft market: per-sequence k from the live
    /// acceptance EWMA vs the breakeven (k = 0 ⇒ plain decode).
    /// `false` — static `k_max` per draft, the legacy behavior.
    pub adaptive_k: bool,
    /// EWMA weight for the per-sequence acceptance estimates
    /// ([`AcceptanceEwma::new`]).
    pub ewma_weight: f64,
    /// `Some` — serve temperature traffic speculatively with the
    /// rejection rule; `None` — greedy draft/verify, token-identical to
    /// plain decode.
    pub sampled: Option<SampledSpecConfig>,
}

impl FleetConfig {
    /// Adaptive greedy fleet with the default EWMA weight.
    pub fn new(drafts: Vec<DraftModelConfig>) -> FleetConfig {
        FleetConfig { drafts, adaptive_k: true, ewma_weight: 0.3, sampled: None }
    }
}

/// Full engine configuration: the scheduler policy knobs plus the
/// engine-level toggles PR 7 plumbs through one front door. The legacy
/// constructors ([`ServingEngine::start`] and friends) build a depth-1,
/// fp32, no-retention config — byte-identical to the engine they
/// replaced — while [`ServingEngine::start_with_config`] exposes the
/// pipelined executor, int8 KV blocks, and prefix-cache retention.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub sched: SchedulerConfig,
    pub policy: AdmissionPolicy,
    /// Legacy single-draft speculative decoding; internally mapped to a
    /// one-draft static greedy [`FleetConfig`] (ignored when `fleet` is
    /// set).
    pub spec: Option<SpecConfig>,
    /// Multi-model fleet serving: N drafts + the adaptive draft market.
    pub fleet: Option<FleetConfig>,
    /// Pipeline slots. `1` runs the classic serial round loop (token
    /// streams and metrics bit-identical to every prior PR). `≥ 2` runs
    /// the staged executor: while slot N's round is in flight, the
    /// scheduler plans slot N+1 — admission, preemption, and KV growth
    /// run ahead against *projected* state and are reconciled when slot
    /// N's outcomes land. Depths above 2 behave exactly like 2: decode
    /// is token-serial (slot N+1's inputs are slot N's argmaxes), so at
    /// most one slot can ever be in flight ahead of the plan.
    pub pipeline_depth: usize,
    /// Store K/V rows int8-quantized (per-row absmax scales,
    /// [`PagedKvStore::new_quantized`]): ≈2× the sequences per device
    /// byte, rows dequantized in-gather.
    pub quantized_kv: bool,
    /// Keep up to this many refcount-0 *published* prefix blocks
    /// committed (LRU, evicted only under arena pressure) so identical
    /// prompt waves re-attach after the first wave fully completes.
    /// `0` — the default — frees them immediately, the pre-PR-7 behavior.
    pub prefix_retain_blocks: usize,
    /// Route depth 1 through the two-actor async executor anyway. The
    /// async loop at depth 1 submits and immediately reaps — no overlap,
    /// but the full channel/device-thread machinery runs, which is what
    /// the token-identity e2e pins against the serial loop.
    pub force_async: bool,
    /// Bench dial: synthetic per-round host planning cost (spun in the
    /// plan stage, outside any store lock). In the async loop it
    /// overlaps modeled device time; in the serial loop it serializes —
    /// the honest depth-1 baseline the overlap bench compares against.
    /// `0` (the default) adds nothing.
    pub synthetic_host_work_us: u64,
}

impl EngineConfig {
    /// Pipelined defaults: depth 2, fp32 KV, no retention.
    pub fn new(sched: SchedulerConfig) -> Self {
        EngineConfig {
            sched,
            policy: AdmissionPolicy::default(),
            spec: None,
            fleet: None,
            pipeline_depth: 2,
            quantized_kv: false,
            prefix_retain_blocks: 0,
            force_async: false,
            synthetic_host_work_us: 0,
        }
    }
}

/// Per-sequence runtime state the scheduler doesn't own: the pending
/// token and timing. The sequence's KV lives in the shared paged region
/// (addressed by its handle in the engine's `handles` map) — dropping
/// this struct at eviction carries no tensors, because there are none.
struct SeqRuntime {
    next_token: i32,
    prefill_s: f64,
    decode_s: f64,
    /// Arrival → first emitted token, captured when the first decode
    /// outcome lands (so it includes round-scheduling gaps, not just the
    /// step durations).
    ttft_s: Option<f64>,
    started: Instant,
    queue_s: f64,
    reply: Sender<InferenceResponse>,
    /// First mid-flight failure (e.g. a decode error that truncated the
    /// generation); reported in the final response's `error` field.
    error: Option<String>,
}

/// Reply channel + the timing a sequence has accumulated while it is
/// *not* running: before its first prefill, and parked across
/// preemptions (eviction drops the `SeqRuntime` — its KV state is
/// recomputed — but the caller's channel and the seconds already spent
/// must survive).
struct PendingReply {
    reply: Sender<InferenceResponse>,
    prefill_s: f64,
    decode_s: f64,
    ttft_s: Option<f64>,
    /// Queue wait before the *first* prefill started — preserved across
    /// evictions (recomputing it from arrival would double-count the
    /// time the sequence already spent running).
    queue_s: Option<f64>,
    error: Option<String>,
}

impl SeqRuntime {
    /// Park a live runtime across an eviction: the KV rows were already
    /// scrubbed when the store released the victim's blocks (recomputed
    /// by the re-prefill), everything the final response needs survives.
    /// The single inverse of [`PendingReply::resume`] — add a carried
    /// field in both places or it silently zeroes.
    fn park(self) -> PendingReply {
        PendingReply {
            reply: self.reply,
            prefill_s: self.prefill_s,
            decode_s: self.decode_s,
            ttft_s: self.ttft_s,
            queue_s: Some(self.queue_s),
            error: self.error,
        }
    }
}

impl PendingReply {
    fn new(reply: Sender<InferenceResponse>) -> Self {
        PendingReply {
            reply,
            prefill_s: 0.0,
            decode_s: 0.0,
            ttft_s: None,
            queue_s: None,
            error: None,
        }
    }

    /// Resume into a live runtime after a successful (re-)prefill,
    /// folding the newly spent prefill seconds into the carried total
    /// and keeping the first-prefill queue wait.
    fn resume(
        self,
        next_token: i32,
        prefill_s: f64,
        started: Instant,
        queue_now_s: f64,
    ) -> SeqRuntime {
        SeqRuntime {
            next_token,
            prefill_s: self.prefill_s + prefill_s,
            decode_s: self.decode_s,
            ttft_s: self.ttft_s,
            started,
            queue_s: self.queue_s.unwrap_or(queue_now_s),
            reply: self.reply,
            error: self.error,
        }
    }
}

/// A thread-based serving engine over the TinyLM PJRT runtime.
pub struct ServingEngine {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl ServingEngine {
    /// Start the engine with the default expected-footprint admission
    /// policy. Spawns the worker, which loads the artifacts (PJRT
    /// handles are not `Send`, so the worker thread owns the whole
    /// runtime; the constructor blocks until loading succeeds or fails).
    pub fn start(artifacts_dir: &str, sched_cfg: SchedulerConfig) -> Result<ServingEngine> {
        Self::start_with_policy(artifacts_dir, sched_cfg, AdmissionPolicy::default())
    }

    /// Start the engine with an explicit KV admission policy
    /// ([`AdmissionPolicy::WorstCase`] restores the PR-1 lifetime gate).
    pub fn start_with_policy(
        artifacts_dir: &str,
        sched_cfg: SchedulerConfig,
        policy: AdmissionPolicy,
    ) -> Result<ServingEngine> {
        Self::start_inner(artifacts_dir, sched_cfg, policy, None)
    }

    /// Start the engine with greedy draft-k **speculative decoding**: a
    /// draft model is loaded next to the target and every decode round
    /// runs the draft/verify path for sequences it can serve (falling
    /// back to plain decode per sequence when the draft cannot — capacity
    /// or prefill-bucket limits — so speculation is an optimization,
    /// never a new failure mode).
    pub fn start_speculative(
        artifacts_dir: &str,
        sched_cfg: SchedulerConfig,
        policy: AdmissionPolicy,
        spec: SpecConfig,
    ) -> Result<ServingEngine> {
        Self::start_inner(artifacts_dir, sched_cfg, policy, Some(spec))
    }

    /// Start a multi-model **fleet** engine: the target plus the
    /// configured draft models, a per-round draft/k chosen by the
    /// adaptive market (when `fleet.adaptive_k`), and optionally
    /// sampling-correct verification for temperature traffic
    /// (`fleet.sampled`). Runs the pipelined executor at the
    /// [`EngineConfig::new`] defaults.
    pub fn start_fleet(
        artifacts_dir: &str,
        sched_cfg: SchedulerConfig,
        policy: AdmissionPolicy,
        fleet: FleetConfig,
    ) -> Result<ServingEngine> {
        let mut cfg = EngineConfig::new(sched_cfg);
        cfg.policy = policy;
        cfg.fleet = Some(fleet);
        Self::start_with_config(artifacts_dir, cfg)
    }

    fn start_inner(
        artifacts_dir: &str,
        sched_cfg: SchedulerConfig,
        policy: AdmissionPolicy,
        spec: Option<SpecConfig>,
    ) -> Result<ServingEngine> {
        // The legacy entry points predate the pipelined executor: they
        // run depth 1 — the serial loop, untouched — so every caller
        // that existed before `EngineConfig` keeps bit-identical
        // behavior without opting into anything.
        let mut cfg = EngineConfig::new(sched_cfg);
        cfg.policy = policy;
        cfg.spec = spec;
        cfg.pipeline_depth = 1;
        Self::start_with_config(artifacts_dir, cfg)
    }

    /// Start the engine from a full [`EngineConfig`] — the one front
    /// door for the pipelined executor (`pipeline_depth ≥ 2`), int8 KV
    /// blocks (`quantized_kv`), and prefix-cache retention
    /// (`prefix_retain_blocks`).
    pub fn start_with_config(artifacts_dir: &str, cfg: EngineConfig) -> Result<ServingEngine> {
        // The legacy single-draft `spec` maps onto a one-draft STATIC
        // GREEDY fleet (same k every round, same store sizing, greedy
        // verify), so every pre-fleet caller keeps bit-identical token
        // streams.
        let fleet_cfg = match (&cfg.fleet, &cfg.spec) {
            (Some(f), _) => Some(f.clone()),
            (None, Some(s)) => Some(FleetConfig {
                drafts: vec![DraftModelConfig {
                    artifacts_dir: s.draft_artifacts_dir.clone(),
                    k_max: s.draft_k.max(1),
                    cost: SpecRoundCost::relative(1.0, 1.0),
                }],
                adaptive_k: false,
                ewma_weight: 0.3,
                sampled: None,
            }),
            (None, None) => None,
        };
        let dir = artifacts_dir.to_string();
        let max_active = cfg.sched.max_active;
        // The loader runs ON the thread that ends up owning the models —
        // the worker in serial mode, the device thread in async mode.
        // PJRT handles are not `Send`, so they must be born where they
        // will live.
        Self::spawn_engine(move || device::load_tinylm_fleet(&dir, fleet_cfg, max_active), cfg)
    }

    /// Start a PJRT-free engine over the deterministic fake backend
    /// ([`FakeLmBackend`]): plain decode + prefill only, argmax streams
    /// fixed by a content hash, device time modeled by
    /// [`crate::runtime::LmBackend::simulated_device_busy`]. The
    /// async-overlap bench and the two-actor e2e tests use it to
    /// exercise the executor itself — host plan time is real, device
    /// time is the configured spin — without artifacts on disk.
    pub fn start_fake(fake: FakeLmConfig, cfg: EngineConfig) -> Result<ServingEngine> {
        Self::spawn_engine(
            move || {
                let backend = FakeLmBackend::new(fake);
                let dims = ModelDims::of(backend.manifest());
                Ok(FleetRuntime {
                    reg: ModelRegistry::new(backend, dims),
                    adaptive_k: false,
                    ewma_weight: 0.3,
                    sampled: None,
                })
            },
            cfg,
        )
    }

    /// Shared spawn scaffolding: worker thread, request channel, and the
    /// blocking ready handshake (loading happens on the owning thread;
    /// the constructor returns only once it succeeded or failed).
    fn spawn_engine<B, L>(loader: L, cfg: EngineConfig) -> Result<ServingEngine>
    where
        B: LmBackend + 'static,
        L: FnOnce() -> Result<FleetRuntime<B>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::default());
        let m2 = Arc::clone(&metrics);
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("mldrift-serving".into())
            .spawn(move || run_worker(loader, cfg, rx, m2, ready_tx))
            .map_err(|e| DriftError::Serving(format!("spawn worker: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| DriftError::Serving("worker died during startup".into()))??;
        Ok(ServingEngine { tx, worker: Some(worker), metrics })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<InferenceResponse>> {
        let (reply_tx, reply_rx) = channel();
        self.metrics.record_submit();
        self.tx
            .send(Msg::Request(req, reply_tx))
            .map_err(|_| DriftError::Serving("engine stopped".into()))?;
        Ok(reply_rx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| DriftError::Serving("engine dropped request".into()))
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            completed: self.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
            tokens_generated: self
                .metrics
                .tokens_generated
                .load(std::sync::atomic::Ordering::Relaxed),
            report: self.metrics.report(),
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Target-store construction shared by both worker loops.
///
/// Default arena: `max_active` full-capacity sequences (per-sequence
/// reservations are block-rounded, so size in blocks, not tokens) —
/// generous, so even worst-case growth (every sequence hitting its
/// `cache_capacity` ceiling) stays preemption-free and the arena is a
/// safety net. `kv_arena_blocks` fixes the budget instead: KV becomes
/// the contended resource and the preemption path takes over. The store
/// backs every block with real storage in one contiguous region —
/// claims commit bytes, evictions scrub and release them. The PR-7
/// engine knobs land here: `quantized_kv` swaps in the int8 region and
/// `prefix_retain_blocks` arms the published-prefix LRU.
pub(crate) fn build_target_store(m: &TinyLmManifest, cfg: &EngineConfig) -> PagedKvStore {
    let arena = KvArenaConfig {
        layers: m.layers,
        heads_kv: m.heads_kv,
        head_dim: m.head_dim,
        block_tokens: KV_BLOCK_TOKENS,
        num_blocks: cfg.sched.kv_arena_blocks.unwrap_or_else(|| {
            cfg.sched.max_active.max(1)
                * crate::util::div_ceil(m.cache_capacity.max(1), KV_BLOCK_TOKENS)
        }),
    };
    let mut store = if cfg.quantized_kv {
        PagedKvStore::new_quantized(arena)
    } else {
        PagedKvStore::new(arena)
    };
    if cfg.prefix_retain_blocks > 0 {
        store.set_prefix_retention(cfg.prefix_retain_blocks);
    }
    store
}

/// Route to the executor the config selects, completing the ready
/// handshake on whichever thread ends up loading the models: the serial
/// loop loads here (worker owns the runtime, exactly the pre-async
/// engine); the async loop hands the loader to the device thread.
fn run_worker<B, L>(
    loader: L,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready_tx: Sender<Result<()>>,
) where
    B: LmBackend + 'static,
    L: FnOnce() -> Result<FleetRuntime<B>> + Send + 'static,
{
    metrics.set_pipeline_depth(cfg.pipeline_depth.max(1) as u64);
    if cfg.pipeline_depth >= 2 || cfg.force_async {
        worker_loop_async(loader, cfg, rx, metrics, ready_tx)
    } else {
        let fleet = match loader() {
            Ok(f) => {
                let _ = ready_tx.send(Ok(()));
                f
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        worker_loop_serial(fleet, cfg, rx, metrics)
    }
}

/// One TTFT-autotuner step, shared by both worker loops: sample the
/// live TTFT p95 (only once at least one request has completed — the
/// histogram is empty before that) and walk the scheduler's prefill
/// granule one rung along the [`ChunkAutotuner`] hysteresis ladder.
/// No-op when the engine runs without a TTFT target.
fn retune_prefill_chunk(
    tuner: &Option<ChunkAutotuner>,
    metrics: &Metrics,
    sched: &mut Scheduler,
) {
    if let Some(tuner) = tuner {
        if metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed) == 0 {
            return;
        }
        let (_, p95) = metrics.ttft_p50_p95();
        let cur = sched.prefill_chunk_tokens();
        let next = tuner.update(cur, p95);
        if next != cur {
            sched.set_prefill_chunk_tokens(next);
        }
    }
}

fn worker_loop_serial<B: LmBackend>(
    fleet: FleetRuntime<B>,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    let sched_cfg = cfg.sched;
    let policy = cfg.policy;
    let mut sched = Scheduler::new(sched_cfg);
    // TTFT-adaptive chunk sizing: with a p95 target configured, retune
    // the prefill granule once per round against the live histogram —
    // shrink below the profile default while the target is missed, grow
    // back once latency recovers. `None` keeps the granule fixed.
    let chunk_tuner = sched_cfg
        .ttft_p95_target_s
        .map(|t| ChunkAutotuner::new(sched_cfg.prefill_chunk_tokens, t));
    let FleetRuntime { reg, adaptive_k, ewma_weight, sampled } = fleet;
    let mut spec_rng = sampled.map(|s| Pcg32::seeded(s.seed));
    let target_cap = reg.target_dims().cache_capacity;
    let mut store = build_target_store(reg.target().manifest(), &cfg);
    // Draft binding: `(draft index, handle in that draft's store)` — a
    // sequence binds to at most one draft for its lifetime.
    let mut draft_handles: HashMap<RequestId, (usize, KvSeqHandle)> = HashMap::new();
    // Per-sequence live acceptance for the draft market. Survives
    // preemption (the estimate describes the *traffic*, not KV state —
    // re-admission should not forget what it learned); dropped at reap.
    let mut acceptance: HashMap<RequestId, AcceptanceEwma> = HashMap::new();
    let mut runtimes: HashMap<RequestId, SeqRuntime> = HashMap::new();
    let mut handles: HashMap<RequestId, KvSeqHandle> = HashMap::new();
    let mut replies: HashMap<RequestId, PendingReply> = HashMap::new();
    // Content-addressed prefix keys per in-flight request, hashed once
    // at enqueue (block granularity; target store only — the draft
    // store never shares). Empty when `share_prefix_kv` is off:
    // admission then sees no keys and claims every block privately —
    // bitwise the pre-sharing behaviour.
    let mut prefix_keys: HashMap<RequestId, Vec<PrefixKey>> = HashMap::new();
    let mut shutdown = false;

    while !shutdown || !sched.is_idle() {
        // Drain incoming requests (non-blocking when busy, blocking when idle).
        loop {
            let msg = if sched.is_idle() && !shutdown {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Request(req, reply) => {
                    // Per-sequence ceiling: the decode artifact scatters
                    // K/V rows at `pos`, so a sequence must never outgrow
                    // the model's cache capacity — nor the whole arena,
                    // when `kv_arena_blocks` shrank it below one
                    // full-capacity sequence (admission defers on
                    // backpressure, so a request that could NEVER fit
                    // must fail here or it would wedge the queue).
                    let tokens = req.prompt.len() + req.max_new_tokens;
                    let cap = target_cap.min(store.config().total_tokens());
                    if tokens > cap {
                        let msg = format!(
                            "prompt + max_new_tokens = {tokens} exceeds per-sequence capacity {cap}"
                        );
                        crate::log_error!("request {} rejected: {msg}", req.id);
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    // Ids key every per-sequence map (replies before
                    // prefill and while parked, handles from admission to
                    // reap): a duplicate in-flight id would cross-wire
                    // two sequences and leak the first one's arena blocks.
                    if replies.contains_key(&req.id) || handles.contains_key(&req.id) {
                        let msg = format!("request id {} is already in flight", req.id);
                        crate::log_error!("request rejected: {msg}");
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    if sched_cfg.share_prefix_kv {
                        prefix_keys
                            .insert(req.id, shareable_prefix_keys(&req.prompt, KV_BLOCK_TOKENS));
                    }
                    replies.insert(req.id, PendingReply::new(reply));
                    sched.submit(req);
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        if sched.is_idle() {
            continue;
        }
        // Bench dial: the synthetic per-round host planning cost. In the
        // serial loop it serializes with device time — the honest
        // baseline the async executor's measured overlap is judged
        // against.
        if cfg.synthetic_host_work_us > 0 {
            device::spin_wait(Duration::from_micros(cfg.synthetic_host_work_us));
        }

        // Admission: gate on the *expected* footprint (blended mean
        // generation length with a safety margin; worst case until
        // history exists — the in-flight gauges below are what corrects
        // the completed-only survivorship bias), claim only the context
        // that prefill must cover now. A gate or claim miss defers the
        // request — backpressure, never failure.
        let (inflight_seqs, inflight_tokens) = sched.inflight_gen();
        metrics.set_inflight_gen(inflight_seqs, inflight_tokens);
        let mean_gen = metrics.mean_gen_tokens();
        let mut newly_admitted: Vec<RequestId> = Vec::new();
        sched.admit_where(|req, ctx_tokens| {
            // Prefix sharing: gate and claim count only the blocks NOT
            // already published by an identical committed prefix — the
            // attach is what multiplies admitted concurrency at fixed
            // arena bytes. With no keys this is exactly the plain gate.
            let keys: &[PrefixKey] = prefix_keys.get(&req.id).map_or(&[], |k| k.as_slice());
            // Fleet draft binding: the first registered draft whose
            // capacity covers the request's lifetime context claims the
            // same context in its own store, atomically with the target
            // claim (a companion miss rolls the target claim back and
            // defers — backpressure, so no store pair can ever disagree
            // about who is admitted).
            let di = reg.assign_draft(req.prompt.len() + req.max_new_tokens);
            let mut companion = di.map(|i| reg.draft_store(i));
            match policy.admit_with_companion(
                &mut store,
                companion.as_mut().map(|g| &mut **g),
                req,
                ctx_tokens,
                mean_gen,
                keys,
            ) {
                Some((h, dh)) => {
                    if let (Some(i), Some(dh)) = (di, dh) {
                        draft_handles.insert(req.id, (i, dh));
                        acceptance
                            .entry(req.id)
                            .or_insert_with(|| AcceptanceEwma::new(ewma_weight));
                    }
                    handles.insert(req.id, h);
                    newly_admitted.push(req.id);
                    true
                }
                None => false,
            }
        });
        // Attached prefix blocks arrive *committed*: prefill resumes
        // after them, so the skipped positions' compute never runs at
        // all. (The draft store, when speculation is on, still prefills
        // its whole context at the final chunk — it never shares.)
        for id in newly_admitted {
            let skip = store.len(handles[&id]);
            if skip > 0 {
                metrics.record_prefix_attach(skip);
                sched.seq_mut(id).expect("admitted above").prefill_progress = skip;
            }
        }
        // (Deferral can never wedge: enqueue rejects anything over the
        // per-sequence capacity — `cache_capacity` capped to the arena —
        // so every queued request's worst-case footprint fits an empty
        // arena, and the FIFO head can always run to completion.)

        let round = sched.next_round();

        // ---- paged growth + preemption (before any state advances) ------
        // Every decode step scatters KV rows, so reservations must cover
        // them *before* the scheduler emits anything: one row for a plain
        // step, `k + 1` provisional rows for a speculative draft/verify
        // step (rejected rows are scrubbed after acceptance, but the
        // blocks must exist up front). Sequences emitting their final
        // token run no step and need no row. `ensure_round_capacity`
        // evicts victims when the arena cannot grow; the callback parks
        // the victim's reply channel and timing (its KV state is
        // recomputed on re-admission) and releases its draft blocks.
        // Held-out sequences sit out the whole round — they lose time,
        // never tokens.
        let mut spec_width: HashMap<RequestId, usize> = HashMap::new();
        let needs_rows: Vec<(RequestId, usize)> = round
            .decode_batch
            .iter()
            .copied()
            .filter_map(|id| {
                let seq = sched.seq(id).expect("scheduled seq exists");
                let remaining =
                    seq.request.max_new_tokens.saturating_sub(seq.generated.len() + 1);
                if remaining == 0 {
                    return None;
                }
                // The draft market: this sequence's width for the
                // round — static `k_max` when the market is off,
                // otherwise the breakeven argmax at the live α
                // estimate (`k = 0` ⇒ plain decode).
                let k_eff = match draft_handles.get(&id) {
                    Some(&(di, _)) => {
                        let alpha = acceptance.get(&id).and_then(|e| e.estimate());
                        reg.plan_k(di, alpha, adaptive_k).min(remaining)
                    }
                    None => 0,
                };
                spec_width.insert(id, k_eff);
                Some((id, k_eff + 1))
            })
            .collect();
        // Prefill chunks reserve through the same loop: a no-op when
        // the admission claim already covers their rows, but a chunk
        // whose write window opens inside a *shared* block needs a
        // copy-on-write block up front — and exhaustion there must
        // preempt a victim, never fail the pack.
        let mut needs_rows = needs_rows;
        needs_rows.extend(round.prefills.iter().filter(|c| c.len > 0).map(|c| (c.id, c.len)));
        let held_out: HashSet<RequestId> = sched.ensure_round_capacity(
            &mut store,
            &mut handles,
            &needs_rows,
            |victim, bill, bytes_freed| {
                if let Some(srt) = runtimes.remove(&victim) {
                    replies.insert(victim, srt.park());
                }
                // The draft store's blocks are released too, but only the
                // *target*-store bytes feed the metric: its documented
                // invariant ties `kv_bytes_freed_by_preemption` to the
                // `kv_device_bytes_*` watermark, which gauges the target
                // store alone.
                let mut draft_freed = 0;
                if let Some((di, dh)) = draft_handles.remove(&victim) {
                    draft_freed = reg.release_draft(di, dh);
                }
                metrics.record_preemption(bill, bytes_freed);
                crate::log_warn!(
                    "kv region exhausted: preempted request {victim} (re-prefill {bill} tokens, \
                     {bytes_freed} device bytes released, {draft_freed} draft bytes)"
                );
            },
        );

        // ---- decode batch first (latency protection) --------------------
        // Advance scheduler state and collect per-sequence step inputs.
        let mut round_tokens = 0usize;
        let mut inputs: HashMap<RequestId, (i32, usize)> = HashMap::new();
        for &id in &round.decode_batch {
            if held_out.contains(&id) {
                continue;
            }
            if let Some(srt) = runtimes.get_mut(&id) {
                let token = srt.next_token;
                let seq = sched.seq_mut(id).expect("scheduled seq exists");
                seq.generated.push(token);
                if srt.ttft_s.is_none() {
                    // The first token is emitted *here* (it was computed by
                    // prefill's logits); stamping after the batched round
                    // would inflate TTFT by the other sequences' steps.
                    srt.ttft_s = Some(srt.started.elapsed().as_secs_f64());
                }
                let pos = seq.pos;
                seq.pos += 1;
                round_tokens += 1;
                // The token just emitted was computed by the *previous*
                // step's logits. A sequence emitting its final token needs
                // no decode step — the step would only produce a successor
                // token (and KV row) that no round will ever consume.
                if seq.generated.len() < seq.request.max_new_tokens {
                    inputs.insert(id, (token, pos));
                }
            }
        }
        // One batched round over the runtime. Per-sequence PJRT decode
        // inside one round keeps numerics exactly single-stream (each
        // step gathers its sequence's blocks into the same dense
        // literals the dense path would pass — bit-identical inputs, so
        // bit-identical token streams); the batched *latency* (weights
        // streamed once per round) is what `sim::exec::simulate_batched`
        // reports for GPUs, with the gather indirection priced by
        // `sim::exec::paged_gather_overhead_s`.
        let mut step_ids = Vec::with_capacity(inputs.len());
        let mut steps = Vec::with_capacity(inputs.len());
        // Speculative members grouped by draft index: weight-streaming
        // cost is shared only within one model's batch, so each group
        // dispatches as one batch against its own draft model.
        let mut spec_groups: Vec<(Vec<RequestId>, Vec<(SpecStepArgs, Vec<i32>)>)> =
            (0..reg.num_drafts()).map(|_| (Vec::new(), Vec::new())).collect();
        for &id in &round.decode_batch {
            if let Some(&(token, pos)) = inputs.get(&id) {
                let k_eff = spec_width.get(&id).copied().unwrap_or(0);
                if k_eff > 0 {
                    // Draft catch-up: the committed tokens the draft's KV
                    // has not consumed yet (lag ≤ 1 after a
                    // fully-accepted round; the whole context after a
                    // re-prefill failure would have dropped the handle).
                    let &(di, dh) = draft_handles.get(&id).expect("spec width implies a draft");
                    let seq = sched.seq(id).expect("scheduled seq exists");
                    let plen = seq.request.prompt.len();
                    let catchup: Vec<i32> = (reg.draft_store(di).len(dh)..pos)
                        .map(|p| {
                            if p < plen { seq.request.prompt[p] } else { seq.generated[p - plen] }
                        })
                        .collect();
                    metrics.record_spec_plan(k_eff as u64);
                    spec_groups[di].0.push(id);
                    spec_groups[di].1.push((
                        SpecStepArgs { token, pos, k: k_eff, h: handles[&id], draft_h: dh },
                        catchup,
                    ));
                } else {
                    step_ids.push(id);
                    steps.push(PagedRoundStep { token, pos, handle: handles[&id] });
                }
            }
        }
        let outcomes = reg.target().decode_round_paged(&mut store, &steps);
        for (id, outcome) in step_ids.into_iter().zip(outcomes) {
            match outcome {
                Ok(out) => {
                    let srt = runtimes.get_mut(&id).expect("member collected above");
                    srt.decode_s += out.step_s;
                    metrics.record_decode_step(out.step_s);
                    srt.next_token = argmax(&out.logits) as i32;
                    // Capacity was ensured before the round (the row
                    // itself was written by the step), so this length
                    // bookkeeping cannot overflow.
                    if let Err(e) = store.append(handles[&id], 1) {
                        crate::log_error!("kv store append for request {id}: {e}");
                    }
                }
                Err(e) => {
                    crate::log_error!("decode failed for request {id}: {e}");
                    if let Some(srt) = runtimes.get_mut(&id) {
                        srt.error.get_or_insert(format!("decode failed mid-generation: {e}"));
                    }
                    let seq = sched.seq_mut(id).expect("scheduled seq exists");
                    seq.request.max_new_tokens = seq.generated.len();
                }
            }
        }
        // ---- speculative draft/verify steps -----------------------------
        // Each step proposes k tokens with the draft, verifies all k + 1
        // positions with the target, commits the accepted prefix into the
        // paged stores (rejected rows scrubbed — `spec_round_paged` also
        // scrubs on failure), and hands back the accepted tokens to emit
        // *this* round. Output is token-identical to plain greedy decode
        // whatever the draft proposed.
        for (di, (ids, group)) in spec_groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let (target_m, draft_m, mut ds) = reg.spec_parts(di);
            let spec_outcomes = match (sampled, spec_rng.as_mut()) {
                (Some(sc), Some(rng)) => target_m.spec_round_paged_sampled(
                    draft_m,
                    &mut store,
                    &mut ds,
                    &group,
                    sc.temperature,
                    rng,
                ),
                _ => target_m.spec_round_paged(draft_m, &mut store, &mut ds, &group),
            };
            for (id, outcome) in ids.into_iter().zip(spec_outcomes) {
                match outcome {
                    Ok((out, step_s)) => {
                        let srt = runtimes.get_mut(&id).expect("member collected above");
                        srt.decode_s += step_s;
                        metrics.record_decode_step(step_s);
                        metrics
                            .record_spec(out.proposed as u64, out.accepted_tokens.len() as u64);
                        // Feed the market: the EWMA this sequence's
                        // next round's width is planned from.
                        if let Some(est) = acceptance.get_mut(&id) {
                            est.observe(out.proposed, out.accepted_tokens.len());
                        }
                        srt.next_token = out.next_token;
                        // Accepted tokens join the emission stream now —
                        // this is what lets tokens/round exceed batch
                        // occupancy. `commit_provisional` inside the step
                        // already appended the kept KV rows.
                        let seq = sched.seq_mut(id).expect("scheduled seq exists");
                        for &tok in &out.accepted_tokens {
                            seq.generated.push(tok);
                            seq.pos += 1;
                        }
                        round_tokens += out.accepted_tokens.len();
                    }
                    Err(e) => {
                        crate::log_error!("speculative decode failed for request {id}: {e}");
                        if let Some(srt) = runtimes.get_mut(&id) {
                            srt.error
                                .get_or_insert(format!("decode failed mid-generation: {e}"));
                        }
                        let seq = sched.seq_mut(id).expect("scheduled seq exists");
                        seq.request.max_new_tokens = seq.generated.len();
                    }
                }
            }
        }
        if !round.is_idle() {
            // Occupancy = the *executed* kernel batch (sequences emitting
            // their final token need no step and don't amortize weights);
            // tokens can exceed it via final emissions AND speculative
            // acceptance — recorded per round, so the tokens/round
            // histogram reflects live acceptance.
            metrics.record_round(inputs.len(), round_tokens);
        }

        // ---- prefills (chunked + packed) --------------------------------
        // The round's prefill pack: chunks from multiple sequences,
        // executed by the runtime as one flattened GEMM
        // ([`TinyLmRuntime::prefill_pack`]; the B=1 CPU artifact loops
        // the chunks — numerics stay exactly single-stream — while the
        // packed one-launch latency is what the cost model prices). A
        // partial chunk deposits KV rows through the provisional-scatter
        // seam and commits at the chunk boundary; only the FINAL chunk
        // returns logits, so the first token — and TTFT — attributes to
        // the round that ran it. Re-prefill after a preemption restarts
        // at token 0 over prompt + generated: recompute rebuilds the
        // evicted rows, and the final chunk's logits reproduce the
        // pending next token exactly.
        let mut pack: Vec<PackedPrefillChunk> = Vec::new();
        let mut pack_ids: Vec<RequestId> = Vec::new();
        for c in &round.prefills {
            if held_out.contains(&c.id) {
                // Evicted this round before its chunk ran (a fresh,
                // zero-progress admission is the preferred victim): it is
                // back in the preempted queue, not active — skip it.
                continue;
            }
            let seq = sched.seq(c.id).expect("scheduled seq exists");
            debug_assert_eq!(c.start, seq.prefill_progress, "chunk off its progress: {c:?}");
            // The queue clock stops when the sequence's FIRST chunk
            // starts running (idempotent — later chunks find it
            // stamped). Under prefix sharing the first chunk can start
            // past 0 (attached positions are skipped), so this must not
            // key on `start == 0`.
            if let Some(pending) = replies.get_mut(&c.id) {
                pending
                    .queue_s
                    .get_or_insert_with(|| seq.request.arrival.elapsed().as_secs_f64());
            }
            let tokens: Vec<i32> = seq
                .request
                .prompt
                .iter()
                .chain(seq.generated.iter())
                .copied()
                .skip(c.start)
                .take(c.len)
                .collect();
            pack.push(PackedPrefillChunk {
                h: handles[&c.id],
                start: c.start,
                tokens,
                last: c.last,
            });
            pack_ids.push(c.id);
        }
        let outcomes = reg.target().prefill_pack(&mut store, &pack);
        for ((id, chunk), outcome) in pack_ids.into_iter().zip(&pack).zip(outcomes) {
            match outcome {
                Ok(out) => {
                    metrics.record_prefill_chunk(chunk.tokens.len());
                    let seq = sched.seq_mut(id).expect("scheduled seq exists");
                    seq.prefill_progress += chunk.tokens.len();
                    // Blocks this chunk fully committed become shareable:
                    // later identical prompts attach instead of recomputing.
                    // Publishing is best-effort — a failure only forfeits
                    // future sharing, never this sequence's own KV.
                    if let Some(keys) = prefix_keys.get(&id) {
                        if let Err(e) = store.publish_prefix(handles[&id], keys) {
                            crate::log_error!("publish prefix for request {id}: {e}");
                        }
                    }
                    if !chunk.last {
                        // Mid-prefill chunk: KV deposited, no token yet —
                        // fold the time into the parked reply and keep
                        // waiting for the final chunk.
                        let pending = replies.get_mut(&id).expect("pending reply");
                        pending.prefill_s += out.step_s;
                        continue;
                    }
                    seq.prefill_done = true;
                    let logits = out.logits.expect("final chunk returns logits");
                    let next = argmax(&logits) as i32;
                    let pending = replies.remove(&id).expect("pending reply");
                    let arrival = seq.request.arrival;
                    // `pending.queue_s` was stamped when this sequence's
                    // first chunk ran (the stamp above is unconditional
                    // and idempotent, and every parked reply reaches at
                    // least one chunk), so `resume`'s elapsed-now
                    // fallback below is provably never taken — it cannot
                    // become the recorded queue wait.
                    runtimes.insert(
                        id,
                        pending.resume(next, out.step_s, arrival, arrival.elapsed().as_secs_f64()),
                    );
                    // Speculative decode: (re-)prefill the draft over the
                    // whole context so draft and target KV agree —
                    // executed once, at the final chunk. A draft prefill
                    // failure downgrades this sequence to plain decode —
                    // speculation is an optimization, never a new way to
                    // fail a request.
                    if let Some(&(di, dh)) = draft_handles.get(&id) {
                        let seq = sched.seq(id).expect("scheduled seq exists");
                        let ctx: Vec<i32> = seq
                            .request
                            .prompt
                            .iter()
                            .chain(seq.generated.iter())
                            .copied()
                            .collect();
                        let (_, draft_m, mut ds) = reg.spec_parts(di);
                        match draft_m.prefill_paged(&ctx, &mut ds, dh) {
                            Ok(_) => {
                                if let Err(e) = ds.append(dh, ctx.len()) {
                                    crate::log_error!("draft kv append for request {id}: {e}");
                                }
                            }
                            Err(e) => {
                                crate::log_warn!(
                                    "draft prefill failed for request {id} \
                                     (plain decode fallback): {e}"
                                );
                                ds.release(dh);
                                draft_handles.remove(&id);
                            }
                        }
                    }
                }
                Err(e) => {
                    // Finish the sequence with whatever it already has:
                    // for a fresh request that's an empty error response,
                    // but a re-prefill failure after preemption must not
                    // discard the tokens generated before eviction (the
                    // reap fallback below replies with `done.generated`
                    // plus the parked timings and this error). The failed
                    // chunk's provisional rows were scrubbed by the pack.
                    crate::log_error!("prefill chunk failed for request {id}: {e}");
                    let seq = sched.seq_mut(id).expect("scheduled seq exists");
                    seq.prefill_done = true;
                    seq.request.max_new_tokens = seq.generated.len(); // finish now
                    if let Some(pending) = replies.get_mut(&id) {
                        pending.error.get_or_insert(format!("prefill failed: {e}"));
                    }
                }
            }
        }

        // Modeled device time (fake-backend path; `None` — a real PJRT
        // round — already spent its wall clock inside the calls above):
        // realize this round's device seconds as a spin so the serial
        // loop prices rounds exactly like the async executor and the
        // overlap bench compares like against like.
        let busy_prefill: usize = pack.iter().map(|c| c.tokens.len()).sum();
        if let Some(d) = reg.target().simulated_device_busy(inputs.len(), busy_prefill) {
            device::spin_wait(d);
        }

        for done in sched.reap_finished() {
            let id = done.request.id;
            if let Some(h) = handles.remove(&id) {
                store.release(h);
            }
            prefix_keys.remove(&id);
            if let Some((di, dh)) = draft_handles.remove(&id) {
                reg.release_draft(di, dh);
            }
            acceptance.remove(&id);
            if let Some(srt) = runtimes.remove(&id) {
                let total_s = srt.started.elapsed().as_secs_f64();
                let ttft_s = fallback_ttft(srt.ttft_s, total_s);
                metrics.record_completion(
                    done.request.prompt.len(),
                    done.generated.len(),
                    ttft_s,
                    total_s,
                );
                let _ = srt.reply.send(InferenceResponse {
                    id,
                    tokens: done.generated,
                    queue_s: srt.queue_s,
                    prefill_s: srt.prefill_s,
                    decode_s: srt.decode_s,
                    ttft_s,
                    total_s,
                    error: srt.error,
                });
            } else if let Some(pending) = replies.remove(&id) {
                // A sequence reaped without a runtime: its (re-)prefill
                // failed, or it never ran at all. Reply with whatever it
                // accumulated — tokens generated before an eviction, the
                // parked timings, and the recorded error — so a caller
                // never hangs on a dropped channel and never loses
                // delivered work. Failed requests stay OUT of the
                // completion metrics: counting their zero-length
                // generations would drag `mean_gen_tokens` down and make
                // expected-footprint admission over-admit, and their
                // wall-clock wait would pollute the TTFT/e2e histograms.
                let waited = done.request.arrival.elapsed().as_secs_f64();
                if pending.error.is_none() {
                    let ttft = pending.ttft_s.unwrap_or(waited);
                    metrics.record_completion(
                        done.request.prompt.len(),
                        done.generated.len(),
                        ttft,
                        waited,
                    );
                }
                let _ = pending.reply.send(InferenceResponse {
                    id,
                    tokens: done.generated,
                    queue_s: pending.queue_s.unwrap_or(waited),
                    prefill_s: pending.prefill_s,
                    decode_s: pending.decode_s,
                    ttft_s: pending.ttft_s.unwrap_or(waited),
                    total_s: waited,
                    error: pending.error,
                });
            }
        }

        // Device-memory gauges: what the paged region actually holds
        // after this round's growth, evictions, AND completions (the
        // watermark the paged-KV e2e assertions read) — updated after the
        // reap so completed sequences' released blocks are reflected and
        // a drained engine reports zero bytes in use.
        metrics.set_kv_device_bytes(
            store.device_bytes_in_use() as u64,
            store.peak_device_bytes_in_use() as u64,
        );
        metrics.set_kv_sharing(store.arena().shared_blocks() as u64, store.arena().cow_copies());
        metrics.set_kv_dequant(store.dequantized_rows());
        retune_prefill_chunk(&chunk_tuner, &metrics, &mut sched);
    }
}

/// One submitted pipeline slot: what the policy thread remembers about
/// the round it handed to the device thread, parked until the reap
/// stage receives the matching [`RoundCompletion`]. The outcomes
/// themselves live on the other side of the channel now — this is the
/// policy-side stub the if-let-guarded reap reconciles against.
/// `window` pins every block the slot's steps gather through
/// ([`PagedKvStore::begin_slot_window`]), and it MUST be opened before
/// the descriptor is submitted: a plan-stage eviction or release of a
/// member while the round sits in the channel (or executes) defers the
/// actual free until the reap closes the window, so no claim can ever
/// alias storage the device still addresses.
struct InflightSlot {
    window: Option<KvSlotWindow>,
    /// Executed kernel batch (plain decode steps + speculative steps).
    batch: usize,
    /// Tokens emitted when the slot was bound (pending-token emissions);
    /// speculative acceptance lands at reap and is added there.
    emitted: usize,
}

/// CI thread-stress knob: a deterministic per-stage delay (microseconds,
/// parsed once from `MLDRIFT_SLOT_JITTER_US`) inserted between the
/// policy loop's plan/reap/bind stages — and, in the async executor,
/// ahead of every device-thread round — widening the window in which
/// cross-thread arrivals and submissions interleave with in-flight
/// slots.
pub(crate) fn slot_jitter_us() -> u64 {
    std::env::var("MLDRIFT_SLOT_JITTER_US").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// The truly-async (depth ≥ 2, or `force_async`) policy loop: the same
/// staged plan/reap/bind machine the synchronous pipelined executor
/// ran, with execution moved onto the dedicated device thread
/// ([`crate::serving::device`]) so the overlap is real wall-clock time,
/// not just reordered bookkeeping.
///
/// Each iteration runs three stages against at most one in-flight slot:
///
/// 1. **Plan** slot N+1 while slot N *executes on the device thread*:
///    admission, the projected round, and `ensure_round_capacity`
///    (growth + preemption) run against *speculated* state — slot N's
///    accepted tokens and prefill progress have not landed yet, so the
///    plan reserves a conservative superset of what the bind will need.
///    Store work takes the shared-store lock briefly; the modeled
///    device busy time spins outside it, so the two genuinely overlap.
/// 2. **Reap** slot N: block on the completion channel (this is the
///    synchronization point — decode is token-serial, the bind needs
///    slot N's argmaxes), then apply the outcomes. Every application is
///    if-let-guarded, because the plan stage may have preempted a slot
///    member while its round sat in the submission channel or executed
///    — the victim's runtime and handle are gone, its outcome is
///    dropped, and re-prefill recomputes the lost pending token
///    (recompute semantics, the same contract as serial eviction).
///    Closing the slot's reservation window here releases the frees the
///    window deferred.
/// 3. **Bind + submit** slot N+1: recompute the round from the now
///    authoritative scheduler state (the reconciliation step — the plan
///    was speculative, the bind is truth), re-run the capacity pass with
///    actual speculative widths, advance emission state exactly like the
///    serial loop, open the reservation window, and only then send the
///    fully-bound descriptor — the window must outlive cross-thread
///    submission, not just slot reap, or a plan-stage free could alias
///    storage the device is about to gather.
///
/// Decode is token-serial — slot N+1's decode inputs are slot N's
/// argmaxes — so at most one slot can be in flight ahead of the plan:
/// depths above 2 are structurally identical to depth 2 (and the
/// submission channel's bound of 1 enforces it; see
/// DESIGN.md §pipelined executor and the matching sim sweep).
///
/// This loop's stage machine — including the second device actor and
/// its FIFO submit/execute gating — is mirrored step-for-step by the
/// drift-check interleaving explorer ([`crate::check::model`]), which
/// exhaustively enumerates plan/bind/submit/exec/reap orderings against
/// the real `KvArena` and asserts the DESIGN.md §6 invariant catalog
/// after every step — when changing the ordering contract here, change
/// the model FIRST and let the explorer veto the design before the
/// engine learns it.
fn worker_loop_async<B, L>(
    loader: L,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready_tx: Sender<Result<()>>,
) where
    B: LmBackend + 'static,
    L: FnOnce() -> Result<FleetRuntime<B>> + Send + 'static,
{
    let (queue, ready) = match device::spawn_device(loader, cfg.clone()) {
        Ok(x) => x,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let _ = ready_tx.send(Ok(()));
    let device::DeviceReady { fleet, store, adaptive_k, ewma_weight } = ready;
    let sched_cfg = cfg.sched;
    let policy = cfg.policy;
    let jitter_us = slot_jitter_us();
    let jitter = |_stage: &str| {
        if jitter_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(jitter_us));
        }
    };
    let mut sched = Scheduler::new(sched_cfg);
    // TTFT-adaptive chunk sizing — same ladder as the serial loop,
    // stepped once per reap. Retuning is policy-side state only; the
    // device thread never sees the granule, so no channel traffic.
    let chunk_tuner = sched_cfg
        .ttft_p95_target_s
        .map(|t| ChunkAutotuner::new(sched_cfg.prefill_chunk_tokens, t));
    let target_cap = fleet.target_dims().cache_capacity;
    // Arena geometry is fixed at construction — snapshot the token total
    // once instead of taking the store lock per enqueued request.
    let store_total_tokens = {
        let st = store.lock().expect("target store lock poisoned");
        st.config().total_tokens()
    };
    let mut draft_handles: HashMap<RequestId, (usize, KvSeqHandle)> = HashMap::new();
    let mut acceptance: HashMap<RequestId, AcceptanceEwma> = HashMap::new();
    let mut runtimes: HashMap<RequestId, SeqRuntime> = HashMap::new();
    let mut handles: HashMap<RequestId, KvSeqHandle> = HashMap::new();
    let mut replies: HashMap<RequestId, PendingReply> = HashMap::new();
    let mut prefix_keys: HashMap<RequestId, Vec<PrefixKey>> = HashMap::new();
    let mut shutdown = false;
    let mut inflight: Option<InflightSlot> = None;
    let mut slot_parity: usize = 0;

    while !shutdown || !sched.is_idle() || inflight.is_some() {
        // ---- drain incoming requests ------------------------------------
        // Identical to the serial loop, except the engine only blocks
        // when there is also no slot in flight (a parked slot's outcomes
        // must be reaped even if the queue is empty).
        loop {
            let msg = if sched.is_idle() && inflight.is_none() && !shutdown {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Request(req, reply) => {
                    let tokens = req.prompt.len() + req.max_new_tokens;
                    let cap = target_cap.min(store_total_tokens);
                    if tokens > cap {
                        let msg = format!(
                            "prompt + max_new_tokens = {tokens} exceeds per-sequence capacity {cap}"
                        );
                        crate::log_error!("request {} rejected: {msg}", req.id);
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    if replies.contains_key(&req.id) || handles.contains_key(&req.id) {
                        let msg = format!("request id {} is already in flight", req.id);
                        crate::log_error!("request rejected: {msg}");
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    if sched_cfg.share_prefix_kv {
                        prefix_keys
                            .insert(req.id, shareable_prefix_keys(&req.prompt, KV_BLOCK_TOKENS));
                    }
                    replies.insert(req.id, PendingReply::new(reply));
                    sched.submit(req);
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        if sched.is_idle() && inflight.is_none() {
            continue;
        }

        // ---- PLAN slot N+1 (ahead of slot N's reap) ---------------------
        // Admission and the capacity/preemption pass run now, against
        // scheduler state as of slot N's *bind* — its spec acceptance
        // and prefill progress are still in flight, so the projection
        // over-estimates remaining budgets and re-plans unfinished
        // chunks. Both errors are conservative (extra reserved rows,
        // never missing ones); the bind stage reconciles.
        let (inflight_seqs, inflight_tokens) = sched.inflight_gen();
        metrics.set_inflight_gen(inflight_seqs, inflight_tokens);
        let mean_gen = metrics.mean_gen_tokens();
        let mut newly_admitted: Vec<RequestId> = Vec::new();
        // Store work takes the shared-store lock for the span of this
        // pass only. The device may be executing slot N right now, but
        // its modeled busy time spins *unlocked*, so the plan genuinely
        // runs concurrently with it (PJRT rounds hold the lock for the
        // whole call — overlap there is bounded by contention, which
        // DESIGN.md §8 is explicit about). Lock order everywhere:
        // target store first, then a draft store — same as the device.
        let mut st = store.lock().expect("target store lock poisoned");
        sched.admit_where(|req, ctx_tokens| {
            let keys: &[PrefixKey] = prefix_keys.get(&req.id).map_or(&[], |k| k.as_slice());
            let di = fleet.assign_draft(req.prompt.len() + req.max_new_tokens);
            let mut companion = di.map(|i| fleet.draft_store(i));
            match policy.admit_with_companion(
                &mut *st,
                companion.as_mut().map(|g| &mut **g),
                req,
                ctx_tokens,
                mean_gen,
                keys,
            ) {
                Some((h, dh)) => {
                    if let (Some(i), Some(dh)) = (di, dh) {
                        draft_handles.insert(req.id, (i, dh));
                        acceptance
                            .entry(req.id)
                            .or_insert_with(|| AcceptanceEwma::new(ewma_weight));
                    }
                    handles.insert(req.id, h);
                    newly_admitted.push(req.id);
                    true
                }
                None => false,
            }
        });
        for id in newly_admitted {
            let skip = st.len(handles[&id]);
            if skip > 0 {
                metrics.record_prefix_attach(skip);
                sched.seq_mut(id).expect("admitted above").prefill_progress = skip;
            }
        }
        let projected = sched.next_round();
        let mut proj_needs: Vec<(RequestId, usize)> = projected
            .decode_batch
            .iter()
            .copied()
            .filter_map(|id| {
                let seq = sched.seq(id).expect("scheduled seq exists");
                let remaining =
                    seq.request.max_new_tokens.saturating_sub(seq.generated.len() + 1);
                if remaining == 0 {
                    return None;
                }
                let k_eff = match draft_handles.get(&id) {
                    Some(&(di, _)) => {
                        let alpha = acceptance.get(&id).and_then(|e| e.estimate());
                        fleet.plan_k(di, alpha, adaptive_k).min(remaining)
                    }
                    None => 0,
                };
                Some((id, k_eff + 1))
            })
            .collect();
        proj_needs.extend(projected.prefills.iter().filter(|c| c.len > 0).map(|c| (c.id, c.len)));
        // Preemption runs *ahead*: a victim chosen here may be a member
        // of the slot currently in the channel or on the device. Its
        // blocks stay pinned by the slot window (deferred free — no
        // aliasing), its handle's generation is retired (the device's
        // store calls reject it cleanly), its outcome is dropped at
        // reap, and re-prefill recomputes everything it loses.
        let _ = sched.ensure_round_capacity(
            &mut *st,
            &mut handles,
            &proj_needs,
            |victim, bill, bytes_freed| {
                if let Some(srt) = runtimes.remove(&victim) {
                    replies.insert(victim, srt.park());
                }
                let mut draft_freed = 0;
                if let Some((di, dh)) = draft_handles.remove(&victim) {
                    draft_freed = fleet.release_draft(di, dh);
                }
                metrics.record_preemption(bill, bytes_freed);
                crate::log_warn!(
                    "kv region exhausted: preempted request {victim} (re-prefill {bill} tokens, \
                     {bytes_freed} device bytes released, {draft_freed} draft bytes)"
                );
            },
        );
        drop(st);
        // The synthetic host-work dial spins here — after the lock is
        // released — so in this executor it overlaps the device's busy
        // spin, where the serial loop pays it serially.
        if cfg.synthetic_host_work_us > 0 {
            device::spin_wait(Duration::from_micros(cfg.synthetic_host_work_us));
        }
        if inflight.is_some() {
            metrics.record_planned_ahead();
        }
        jitter("plan");

        // ---- REAP slot N ------------------------------------------------
        if let Some(slot) = inflight.take() {
            // Block for the completion BEFORE taking the store lock: the
            // device needs the lock to finish the round, so holding it
            // across this recv would deadlock the two actors.
            let comp = match queue.completions.recv() {
                Ok(c) => c,
                Err(_) => {
                    crate::log_error!("device thread died mid-round; engine shutting down");
                    break;
                }
            };
            let mut round_tokens = slot.emitted;
            let mut st = store.lock().expect("target store lock poisoned");
            for (id, outcome) in comp.decode {
                match outcome {
                    Ok(out) => {
                        // A member the plan stage preempted after this
                        // round was dispatched has no runtime (parked)
                        // and no live handle — drop its outcome;
                        // re-prefill reproduces the pending token.
                        if let Some(srt) = runtimes.get_mut(&id) {
                            srt.decode_s += out.step_s;
                            metrics.record_decode_step(out.step_s);
                            srt.next_token = argmax(&out.logits) as i32;
                            if let Some(&h) = handles.get(&id) {
                                if let Err(e) = st.append(h, 1) {
                                    crate::log_error!("kv store append for request {id}: {e}");
                                }
                            }
                        }
                    }
                    Err(e) => {
                        crate::log_error!("decode failed for request {id}: {e}");
                        if let Some(srt) = runtimes.get_mut(&id) {
                            srt.error.get_or_insert(format!("decode failed mid-generation: {e}"));
                        }
                        if let Some(seq) = sched.seq_mut(id) {
                            seq.request.max_new_tokens = seq.generated.len();
                        }
                    }
                }
            }
            for (id, outcome) in comp.spec {
                match outcome {
                    Ok((out, step_s)) => {
                        if let Some(srt) = runtimes.get_mut(&id) {
                            srt.decode_s += step_s;
                            metrics.record_decode_step(step_s);
                            metrics.record_spec(
                                out.proposed as u64,
                                out.accepted_tokens.len() as u64,
                            );
                            if let Some(est) = acceptance.get_mut(&id) {
                                est.observe(out.proposed, out.accepted_tokens.len());
                            }
                            srt.next_token = out.next_token;
                            if let Some(seq) = sched.seq_mut(id) {
                                for &tok in &out.accepted_tokens {
                                    seq.generated.push(tok);
                                    seq.pos += 1;
                                }
                                round_tokens += out.accepted_tokens.len();
                            }
                        }
                    }
                    Err(e) => {
                        crate::log_error!("speculative decode failed for request {id}: {e}");
                        if let Some(srt) = runtimes.get_mut(&id) {
                            srt.error.get_or_insert(format!("decode failed mid-generation: {e}"));
                        }
                        if let Some(seq) = sched.seq_mut(id) {
                            seq.request.max_new_tokens = seq.generated.len();
                        }
                    }
                }
            }
            for (id, chunk, outcome) in comp.prefill {
                match outcome {
                    Ok(out) => {
                        metrics.record_prefill_chunk(chunk.tokens.len());
                        let arrival = match sched.seq_mut(id) {
                            Some(seq) => {
                                debug_assert_eq!(
                                    chunk.start, seq.prefill_progress,
                                    "chunk off its progress"
                                );
                                seq.prefill_progress += chunk.tokens.len();
                                if chunk.last {
                                    seq.prefill_done = true;
                                }
                                seq.request.arrival
                            }
                            // Preempted while its chunk was in flight:
                            // the deposited rows went with the released
                            // blocks; re-admission restarts the prefill.
                            None => continue,
                        };
                        if let Some(keys) = prefix_keys.get(&id) {
                            if let Some(&h) = handles.get(&id) {
                                if let Err(e) = st.publish_prefix(h, keys) {
                                    crate::log_error!("publish prefix for request {id}: {e}");
                                }
                            }
                        }
                        if !chunk.last {
                            if let Some(pending) = replies.get_mut(&id) {
                                pending.prefill_s += out.step_s;
                            }
                            continue;
                        }
                        let logits = out.logits.expect("final chunk returns logits");
                        let next = argmax(&logits) as i32;
                        let Some(pending) = replies.remove(&id) else { continue };
                        runtimes.insert(
                            id,
                            pending.resume(
                                next,
                                out.step_s,
                                arrival,
                                arrival.elapsed().as_secs_f64(),
                            ),
                        );
                        // Draft catch-up prefill ran on the DEVICE this
                        // round (bound as a job next to the final
                        // chunk); its outcome is reconciled below from
                        // `comp.draft_prefill`.
                    }
                    Err(e) => {
                        crate::log_error!("prefill chunk failed for request {id}: {e}");
                        if let Some(seq) = sched.seq_mut(id) {
                            seq.prefill_done = true;
                            seq.request.max_new_tokens = seq.generated.len();
                        }
                        if let Some(pending) = replies.get_mut(&id) {
                            pending.error.get_or_insert(format!("prefill failed: {e}"));
                        }
                    }
                }
            }
            // Draft catch-up outcomes: `Ok` already committed its rows
            // on the device; `Err` downgrades the sequence to plain
            // decode — but ONLY if the binding the job was built from is
            // still the live one. A preemption while the round sat in
            // the channel released (di, dh) and a re-admission may have
            // bound a fresh draft handle; releasing by the stale pair
            // would double-free another sequence's rows.
            for (id, di, dh, res) in comp.draft_prefill {
                if let Err(e) = res {
                    crate::log_warn!(
                        "draft prefill failed for request {id} (plain decode fallback): {e}"
                    );
                    if draft_handles.get(&id) == Some(&(di, dh)) {
                        fleet.release_draft(di, dh);
                        draft_handles.remove(&id);
                    }
                }
            }
            metrics.record_round(slot.batch, round_tokens);
            // Close the reservation window before reaping completions so
            // deferred frees (and completed sequences' blocks) release
            // in the same stage the device work retired.
            if let Some(w) = slot.window {
                st.end_slot_window(w);
            }
            for done in sched.reap_finished() {
                let id = done.request.id;
                if let Some(h) = handles.remove(&id) {
                    st.release(h);
                }
                prefix_keys.remove(&id);
                if let Some((di, dh)) = draft_handles.remove(&id) {
                    fleet.release_draft(di, dh);
                }
                acceptance.remove(&id);
                if let Some(srt) = runtimes.remove(&id) {
                    let total_s = srt.started.elapsed().as_secs_f64();
                    let ttft_s = fallback_ttft(srt.ttft_s, total_s);
                    metrics.record_completion(
                        done.request.prompt.len(),
                        done.generated.len(),
                        ttft_s,
                        total_s,
                    );
                    let _ = srt.reply.send(InferenceResponse {
                        id,
                        tokens: done.generated,
                        queue_s: srt.queue_s,
                        prefill_s: srt.prefill_s,
                        decode_s: srt.decode_s,
                        ttft_s,
                        total_s,
                        error: srt.error,
                    });
                } else if let Some(pending) = replies.remove(&id) {
                    let waited = done.request.arrival.elapsed().as_secs_f64();
                    if pending.error.is_none() {
                        let ttft = pending.ttft_s.unwrap_or(waited);
                        metrics.record_completion(
                            done.request.prompt.len(),
                            done.generated.len(),
                            ttft,
                            waited,
                        );
                    }
                    let _ = pending.reply.send(InferenceResponse {
                        id,
                        tokens: done.generated,
                        queue_s: pending.queue_s.unwrap_or(waited),
                        prefill_s: pending.prefill_s,
                        decode_s: pending.decode_s,
                        ttft_s: pending.ttft_s.unwrap_or(waited),
                        total_s: waited,
                        error: pending.error,
                    });
                }
            }
            metrics.set_kv_device_bytes(
                st.device_bytes_in_use() as u64,
                st.peak_device_bytes_in_use() as u64,
            );
            metrics.set_kv_sharing(st.arena().shared_blocks() as u64, st.arena().cow_copies());
            metrics.set_kv_dequant(st.dequantized_rows());
        }
        retune_prefill_chunk(&chunk_tuner, &metrics, &mut sched);
        jitter("reap");

        // ---- BIND + SUBMIT slot N+1 -------------------------------------
        // Reconciliation: the plan was speculative; recompute the round
        // and the capacity pass from the now-authoritative scheduler
        // state (slot N's acceptance, prefill progress, and completions
        // have all landed). The plan already reserved a superset, so
        // this pass is normally claim-free.
        if sched.is_idle() {
            continue;
        }
        let round = sched.next_round();
        if round.is_idle() {
            continue;
        }
        let mut spec_width: HashMap<RequestId, usize> = HashMap::new();
        let mut needs_rows: Vec<(RequestId, usize)> = round
            .decode_batch
            .iter()
            .copied()
            .filter_map(|id| {
                let seq = sched.seq(id).expect("scheduled seq exists");
                let remaining =
                    seq.request.max_new_tokens.saturating_sub(seq.generated.len() + 1);
                if remaining == 0 {
                    return None;
                }
                // The draft market: this sequence's width for the
                // round — static `k_max` when the market is off,
                // otherwise the breakeven argmax at the live α
                // estimate (`k = 0` ⇒ plain decode).
                let k_eff = match draft_handles.get(&id) {
                    Some(&(di, _)) => {
                        let alpha = acceptance.get(&id).and_then(|e| e.estimate());
                        fleet.plan_k(di, alpha, adaptive_k).min(remaining)
                    }
                    None => 0,
                };
                spec_width.insert(id, k_eff);
                Some((id, k_eff + 1))
            })
            .collect();
        needs_rows.extend(round.prefills.iter().filter(|c| c.len > 0).map(|c| (c.id, c.len)));
        // The bind holds the target-store lock from the capacity pass
        // through window opening: the previous round has already been
        // reaped (the recv above), so nothing contends but the idle
        // device waiting for the next descriptor.
        let mut st = store.lock().expect("target store lock poisoned");
        let held_out: HashSet<RequestId> = sched.ensure_round_capacity(
            &mut *st,
            &mut handles,
            &needs_rows,
            |victim, bill, bytes_freed| {
                if let Some(srt) = runtimes.remove(&victim) {
                    replies.insert(victim, srt.park());
                }
                let mut draft_freed = 0;
                if let Some((di, dh)) = draft_handles.remove(&victim) {
                    draft_freed = fleet.release_draft(di, dh);
                }
                metrics.record_preemption(bill, bytes_freed);
                crate::log_warn!(
                    "kv region exhausted: preempted request {victim} (re-prefill {bill} tokens, \
                     {bytes_freed} device bytes released, {draft_freed} draft bytes)"
                );
            },
        );

        // Emission + step construction: identical to the serial loop
        // (state advances at bind, so the next plan's projections see
        // this slot's emissions immediately).
        let mut round_tokens = 0usize;
        let mut inputs: HashMap<RequestId, (i32, usize)> = HashMap::new();
        for &id in &round.decode_batch {
            if held_out.contains(&id) {
                continue;
            }
            if let Some(srt) = runtimes.get_mut(&id) {
                let token = srt.next_token;
                let seq = sched.seq_mut(id).expect("scheduled seq exists");
                seq.generated.push(token);
                if srt.ttft_s.is_none() {
                    srt.ttft_s = Some(srt.started.elapsed().as_secs_f64());
                }
                let pos = seq.pos;
                seq.pos += 1;
                round_tokens += 1;
                if seq.generated.len() < seq.request.max_new_tokens {
                    inputs.insert(id, (token, pos));
                }
            }
        }
        let mut step_ids = Vec::with_capacity(inputs.len());
        let mut steps = Vec::with_capacity(inputs.len());
        // Speculative members grouped by draft index: weight-streaming
        // cost is shared only within one model's batch, so each group
        // dispatches (on the device thread) as one batch against its
        // own draft model.
        let mut spec_groups: Vec<(Vec<RequestId>, Vec<(SpecStepArgs, Vec<i32>)>)> =
            (0..fleet.num_drafts()).map(|_| (Vec::new(), Vec::new())).collect();
        for &id in &round.decode_batch {
            if let Some(&(token, pos)) = inputs.get(&id) {
                let k_eff = spec_width.get(&id).copied().unwrap_or(0);
                if k_eff > 0 {
                    let &(di, dh) = draft_handles.get(&id).expect("spec width implies a draft");
                    let seq = sched.seq(id).expect("scheduled seq exists");
                    let plen = seq.request.prompt.len();
                    // Brief draft-store lock nested under the target
                    // lock held across the bind — the same target→draft
                    // order the device thread uses, so no cycle.
                    let catchup: Vec<i32> = (fleet.draft_store(di).len(dh)..pos)
                        .map(|p| {
                            if p < plen { seq.request.prompt[p] } else { seq.generated[p - plen] }
                        })
                        .collect();
                    metrics.record_spec_plan(k_eff as u64);
                    spec_groups[di].0.push(id);
                    spec_groups[di].1.push((
                        SpecStepArgs { token, pos, k: k_eff, h: handles[&id], draft_h: dh },
                        catchup,
                    ));
                } else {
                    step_ids.push(id);
                    steps.push(PagedRoundStep { token, pos, handle: handles[&id] });
                }
            }
        }
        let mut pack: Vec<PackedPrefillChunk> = Vec::new();
        let mut pack_ids: Vec<RequestId> = Vec::new();
        for c in &round.prefills {
            if held_out.contains(&c.id) {
                continue;
            }
            let seq = sched.seq(c.id).expect("scheduled seq exists");
            debug_assert_eq!(c.start, seq.prefill_progress, "chunk off its progress: {c:?}");
            if let Some(pending) = replies.get_mut(&c.id) {
                pending
                    .queue_s
                    .get_or_insert_with(|| seq.request.arrival.elapsed().as_secs_f64());
            }
            let tokens: Vec<i32> = seq
                .request
                .prompt
                .iter()
                .chain(seq.generated.iter())
                .copied()
                .skip(c.start)
                .take(c.len)
                .collect();
            pack.push(PackedPrefillChunk {
                h: handles[&c.id],
                start: c.start,
                tokens,
                last: c.last,
            });
            pack_ids.push(c.id);
        }
        // Draft catch-up prefills bind next to their final chunks. The
        // context (prompt + generated) is frozen into the job here,
        // which is sound because a still-prefilling sequence emits no
        // tokens between this bind and its reap.
        let mut draft_prefills: Vec<DraftPrefillJob> = Vec::new();
        for (i, c) in pack.iter().enumerate() {
            if !c.last {
                continue;
            }
            let id = pack_ids[i];
            if let Some(&(di, dh)) = draft_handles.get(&id) {
                let seq = sched.seq(id).expect("scheduled seq exists");
                let ctx: Vec<i32> =
                    seq.request.prompt.iter().chain(seq.generated.iter()).copied().collect();
                draft_prefills.push(DraftPrefillJob { id, di, dh, ctx });
            }
        }

        // SUBMIT: pin the slot's block tables FIRST — the reservation
        // window must be open before the descriptor crosses the channel
        // (K7: windows outlive cross-thread submission, not just slot
        // reap; a plan-stage release while the round sits in the channel
        // defers its blocks until this slot's reap). The gather-scratch
        // parity rides in the descriptor and is selected by the device
        // at execution start, so slot N+1's dense inputs can never
        // alias the slot still executing when this one was bound.
        let mut member_handles: Vec<KvSeqHandle> = steps.iter().map(|s| s.handle).collect();
        for (_, group) in &spec_groups {
            member_handles.extend(group.iter().map(|(a, _)| a.h));
        }
        member_handles.extend(pack.iter().map(|c| c.h));
        let window = match st.begin_slot_window(&member_handles) {
            Ok(w) => Some(w),
            Err(e) => {
                crate::log_error!("slot reservation window: {e}");
                None
            }
        };
        drop(st);
        let desc = RoundDescriptor {
            scratch_slot: slot_parity,
            step_ids,
            steps,
            spec_groups,
            pack_ids,
            pack,
            draft_prefills,
        };
        slot_parity ^= 1;
        inflight = Some(InflightSlot { window, batch: inputs.len(), emitted: round_tokens });
        if queue.submit.send(desc).is_err() {
            crate::log_error!("device thread died; engine shutting down");
            break;
        }
        jitter("bind");
    }
    // Past the loop the scheduler has drained (or the device died): drop
    // the submission side and join the device thread so the models tear
    // down before the engine reports itself gone.
    queue.shutdown();
}

/// A failed-request response: no tokens, the queue time it did spend, and
/// the reason in `error` — so callers draining a batch of receivers see a
/// response for every request instead of a dropped channel.
fn rejection(req: &InferenceRequest, error: String) -> InferenceResponse {
    let waited = req.arrival.elapsed().as_secs_f64();
    InferenceResponse {
        id: req.id,
        tokens: Vec::new(),
        queue_s: waited,
        prefill_s: 0.0,
        decode_s: 0.0,
        // No token was ever produced; report the full wait so the timing
        // record stays internally consistent (ttft == total == queue).
        ttft_s: waited,
        total_s: waited,
        error: Some(error),
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

/// TTFT for a sequence that never stamped one during a decode round —
/// its first token came straight out of the final prefill chunk's logits
/// at completion (`max_new_tokens ≤ 1`, or a generation truncated before
/// its first decode emission): the full arrival→completion wall clock.
///
/// The pre-fix fallback was `queue_s + prefill_s`, which **undercounts
/// after an eviction/re-admission cycle**: `queue_s` stops at the first
/// prefill and `prefill_s` sums only the seconds spent inside prefill
/// executions, so the parked wait between eviction and re-admission (and
/// every round-scheduling gap) appeared in neither term. The elapsed
/// wall clock contains them all by construction, and for the no-eviction
/// case it is what the old sum approximated anyway.
fn fallback_ttft(stamped: Option<f64>, total_s: f64) -> f64 {
    stamped.unwrap_or(total_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn ttft_fallback_covers_requeue_wait_after_eviction() {
        // Regression (ISSUE 5 satellite): a sequence whose first token
        // comes straight out of prefill AFTER an eviction/re-admission
        // cycle. Timeline: 10 ms queue → 20 ms first prefill → evicted →
        // 300 ms parked in the re-admission queue → 25 ms re-prefill →
        // reaped with the first token from the re-prefill logits. The
        // old `queue_s + prefill_s` fallback reported 55 ms — the 300 ms
        // re-queue wait appeared in neither term — while the elapsed
        // wall clock (355 ms) is the time the caller actually waited for
        // the first token.
        let (tx, _rx) = channel();
        let mut parked = PendingReply::new(tx);
        parked.queue_s = Some(0.010); // stopped at the FIRST prefill
        parked.prefill_s = 0.020; // first prefill, before the eviction
        // Re-admission: resume after the re-prefill. `queue_now` (the
        // arrival→now elapsed at re-prefill time) must NOT replace the
        // carried first-prefill queue wait.
        let srt = parked.resume(7, 0.025, Instant::now(), 0.330);
        assert_eq!(srt.queue_s, 0.010, "first-prefill queue wait survives re-admission");
        assert!((srt.prefill_s - 0.045).abs() < 1e-12, "prefill seconds accumulate");
        assert_eq!(srt.ttft_s, None, "no decode emission ever stamped a TTFT");

        let total_s = 0.355; // arrival → reap wall clock
        let fixed = fallback_ttft(srt.ttft_s, total_s);
        assert_eq!(fixed, total_s, "fallback must be the full elapsed wait");
        let old = srt.queue_s + srt.prefill_s;
        assert!(
            fixed - old > 0.29,
            "the pre-fix fallback hid the ~300 ms re-queue wait: {old} vs {fixed}"
        );
        // A stamped TTFT (first token emitted in a decode round) is
        // always preferred over the fallback.
        assert_eq!(fallback_ttft(Some(0.042), total_s), 0.042);
    }

    #[test]
    fn park_resume_roundtrip_carries_every_timing_field() {
        // `SeqRuntime::park` and `PendingReply::resume` are inverses; a
        // field added to one but not the other silently zeroes across an
        // eviction. Drive a full park → resume cycle and check each
        // carried field.
        let (tx, _rx) = channel();
        let mut p = PendingReply::new(tx);
        p.queue_s = Some(0.2);
        p.prefill_s = 0.3;
        p.error = Some("boom".into());
        let mut srt = p.resume(5, 0.1, Instant::now(), 9.9);
        srt.decode_s = 0.7;
        srt.ttft_s = Some(0.55);
        let parked = srt.park();
        assert_eq!(parked.queue_s, Some(0.2));
        assert!((parked.prefill_s - 0.4).abs() < 1e-12);
        assert_eq!(parked.decode_s, 0.7);
        assert_eq!(parked.ttft_s, Some(0.55));
        assert_eq!(parked.error.as_deref(), Some("boom"));
        let back = parked.resume(6, 0.05, Instant::now(), 9.9);
        assert_eq!(back.queue_s, 0.2);
        assert!((back.prefill_s - 0.45).abs() < 1e-12);
        assert_eq!(back.ttft_s, Some(0.55));
    }
}
