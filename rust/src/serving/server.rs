//! The serving engine: a worker thread owning the PJRT runtime, a
//! continuous-batching scheduler, and per-sequence KV state.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{DriftError, Result};
use crate::runtime::tinylm::TinyLmRuntime;
use crate::runtime::Runtime;
use crate::serving::metrics::Metrics;
use crate::serving::request::{InferenceRequest, InferenceResponse, RequestId};
use crate::serving::scheduler::{Scheduler, SchedulerConfig};

enum Msg {
    Request(InferenceRequest, Sender<InferenceResponse>),
    Shutdown,
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub tokens_generated: u64,
    pub report: String,
}

/// Per-sequence runtime state the scheduler doesn't own: host KV state
/// and timing.
struct SeqRuntime {
    kv: crate::runtime::tinylm::KvState,
    next_token: i32,
    prefill_s: f64,
    decode_s: f64,
    first_decode_s: Option<f64>,
    started: Instant,
    queue_s: f64,
    reply: Sender<InferenceResponse>,
}

/// A thread-based serving engine over the TinyLM PJRT runtime.
pub struct ServingEngine {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl ServingEngine {
    /// Start the engine: spawns the worker, which loads the artifacts
    /// (PJRT handles are not `Send`, so the worker thread owns the whole
    /// runtime; the constructor blocks until loading succeeds or fails).
    pub fn start(artifacts_dir: &str, sched_cfg: SchedulerConfig) -> Result<ServingEngine> {
        let metrics = Arc::new(Metrics::default());
        let m2 = Arc::clone(&metrics);
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let dir = artifacts_dir.to_string();
        let worker = std::thread::Builder::new()
            .name("mldrift-serving".into())
            .spawn(move || {
                let model = match Runtime::cpu().and_then(|rt| TinyLmRuntime::load(&rt, &dir)) {
                    Ok(m) => {
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(model, sched_cfg, rx, m2)
            })
            .map_err(|e| DriftError::Serving(format!("spawn worker: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| DriftError::Serving("worker died during startup".into()))??;
        Ok(ServingEngine { tx, worker: Some(worker), metrics })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<InferenceResponse>> {
        let (reply_tx, reply_rx) = channel();
        self.metrics.record_submit();
        self.tx
            .send(Msg::Request(req, reply_tx))
            .map_err(|_| DriftError::Serving("engine stopped".into()))?;
        Ok(reply_rx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| DriftError::Serving("engine dropped request".into()))
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            completed: self.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
            tokens_generated: self
                .metrics
                .tokens_generated
                .load(std::sync::atomic::Ordering::Relaxed),
            report: self.metrics.report(),
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: TinyLmRuntime,
    sched_cfg: SchedulerConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    let mut sched = Scheduler::new(sched_cfg);
    let mut runtimes: HashMap<RequestId, SeqRuntime> = HashMap::new();
    let mut replies: HashMap<RequestId, Sender<InferenceResponse>> = HashMap::new();
    let mut shutdown = false;

    while !shutdown || !sched.is_idle() {
        // Drain incoming requests (non-blocking when busy, blocking when idle).
        loop {
            let msg = if sched.is_idle() && !shutdown {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Request(req, reply) => {
                    replies.insert(req.id, reply);
                    sched.submit(req);
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        if sched.is_idle() {
            continue;
        }

        sched.admit();
        use crate::serving::scheduler::Action;
        match sched.next_action() {
            Action::Prefill(id) => {
                let seq = sched.seq_mut(id).expect("scheduled seq exists");
                let queue_s = seq.request.arrival.elapsed().as_secs_f64();
                let t = Instant::now();
                match model.prefill(&seq.request.prompt) {
                    Ok((logits, kv)) => {
                        let prefill_s = t.elapsed().as_secs_f64();
                        seq.prefill_done = true;
                        let next = argmax(&logits) as i32;
                        let reply = replies.remove(&id).expect("reply channel");
                        runtimes.insert(
                            id,
                            SeqRuntime {
                                kv,
                                next_token: next,
                                prefill_s,
                                decode_s: 0.0,
                                first_decode_s: None,
                                started: seq.request.arrival,
                                queue_s,
                                reply,
                            },
                        );
                    }
                    Err(e) => {
                        crate::log_error!("prefill failed for request {id}: {e}");
                        seq.prefill_done = true;
                        seq.request.max_new_tokens = 0; // finish immediately
                        replies.remove(&id);
                    }
                }
            }
            Action::Decode(id) => {
                let seq = sched.seq_mut(id).expect("scheduled seq exists");
                if let Some(srt) = runtimes.get_mut(&id) {
                    let token = srt.next_token;
                    seq.generated.push(token);
                    let pos = seq.pos;
                    seq.pos += 1;
                    let t = Instant::now();
                    match model.decode_step(token, pos, &mut srt.kv) {
                        Ok(logits) => {
                            let dt = t.elapsed().as_secs_f64();
                            srt.decode_s += dt;
                            srt.first_decode_s.get_or_insert(dt);
                            metrics.record_decode_step(dt);
                            srt.next_token = argmax(&logits) as i32;
                        }
                        Err(e) => {
                            crate::log_error!("decode failed for request {id}: {e}");
                            seq.request.max_new_tokens = seq.generated.len();
                        }
                    }
                }
            }
            Action::Idle => {}
        }

        for done in sched.reap_finished() {
            let id = done.request.id;
            if let Some(srt) = runtimes.remove(&id) {
                let total_s = srt.started.elapsed().as_secs_f64();
                let ttft_s = srt.queue_s + srt.prefill_s + srt.first_decode_s.unwrap_or(0.0);
                metrics.record_completion(
                    done.request.prompt.len(),
                    done.generated.len(),
                    ttft_s,
                    total_s,
                );
                let _ = srt.reply.send(InferenceResponse {
                    id,
                    tokens: done.generated,
                    queue_s: srt.queue_s,
                    prefill_s: srt.prefill_s,
                    decode_s: srt.decode_s,
                    ttft_s,
                    total_s,
                });
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}
