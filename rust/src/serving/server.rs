//! The serving engine: a worker thread owning the PJRT runtime, a
//! round-based continuous-batching scheduler, a shared KV arena, and
//! per-sequence KV state.
//!
//! Each iteration of the worker loop executes one scheduling **round**:
//! the decode batch first (one step for every active sequence — weights
//! stream once per round on the simulated GPU), then up to
//! `max_prefills_per_round` prefills. Admission is gated by the KV
//! arena: a request whose reservation does not fit is *deferred* (stays
//! queued), never failed.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{DriftError, Result};
use crate::kv::{KvArena, KvArenaConfig, KvSeqHandle};
use crate::runtime::tinylm::{RoundStep, TinyLmRuntime};
use crate::runtime::Runtime;
use crate::serving::metrics::Metrics;
use crate::serving::request::{InferenceRequest, InferenceResponse, RequestId};
use crate::serving::scheduler::{Scheduler, SchedulerConfig};

/// KV-arena allocation granule (token positions per block). 16 divides
/// every prefill bucket and keeps worst-case internal fragmentation to
/// 15 positions per sequence.
const KV_BLOCK_TOKENS: usize = 16;

enum Msg {
    Request(InferenceRequest, Sender<InferenceResponse>),
    Shutdown,
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub tokens_generated: u64,
    pub report: String,
}

/// Per-sequence runtime state the scheduler doesn't own: host KV state,
/// the arena reservation, and timing.
struct SeqRuntime {
    kv: crate::runtime::tinylm::KvState,
    next_token: i32,
    prefill_s: f64,
    decode_s: f64,
    /// Arrival → first emitted token, captured when the first decode
    /// outcome lands (so it includes round-scheduling gaps, not just the
    /// step durations).
    ttft_s: Option<f64>,
    started: Instant,
    queue_s: f64,
    reply: Sender<InferenceResponse>,
    /// First mid-flight failure (e.g. a decode error that truncated the
    /// generation); reported in the final response's `error` field.
    error: Option<String>,
}

/// A thread-based serving engine over the TinyLM PJRT runtime.
pub struct ServingEngine {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl ServingEngine {
    /// Start the engine: spawns the worker, which loads the artifacts
    /// (PJRT handles are not `Send`, so the worker thread owns the whole
    /// runtime; the constructor blocks until loading succeeds or fails).
    pub fn start(artifacts_dir: &str, sched_cfg: SchedulerConfig) -> Result<ServingEngine> {
        let metrics = Arc::new(Metrics::default());
        let m2 = Arc::clone(&metrics);
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let dir = artifacts_dir.to_string();
        let worker = std::thread::Builder::new()
            .name("mldrift-serving".into())
            .spawn(move || {
                let model = match Runtime::cpu().and_then(|rt| TinyLmRuntime::load(&rt, &dir)) {
                    Ok(m) => {
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(model, sched_cfg, rx, m2)
            })
            .map_err(|e| DriftError::Serving(format!("spawn worker: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| DriftError::Serving("worker died during startup".into()))??;
        Ok(ServingEngine { tx, worker: Some(worker), metrics })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<InferenceResponse>> {
        let (reply_tx, reply_rx) = channel();
        self.metrics.record_submit();
        self.tx
            .send(Msg::Request(req, reply_tx))
            .map_err(|_| DriftError::Serving("engine stopped".into()))?;
        Ok(reply_rx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| DriftError::Serving("engine dropped request".into()))
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            completed: self.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
            tokens_generated: self
                .metrics
                .tokens_generated
                .load(std::sync::atomic::Ordering::Relaxed),
            report: self.metrics.report(),
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: TinyLmRuntime,
    sched_cfg: SchedulerConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    let mut sched = Scheduler::new(sched_cfg);
    // One shared arena sized for `max_active` full-capacity sequences
    // (per-sequence reservations are block-rounded, so size in blocks,
    // not tokens): with whole-lifetime reservations this makes the slot
    // count the binding constraint and the arena a safety net; shrinking
    // the arena below `max_active` full reservations (or moving to
    // expected-footprint admission, see ROADMAP) is what would make KV
    // backpressure the contended resource in production.
    let m = &model.manifest;
    let mut arena = KvArena::new(KvArenaConfig {
        layers: m.layers,
        heads_kv: m.heads_kv,
        head_dim: m.head_dim,
        block_tokens: KV_BLOCK_TOKENS,
        num_blocks: sched_cfg.max_active.max(1)
            * crate::util::div_ceil(m.cache_capacity.max(1), KV_BLOCK_TOKENS),
    });
    let mut runtimes: HashMap<RequestId, SeqRuntime> = HashMap::new();
    let mut handles: HashMap<RequestId, KvSeqHandle> = HashMap::new();
    let mut replies: HashMap<RequestId, Sender<InferenceResponse>> = HashMap::new();
    let mut shutdown = false;

    while !shutdown || !sched.is_idle() {
        // Drain incoming requests (non-blocking when busy, blocking when idle).
        loop {
            let msg = if sched.is_idle() && !shutdown {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Request(req, reply) => {
                    // Per-sequence ceiling: the decode artifact scatters
                    // K/V rows at `pos`, so a sequence must never outgrow
                    // the model's cache capacity (the arena bounds the
                    // *sum* across sequences, not any one of them).
                    let tokens = req.prompt.len() + req.max_new_tokens;
                    if tokens > model.manifest.cache_capacity {
                        let msg = format!(
                            "prompt + max_new_tokens = {tokens} exceeds cache capacity {}",
                            model.manifest.cache_capacity
                        );
                        crate::log_error!("request {} rejected: {msg}", req.id);
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    // Ids key every per-sequence map (replies before
                    // prefill, handles from admission to reap): a
                    // duplicate in-flight id would cross-wire two
                    // sequences and leak the first one's arena blocks.
                    if replies.contains_key(&req.id) || handles.contains_key(&req.id) {
                        let msg = format!("request id {} is already in flight", req.id);
                        crate::log_error!("request rejected: {msg}");
                        let _ = reply.send(rejection(&req, msg));
                        continue;
                    }
                    replies.insert(req.id, reply);
                    sched.submit(req);
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        if sched.is_idle() {
            continue;
        }

        // Admission, gated by the arena (overflow → defer, i.e. the
        // request stays at the queue head until blocks free up).
        sched.admit_where(|req| {
            let tokens = req.prompt.len() + req.max_new_tokens;
            match arena.claim(tokens) {
                Ok(h) => {
                    handles.insert(req.id, h);
                    true
                }
                Err(_) => false,
            }
        });
        // (Every queued request fits an empty arena: enqueue rejects
        // anything over `cache_capacity`, and the arena holds `max_active`
        // full-capacity reservations — so deferral can never wedge.)

        let round = sched.next_round();

        // ---- decode batch first (latency protection) --------------------
        // Advance scheduler state and collect per-sequence step inputs.
        let mut round_tokens = 0usize;
        let mut inputs: HashMap<RequestId, (i32, usize)> = HashMap::new();
        for &id in &round.decode_batch {
            if let Some(srt) = runtimes.get_mut(&id) {
                let token = srt.next_token;
                let seq = sched.seq_mut(id).expect("scheduled seq exists");
                seq.generated.push(token);
                if srt.ttft_s.is_none() {
                    // The first token is emitted *here* (it was computed by
                    // prefill's logits); stamping after the batched round
                    // would inflate TTFT by the other sequences' steps.
                    srt.ttft_s = Some(srt.started.elapsed().as_secs_f64());
                }
                let pos = seq.pos;
                seq.pos += 1;
                round_tokens += 1;
                // The token just emitted was computed by the *previous*
                // step's logits. A sequence emitting its final token needs
                // no decode step — the step would only produce a successor
                // token (and KV row) that no round will ever consume.
                if seq.generated.len() < seq.request.max_new_tokens {
                    inputs.insert(id, (token, pos));
                }
            }
        }
        // One batched round over the runtime. Per-sequence PJRT decode
        // inside one round keeps numerics exactly single-stream; the
        // batched *latency* (weights streamed once per round) is what
        // `sim::exec::simulate_batched` reports for GPUs.
        let mut step_ids = Vec::with_capacity(inputs.len());
        let mut steps = Vec::with_capacity(inputs.len());
        for (&id, srt) in runtimes.iter_mut() {
            if let Some(&(token, pos)) = inputs.get(&id) {
                step_ids.push(id);
                steps.push(RoundStep { token, pos, kv: &mut srt.kv });
            }
        }
        let outcomes = model.decode_round(steps);
        for (id, outcome) in step_ids.into_iter().zip(outcomes) {
            match outcome {
                Ok(out) => {
                    let srt = runtimes.get_mut(&id).expect("member collected above");
                    srt.decode_s += out.step_s;
                    metrics.record_decode_step(out.step_s);
                    srt.next_token = argmax(&out.logits) as i32;
                    if let Err(e) = arena.append(handles[&id], 1) {
                        crate::log_error!("kv arena append for request {id}: {e}");
                    }
                }
                Err(e) => {
                    crate::log_error!("decode failed for request {id}: {e}");
                    if let Some(srt) = runtimes.get_mut(&id) {
                        srt.error.get_or_insert(format!("decode failed mid-generation: {e}"));
                    }
                    let seq = sched.seq_mut(id).expect("scheduled seq exists");
                    seq.request.max_new_tokens = seq.generated.len();
                }
            }
        }
        if !round.is_idle() {
            // Occupancy = the *executed* kernel batch (sequences emitting
            // their final token need no step and don't amortize weights).
            metrics.record_round(inputs.len(), round_tokens);
        }

        // ---- prefills ---------------------------------------------------
        for &id in &round.prefills {
            let seq = sched.seq_mut(id).expect("scheduled seq exists");
            let queue_s = seq.request.arrival.elapsed().as_secs_f64();
            let t = Instant::now();
            match model.prefill(&seq.request.prompt) {
                Ok((logits, kv)) => {
                    let prefill_s = t.elapsed().as_secs_f64();
                    seq.prefill_done = true;
                    let prompt_len = seq.request.prompt.len();
                    let next = argmax(&logits) as i32;
                    let reply = replies.remove(&id).expect("reply channel");
                    if let Err(e) = arena.append(handles[&id], prompt_len) {
                        crate::log_error!("kv arena append for request {id}: {e}");
                    }
                    runtimes.insert(
                        id,
                        SeqRuntime {
                            kv,
                            next_token: next,
                            prefill_s,
                            decode_s: 0.0,
                            ttft_s: None,
                            started: seq.request.arrival,
                            queue_s,
                            reply,
                            error: None,
                        },
                    );
                }
                Err(e) => {
                    crate::log_error!("prefill failed for request {id}: {e}");
                    seq.prefill_done = true;
                    seq.request.max_new_tokens = 0; // finish immediately
                    if let Some(reply) = replies.remove(&id) {
                        let _ = reply.send(rejection(&seq.request, format!("prefill failed: {e}")));
                    }
                }
            }
        }

        for done in sched.reap_finished() {
            let id = done.request.id;
            if let Some(h) = handles.remove(&id) {
                arena.release(h);
            }
            if let Some(srt) = runtimes.remove(&id) {
                let total_s = srt.started.elapsed().as_secs_f64();
                // No decode step ever ran (max_new_tokens ≤ 1): the first
                // token came straight from prefill, so TTFT ≈ completion.
                let ttft_s = srt.ttft_s.unwrap_or(srt.queue_s + srt.prefill_s);
                metrics.record_completion(
                    done.request.prompt.len(),
                    done.generated.len(),
                    ttft_s,
                    total_s,
                );
                let _ = srt.reply.send(InferenceResponse {
                    id,
                    tokens: done.generated,
                    queue_s: srt.queue_s,
                    prefill_s: srt.prefill_s,
                    decode_s: srt.decode_s,
                    ttft_s,
                    total_s,
                    error: srt.error,
                });
            } else if let Some(reply) = replies.remove(&id) {
                // Defense in depth: a sequence reaped without a runtime
                // whose reply wasn't already answered (today that's
                // impossible — prefill failures respond inline — but a
                // caller must never hang on a dropped channel).
                let waited = done.request.arrival.elapsed().as_secs_f64();
                metrics.record_completion(0, done.generated.len(), waited, waited);
                let _ = reply.send(InferenceResponse {
                    id,
                    tokens: done.generated,
                    queue_s: waited,
                    prefill_s: 0.0,
                    decode_s: 0.0,
                    ttft_s: waited,
                    total_s: waited,
                    error: None,
                });
            }
        }
    }
}

/// A failed-request response: no tokens, the queue time it did spend, and
/// the reason in `error` — so callers draining a batch of receivers see a
/// response for every request instead of a dropped channel.
fn rejection(req: &InferenceRequest, error: String) -> InferenceResponse {
    let waited = req.arrival.elapsed().as_secs_f64();
    InferenceResponse {
        id: req.id,
        tokens: Vec::new(),
        queue_s: waited,
        prefill_s: 0.0,
        decode_s: 0.0,
        // No token was ever produced; report the full wait so the timing
        // record stays internally consistent (ttft == total == queue).
        ttft_s: waited,
        total_s: waited,
        error: Some(error),
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}
