//! Per-kernel cost model.

use crate::codegen::select::{KernelChoice, KernelVariant, Stage};
use crate::device::profile::{DeviceProfile, Precision};
use crate::graph::{Graph, Node, OpKind};


/// Cost breakdown for one kernel launch.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelCost {
    /// Floating/integer operations (MACs counted as 2 ops).
    pub flops: f64,
    /// Bytes moved to/from DRAM (weights at quantized width, activations
    /// at their dtype, texture-cache boost already applied).
    pub bytes: f64,
    /// The *batch-shared* weight portion of `bytes` (same texture boost
    /// applied) — the part batched decode reads once per round, not once
    /// per sequence. Activations, KV-cache traffic, and gather-style
    /// weight reads (embedding rows differ per sequence) are `bytes -
    /// weight_bytes` and scale per sequence.
    pub weight_bytes: f64,
    /// Compute-limited time (s).
    pub t_compute: f64,
    /// Bandwidth-limited time (s).
    pub t_memory: f64,
    /// Launch/driver overhead (s).
    pub t_launch: f64,
}

impl KernelCost {
    /// Total kernel time under the roofline: bound by the slower resource.
    pub fn total(&self) -> f64 {
        self.t_compute.max(self.t_memory) + self.t_launch
    }

    /// True when compute-bound.
    pub fn compute_bound(&self) -> bool {
        self.t_compute >= self.t_memory
    }

    /// Roofline time for this kernel serving a decode batch of `batch`
    /// sequences in one launch (§3.7 applied across users):
    ///
    /// * weight bytes stream **once** for the whole batch;
    /// * activation + KV bytes are per-sequence and scale with `batch`;
    /// * per-sequence FLOPs scale with `batch` (a batched matvec does
    ///   `batch` times the MACs);
    /// * launch overhead is paid once per round, not once per sequence.
    ///
    /// `batched_total(1)` equals [`total`](Self::total) exactly, so the
    /// single-stream numbers are the B=1 point of the same model.
    pub fn batched_total(&self, batch: usize) -> f64 {
        if batch <= 1 {
            return self.total(); // bit-exact B=1 ⇒ single-stream identity
        }
        (self.t_compute * batch as f64).max(self.batched_t_memory(batch)) + self.t_launch
    }

    /// Roofline time for this kernel running the **speculative verify
    /// pass**: the target scores all `k + 1` positions of `batch`
    /// sequences in one launch (a `(k + 1)`-token prefill per sequence,
    /// batched). Weights still stream once; per-sequence traffic (KV
    /// reads, activations) and FLOPs scale with `batch × (k + 1)` —
    /// position `pos + i` attends over nearly the same context as a
    /// decode step, so each extra scored position costs one more
    /// per-sequence share, never another weight pass. Structurally this
    /// IS [`batched_total`](Self::batched_total) at `batch × (k + 1)`, so
    /// `k = 0` is the plain decode round bit-exactly — the draft/verify
    /// split degenerates to the non-speculative model instead of forking
    /// it.
    pub fn speculative_verify_total(&self, batch: usize, k: usize) -> f64 {
        self.batched_total(batch.max(1) * (k + 1))
    }

    /// Roofline time for this kernel executing a **packed prefill**: one
    /// launch covers several sequences' chunks, each contributing a
    /// per-sequence work share in `scales` (its chunk's fraction of the
    /// work the kernel was compiled for — linear token share for the
    /// GEMM/norm/RoPE family, the quadratic attention share for the
    /// weightless score/softmax kernels; the split is the caller's,
    /// [`crate::sim::exec::packed_prefill_time_s`]).
    ///
    /// * compute scales with the **summed** share (the flattened
    ///   `(Σ tokens, d_model)` GEMM does every sequence's MACs);
    /// * weight bytes stream **once** for the whole pack — the §3.7
    ///   bandwidth argument applied to concurrent prompts — while
    ///   per-sequence bytes (activations, KV writes) scale with the sum;
    /// * launch overhead is paid once per pack, not once per prompt —
    ///   the term that dominates short-chunk packs on phone-class
    ///   profiles.
    ///
    /// `packed_prefill_total(&[1.0])` equals [`total`](Self::total)
    /// exactly (one full-plan sequence degenerates to the plain kernel),
    /// and shares summing to 1 across chunks reproduce the one-shot
    /// kernel body, so chunking redistributes work without inventing or
    /// losing any. An empty/zero pack costs nothing.
    pub fn packed_prefill_total(&self, scales: &[f64]) -> f64 {
        let s: f64 = scales.iter().sum();
        if s <= 0.0 {
            return 0.0;
        }
        let mem = if self.bytes <= 0.0 {
            0.0
        } else {
            self.t_memory * (self.weight_bytes + s * (self.bytes - self.weight_bytes))
                / self.bytes
        };
        (self.t_compute * s).max(mem) + self.t_launch
    }

    /// Wall-clock for one serving round under a bounded-depth pipelined
    /// executor. `device_exec_s` is the round's device time (the kernel
    /// launches), `host_plan_s` the host-side work attached to the round
    /// — planning the *next* round (admission, capacity reservation,
    /// prefill-pack assembly) plus the submit/sync overhead.
    ///
    /// * `depth <= 1` is the unpipelined loop: host work serializes with
    ///   the device, so the round costs `device_exec_s + host_plan_s`
    ///   exactly (bitwise — this is the depth-1 identity the engine's
    ///   gate relies on).
    /// * `depth >= 2` overlaps the host plan of round N+1 with round N's
    ///   device execution, so the visible host overhead collapses to
    ///   `max(0, host_plan_s − device_exec_s)` — zero whenever planning
    ///   hides entirely under the device.
    ///
    /// Depth beyond 2 changes nothing: there is one device and one host,
    /// so a single planned-ahead slot already keeps both busy — extra
    /// slots only add reconciliation state, which is why the engine
    /// defaults to 2 and the sweep shows 3 flat.
    pub fn pipelined_round_time_s(device_exec_s: f64, host_plan_s: f64, depth: usize) -> f64 {
        if depth <= 1 {
            device_exec_s + host_plan_s
        } else {
            device_exec_s + (host_plan_s - device_exec_s).max(0.0)
        }
    }

    /// Memory-limited time for a batch-`batch` launch: weight bytes once,
    /// per-sequence bytes × batch. The single source of the batched
    /// scaling rule — `batched_total` and the round simulator both use it.
    pub fn batched_t_memory(&self, batch: usize) -> f64 {
        if batch <= 1 {
            return self.t_memory; // bit-exact single-stream identity
        }
        if self.bytes <= 0.0 {
            return 0.0;
        }
        let per_seq = self.bytes - self.weight_bytes;
        self.t_memory * (self.weight_bytes + batch as f64 * per_seq) / self.bytes
    }
}

/// FLOP count for a node (2 ops per MAC).
pub fn node_flops(g: &Graph, n: &Node) -> f64 {
    let out = n.shape;
    let out_el = out.elements() as f64;
    let base = match &n.kind {
        OpKind::Conv2D { kh, kw, .. } => {
            let in_c = n.weight.map(|w| w.shape.i).unwrap_or(0) as f64;
            2.0 * out_el * in_c * (*kh as f64) * (*kw as f64)
        }
        OpKind::FullyConnected { .. } => {
            let in_c = n.weight.map(|w| w.shape.i).unwrap_or(0) as f64;
            2.0 * out_el * in_c
        }
        OpKind::MatMul { .. } => {
            let k = g.nodes[n.inputs[0]].shape.c as f64;
            2.0 * out_el * k
        }
        OpKind::Embedding { .. } => out_el, // gather
        OpKind::RmsNorm { .. } | OpKind::LayerNorm { .. } | OpKind::GroupNorm { .. } => {
            4.0 * out_el
        }
        OpKind::FusedAddRmsNorm { .. } => 5.0 * out_el,
        OpKind::Softmax => 5.0 * out_el,
        OpKind::Rope { .. } | OpKind::FusedQkvRope { .. } => 4.0 * out_el,
        OpKind::QuantAct => 2.0 * out_el,
        OpKind::Elementwise(_) | OpKind::Binary(_) => out_el,
        OpKind::Upsample2x | OpKind::AvgPool { .. } | OpKind::Reshape { .. }
        | OpKind::Transpose { .. } | OpKind::Concat { .. } => 0.0,
        OpKind::Input | OpKind::Const => 0.0,
    };
    // Epilogues and fused adds are ~free relative to matmuls but counted.
    base + (n.epilogue.len() as f64 + n.fused_adds.len() as f64) * out_el
}

/// Weight bytes read by a node's kernel (quantized width, before the
/// texture-cache boost).
pub fn node_weight_bytes(n: &Node) -> f64 {
    match &n.weight {
        // Embedding gathers read only the used rows; lm_head-style FC reads
        // all of them. Embedding op → rows = out elements / dim.
        Some(w) => match &n.kind {
            OpKind::Embedding { dim, .. } => {
                let rows = n.shape.elements() / dim;
                w.dtype.bytes_for(rows * dim) as f64
            }
            _ => w.bytes() as f64,
        },
        None => 0.0,
    }
}

/// The *batch-shared* portion of a node's weight read: dense weights are
/// streamed once for every sequence in a batched round, but gather-style
/// reads (embedding rows) touch different rows per sequence and scale
/// with batch — so they count as per-sequence traffic, not shared.
pub fn node_shared_weight_bytes(n: &Node) -> f64 {
    match &n.kind {
        OpKind::Embedding { .. } => 0.0,
        _ => node_weight_bytes(n),
    }
}

/// Bytes moved by a node's kernel.
pub fn node_bytes(g: &Graph, n: &Node, choice: &KernelChoice) -> f64 {
    let act_bytes = |node: &Node| -> f64 {
        node.dtype.bytes_for(node.shape.padded_elements()) as f64
    };
    // Inputs (reads).
    let mut bytes: f64 = n.inputs.iter().map(|&i| act_bytes(&g.nodes[i])).sum();
    bytes += n.fused_adds.iter().map(|&(i, _)| act_bytes(&g.nodes[i])).sum::<f64>();
    // Weights at quantized width (the decisive decode-path term).
    bytes += node_weight_bytes(n);
    // Output (write).
    bytes += act_bytes(n);
    // Texture path: better cache behaviour on spatially-local reads.
    if choice.act_storage.is_texture() {
        bytes /= choice_boost(choice);
    }
    bytes
}

/// Bytes one token position moves through the **KV-dequant loop** when
/// blocks are stored int8 ([`crate::kv::PagedKvStore::new_quantized`]):
/// the gather reads the int8 K+V payload plus its two f32 scales
/// (`quantized_bytes_per_token`, the
/// [`crate::kv::KvArenaConfig::quantized_bytes_per_token`] value) and
/// writes the dequantized f32 rows into the dense scratch — a 4× widen
/// of the payload on the way out. This is the byte model
/// [`crate::sim::exec::kv_dequant_overhead_s`] prices by bandwidth;
/// keeping it here keeps every traffic formula in the cost module.
pub fn kv_dequant_bytes_per_position(quantized_bytes_per_token: usize) -> f64 {
    let payload = quantized_bytes_per_token.saturating_sub(2 * 4) as f64;
    quantized_bytes_per_token as f64 + 4.0 * payload
}

fn choice_boost(choice: &KernelChoice) -> f64 {
    // Boost applies to texture-friendly access patterns; stored on the
    // choice as a constant factor (device-level boost is applied by the
    // caller via the profile; this keeps cost pure).
    match choice.variant {
        KernelVariant::Conv2dGeneric | KernelVariant::Conv2dWinograd => 1.15,
        _ => 1.0,
    }
}

/// Arithmetic precision the kernel computes in.
pub fn kernel_precision(n: &Node, choice: &KernelChoice, dev: &DeviceProfile) -> Precision {
    match choice.variant {
        KernelVariant::FcGemmInt8Dot => Precision::Int8,
        // Decode matvec dequantizes to fp16 in-register: compute runs at
        // float rate (it's memory-bound anyway).
        _ => {
            if dev.extensions.fp16_arith && n.dtype == crate::tensor::DType::F16 {
                Precision::Fp16
            } else {
                Precision::Fp32
            }
        }
    }
}

/// Full cost for one node under a kernel choice.
pub fn kernel_cost(
    g: &Graph,
    n: &Node,
    choice: &KernelChoice,
    dev: &DeviceProfile,
    _stage: Stage,
) -> KernelCost {
    if n.absorbed_into.is_some() || !n.kind.is_compute() {
        return KernelCost::default();
    }
    let mut flops = node_flops(g, n);
    if choice.variant == KernelVariant::Conv2dWinograd {
        flops /= 2.25; // F(4×4,3×3) multiply reduction
    }
    // Kernel-family efficiency: `eff_compute` is calibrated on tuned FC
    // GEMMs; spatial convolutions and attention matmuls achieve a lower
    // fraction of peak (irregular access, small K tiles). Vendors with
    // texture-path conv kernels (Adreno, Apple) retain more of it —
    // calibrated against the paper's SD end-to-end checkpoints (§4.1).
    let family_eff = match choice.variant {
        KernelVariant::Conv2dGeneric | KernelVariant::Conv2dWinograd => {
            match dev.vendor {
                crate::device::profile::Vendor::Qualcomm => 0.95,
                crate::device::profile::Vendor::Apple => 0.60,
                crate::device::profile::Vendor::Arm => 0.65,
                _ => 0.50,
            }
        }
        KernelVariant::MatMulTiled => 0.65,
        _ => 1.0,
    };
    let bytes = node_bytes(g, n, choice);
    // Batch-shared weight bytes, under the same texture boost so the
    // shared/per-sequence split stays a consistent fraction of the total.
    let weight_bytes = if choice.act_storage.is_texture() {
        node_shared_weight_bytes(n) / choice_boost(choice)
    } else {
        node_shared_weight_bytes(n)
    };
    let precision = kernel_precision(n, choice, dev);
    let gflops = dev.effective_gflops(precision).max(1e-9);
    let bw = dev.effective_bandwidth().max(1e-9);
    let tex_boost = if choice.act_storage.is_texture() { dev.texture_cache_boost } else { 1.0 };
    KernelCost {
        flops,
        bytes,
        weight_bytes,
        t_compute: flops / (gflops * family_eff * 1e9),
        t_memory: bytes / (bw * 1e9 * tex_boost),
        t_launch: dev.launch_overhead_us * 1e-6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::select::{select_kernel, Stage};
    use crate::device::registry::device;
    use crate::graph::Graph;
    use crate::tensor::{DType, Shape};

    fn fc_graph(seq: usize, wdtype: DType) -> (Graph, usize) {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, seq, 2048), DType::F16);
        let fc = g.fully_connected("fc", x, 2048, wdtype).unwrap();
        g.output(fc);
        (g, fc)
    }

    #[test]
    fn decode_fc_is_memory_bound_prefill_compute_bound() {
        let dev = device("adreno_750").unwrap();
        // Decode: seq 1.
        let (g, fc) = fc_graph(1, DType::I8);
        let choice = select_kernel(&g.nodes[fc], &dev, Stage::Decode);
        let c = kernel_cost(&g, &g.nodes[fc], &choice, &dev, Stage::Decode);
        assert!(!c.compute_bound(), "decode matvec must be memory-bound: {c:?}");
        // Prefill: seq 1024.
        let (g, fc) = fc_graph(1024, DType::I8);
        let choice = select_kernel(&g.nodes[fc], &dev, Stage::Prefill);
        let c = kernel_cost(&g, &g.nodes[fc], &choice, &dev, Stage::Prefill);
        assert!(c.compute_bound(), "long-seq GEMM must be compute-bound: {c:?}");
    }

    #[test]
    fn quantization_speeds_decode_not_prefill() {
        let dev = device("adreno_750").unwrap();
        let time = |wdtype: DType, seq: usize, stage: Stage| {
            let (g, fc) = fc_graph(seq, wdtype);
            let choice = select_kernel(&g.nodes[fc], &dev, stage);
            kernel_cost(&g, &g.nodes[fc], &choice, &dev, stage).total()
        };
        let d8 = time(DType::I8, 1, Stage::Decode);
        let d4 = time(DType::I4, 1, Stage::Decode);
        // int4 halves weight traffic → decode nearly 2× faster (launch
        // overhead prevents exactly 2×).
        let ratio = d8 / d4;
        assert!(ratio > 1.4 && ratio < 2.1, "decode q8/q4 ratio {ratio}");
        let p8 = time(DType::I8, 1024, Stage::Prefill);
        let p4 = time(DType::I4, 1024, Stage::Prefill);
        let pratio = p8 / p4;
        assert!(pratio < 1.1, "prefill barely moves with weight quant: {pratio}");
    }

    #[test]
    fn batched_decode_amortizes_weight_reads() {
        let dev = device("adreno_750").unwrap();
        let (g, fc) = fc_graph(1, DType::I8);
        let choice = select_kernel(&g.nodes[fc], &dev, Stage::Decode);
        let c = kernel_cost(&g, &g.nodes[fc], &choice, &dev, Stage::Decode);
        assert!(c.weight_bytes > 0.0 && c.weight_bytes < c.bytes);
        // B=1 batched total is exactly the single-stream total.
        assert_eq!(c.batched_total(1), c.total());
        // A weight-dominated matvec barely slows down at B=8 …
        let t1 = c.batched_total(1);
        let t8 = c.batched_total(8);
        assert!(t8 < 2.0 * t1, "decode FC round at B=8 must cost ≪ 8×: {t8} vs {t1}");
        // … so per-token cost drops steeply, and monotonically in B.
        let mut prev = f64::INFINITY;
        for b in [1usize, 2, 4, 8, 16] {
            let per_token = c.batched_total(b) / b as f64;
            assert!(per_token < prev, "per-token cost must fall with batch (B={b})");
            prev = per_token;
        }
    }

    #[test]
    fn speculative_verify_amortizes_weights_like_a_short_prefill() {
        let dev = device("adreno_750").unwrap();
        let (g, fc) = fc_graph(1, DType::I8);
        let choice = select_kernel(&g.nodes[fc], &dev, Stage::Decode);
        let c = kernel_cost(&g, &g.nodes[fc], &choice, &dev, Stage::Decode);
        // k = 0 degenerates to the plain decode round, bit-exactly.
        assert_eq!(c.speculative_verify_total(1, 0), c.batched_total(1));
        assert_eq!(c.speculative_verify_total(4, 0), c.batched_total(4));
        // Scoring k+1 positions costs far less than k+1 rounds for a
        // weight-dominated kernel (the whole point of the verify pass)…
        let k = 3;
        let verify = c.speculative_verify_total(1, k);
        assert!(
            verify < 0.5 * (k + 1) as f64 * c.total(),
            "verify {verify} vs {} sequential rounds",
            (k + 1) as f64 * c.total()
        );
        // … but is monotone in k (each position still pays its
        // per-sequence traffic).
        assert!(c.speculative_verify_total(1, 2) > c.speculative_verify_total(1, 1));
    }

    #[test]
    fn packed_prefill_amortizes_weights_and_launch() {
        let dev = device("adreno_750").unwrap();
        let (g, fc) = fc_graph(128, DType::I8);
        let choice = select_kernel(&g.nodes[fc], &dev, Stage::Prefill);
        let c = kernel_cost(&g, &g.nodes[fc], &choice, &dev, Stage::Prefill);
        // A single full-share pack degenerates to the plain kernel.
        assert_eq!(c.packed_prefill_total(&[1.0]), c.total());
        // Shares are additive: splitting one sequence's work across
        // chunk entries of the same pack changes nothing.
        assert_eq!(
            c.packed_prefill_total(&[0.25, 0.5, 0.25]),
            c.packed_prefill_total(&[1.0])
        );
        // Packing N short chunks beats N separate launches: the pack
        // pays one launch (and streams weights once) for the same work.
        let n = 4;
        let shares = vec![0.25; n];
        let packed = c.packed_prefill_total(&shares);
        let sequential: f64 = (0..n).map(|_| c.packed_prefill_total(&[0.25])).sum();
        assert!(
            packed < sequential,
            "pack {packed} must undercut {n} separate launches {sequential}"
        );
        // Degenerate packs cost nothing.
        assert_eq!(c.packed_prefill_total(&[]), 0.0);
        assert_eq!(c.packed_prefill_total(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn batching_does_not_help_weightless_kernels() {
        // Attention score matmuls read per-sequence KV, not shared
        // weights: their memory time scales linearly with the batch.
        let dev = device("adreno_750").unwrap();
        let mut g = Graph::new("t");
        let q = g.input("q", Shape::bhwc(4, 1, 2, 256), DType::F16);
        let k = g.input("k", Shape::bhwc(4, 1, 1024, 256), DType::F16);
        let s = g.matmul("scores", q, k, true).unwrap();
        g.output(s);
        let choice = select_kernel(&g.nodes[s], &dev, Stage::Decode);
        let c = kernel_cost(&g, &g.nodes[s], &choice, &dev, Stage::Decode);
        assert_eq!(c.weight_bytes, 0.0);
        let body1 = c.batched_total(1) - c.t_launch;
        let body8 = c.batched_total(8) - c.t_launch;
        assert!(
            (body8 - 8.0 * body1).abs() < 1e-12,
            "KV traffic is per-sequence: {body8} vs 8×{body1}"
        );
    }

    #[test]
    fn absorbed_nodes_cost_nothing() {
        let dev = device("adreno_750").unwrap();
        let (mut g, fc) = fc_graph(8, DType::I8);
        let act = g.unary("gelu", fc, crate::graph::EwOp::Gelu).unwrap();
        g.outputs = vec![act];
        crate::fusion::passes::fuse_elementwise(&mut g);
        let choice = select_kernel(&g.nodes[act], &dev, Stage::Single);
        let c = kernel_cost(&g, &g.nodes[act], &choice, &dev, Stage::Single);
        assert_eq!(c.total(), 0.0);
    }

    #[test]
    fn winograd_cuts_conv_compute() {
        let dev = device("adreno_750").unwrap();
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 64, 64, 320), DType::F16);
        let c = g.conv2d("c", x, 320, 3, 1, 1, DType::F16).unwrap();
        g.output(c);
        let node = &g.nodes[c];
        let win = select_kernel(node, &dev, Stage::Single);
        assert_eq!(win.variant, KernelVariant::Conv2dWinograd);
        let cost_win = kernel_cost(&g, node, &win, &dev, Stage::Single);
        let mut generic = win.clone();
        generic.variant = KernelVariant::Conv2dGeneric;
        let cost_gen = kernel_cost(&g, node, &generic, &dev, Stage::Single);
        assert!(cost_win.t_compute < cost_gen.t_compute);
    }

    #[test]
    fn int8_dot_path_fast_on_extension_devices() {
        let adreno = device("adreno_750").unwrap();
        let nv = device("rtx_4090").unwrap();
        let (g, fc) = fc_graph(1024, DType::I8);
        let a_choice = select_kernel(&g.nodes[fc], &adreno, Stage::Prefill);
        let a = kernel_cost(&g, &g.nodes[fc], &a_choice, &adreno, Stage::Prefill);
        // Adreno int8 path beats its own fp16 path ~2–3×.
        let mut f16_choice = a_choice.clone();
        f16_choice.variant = KernelVariant::FcGemmTiled;
        let f = kernel_cost(&g, &g.nodes[fc], &f16_choice, &adreno, Stage::Prefill);
        assert!(a.t_compute < f.t_compute);
        // NVIDIA prefill runs at fp32 rate (tensor cores unreachable).
        let n_choice = select_kernel(&g.nodes[fc], &nv, Stage::Prefill);
        assert_eq!(n_choice.variant, KernelVariant::FcGemmTiled);
        let n = kernel_cost(&g, &g.nodes[fc], &n_choice, &nv, Stage::Prefill);
        assert_eq!(
            n.t_compute,
            n.flops / (nv.fp32_gflops * nv.eff_compute * 1e9),
            "fp32 fallback"
        );
    }
}
