//! Serving-level simulation: scheduler + KV arena + batched cost model.
//!
//! The kernel-level simulator ([`crate::sim::exec`]) prices one round at
//! a given batch size; this module closes the loop and prices a whole
//! *workload* — admission, paged growth, preemption, re-prefill — so KV
//! reservation disciplines can be compared at **fixed arena memory**:
//!
//! * [`KvReservation::Lifetime`]: claim `prompt + max_new_tokens` at
//!   admission (PR-1 discipline). Overflow-free, but short-generating
//!   sequences strand their unwritten reservation as internal
//!   fragmentation, capping concurrency.
//! * [`KvReservation::Paged`]: claim the prompt, grow block-by-block,
//!   gate admission on the expected footprint
//!   ([`crate::serving::AdmissionPolicy`]). Occupancy tracks actual
//!   footprints; mid-round exhaustion preempts (evict → requeue →
//!   re-prefill), and the simulator charges that re-prefill via
//!   [`crate::sim::exec::packed_prefill_time_s`] (quadratic attention
//!   share included) so thrashing is priced, not hidden.
//!
//! Per-token KV accounting is one row per emitted token (the
//! final-emission row the engine skips is ≤ one block per sequence and
//! identical across disciplines, so comparisons are unaffected).
//!
//! **Shared-prefix workloads** ([`simulate_serving_shared`]): requests
//! carry synthetic prompts with a common per-group prefix; admission
//! attaches published prefix blocks
//! ([`AdmissionPolicy::admit_prefixed`]) so only *unique* blocks gate
//! capacity, prefill skips the attached positions, committed chunks
//! publish their blocks for later arrivals, and growth into a shared
//! block is a priced copy-on-write (an extra block, preemption on
//! exhaustion — the same `ensure` seam as plain growth). With the
//! `quantized` flag the arena is accounted at int8 block bytes and
//! every decode round is billed the f32 re-materialization of the
//! positions its gather touches
//! ([`crate::sim::exec::kv_dequant_overhead_s`]) — the capacity
//! multiplier is never free.
//!
//! **Chunked + packed prefill**
//! ([`SchedulerConfig::prefill_chunk_tokens`] > 0): each round's prefill
//! pack — chunks from multiple sequences — is billed as one flattened
//! GEMM with one launch set and one host sync
//! ([`packed_prefill_time_s`]), and per-request TTFT is stamped at the
//! round whose pack carried the request's *final* chunk. With chunking
//! off, prefills bill per prompt (launch + sync each) — the sequential
//! baseline the TTFT-burst sweep compares against.

use std::collections::{HashMap, HashSet};

use crate::kv::{shareable_prefix_keys, KvArena, KvArenaConfig, KvSeqHandle, PrefixKey};
use crate::serving::request::{InferenceRequest, RequestId};
use crate::serving::scheduler::{ChunkAutotuner, Scheduler, SchedulerConfig};
use crate::serving::{blended_mean_gen, AdmissionPolicy};
use crate::serving::registry::{AcceptanceEwma, DraftController, SpecRoundCost};
use crate::sim::exec::{
    expected_accepted_tokens, expected_draft_steps, kv_dequant_overhead_s,
    mixed_verify_time_s, packed_prefill_time_s, paged_gather_overhead_s,
    pipelined_round_time_s, simulate_batched, verify_time_s, ExecutionPlan, PackedChunkCost,
};
use crate::util::div_ceil;
use crate::util::stats::Summary;

/// One simulated request: what the client *asks for* vs what the model
/// *actually generates* (the gap lifetime reservation pays for).
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub prompt_tokens: usize,
    /// The client's generation budget — what admission must assume.
    pub max_new_tokens: usize,
    /// Tokens actually generated before EOS (≤ `max_new_tokens`).
    pub actual_new_tokens: usize,
}

/// One request of a **shared-prefix workload** ([`simulate_serving_shared`]):
/// a [`SimRequest`] whose prompt starts with a prefix common to every
/// request in the same `prefix_group` — the system-prompt / few-shot
/// template shape prefix sharing multiplies concurrency on.
#[derive(Clone, Copy, Debug)]
pub struct PrefixSimRequest {
    pub prompt_tokens: usize,
    pub max_new_tokens: usize,
    pub actual_new_tokens: usize,
    /// Requests with equal group ids share their prefix tokens exactly.
    pub prefix_group: u64,
    /// Leading prompt positions drawn from the group (clamped to the
    /// prompt length); the rest of the prompt is unique per request.
    pub shared_prefix_tokens: usize,
}

/// Deterministic synthetic token stream (splitmix-style finalizer): the
/// simulator needs prompts whose *equality structure* is controlled —
/// same `(seed, pos)` ⇒ same token, different seeds ⇒ tokens that never
/// align for a whole hash block — without a randomness source.
fn synth_token(seed: u64, pos: usize) -> i32 {
    let mut x = seed ^ (pos as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    (x & 0x7fff_ffff) as i32
}

/// KV reservation discipline under test.
#[derive(Clone, Copy, Debug)]
pub enum KvReservation {
    /// Whole-lifetime claim at admission; never grows, never preempts.
    Lifetime,
    /// Prompt-only claim, on-demand growth, expectation-gated admission,
    /// preemption on exhaustion.
    Paged { policy: AdmissionPolicy },
}

/// Which mean-generation-length estimate admission is fed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GenLenEstimator {
    /// Average completed sequences only — the survivorship-biased pre-fix
    /// form, kept as an ablation: short generations finish first, so the
    /// warm-up mean is biased low and admission over-admits.
    CompletedOnly,
    /// Blend in-flight generated-so-far lower bounds into the estimate
    /// ([`blended_mean_gen`]) — the engine's behaviour.
    #[default]
    Blended,
    /// 90th percentile of the pooled generation-length samples
    /// (completed lengths ∪ in-flight generated-so-far lower bounds),
    /// floored at the blended mean so it can only be *more* conservative.
    /// On bimodal workloads the mean splits the modes and still
    /// over-admits the long mode; the p90 tracks the long mode itself,
    /// cutting warm-up preemptions further at the cost of admitting
    /// fewer speculative shorts. Cold start (no completions) stays
    /// worst-case, like the other estimators.
    P90,
}

/// Speculative-decode parameters for an acceptance-rate-parameterized
/// simulation ([`simulate_serving_spec`]).
#[derive(Clone, Copy, Debug)]
pub struct SpecSim {
    /// Draft proposals per sequence per round.
    pub k: usize,
    /// Per-token draft/target agreement probability α ∈ [0, 1]; a round
    /// accepts `E[a] = Σ_{i=1..k} α^i` proposals in expectation
    /// ([`expected_accepted_tokens`]), tracked per sequence with a
    /// fractional-credit accumulator so long-run token counts match the
    /// expectation exactly without a noise source.
    pub acceptance: f64,
}

/// Serving-simulation tuning.
#[derive(Clone, Copy, Debug)]
pub struct ServingSimConfig {
    pub sched: SchedulerConfig,
    pub arena: KvArenaConfig,
    pub reservation: KvReservation,
    /// Host/GPU sync per executed round (s).
    pub sync_s: f64,
    /// Sequence length the prefill plan was compiled at
    /// ([`packed_prefill_time_s`] scales the per-chunk linear and
    /// quadratic work shares from it).
    pub prefill_plan_tokens: usize,
    /// Mean-generation estimator admission is fed.
    pub estimator: GenLenEstimator,
}

/// Pipelined-executor parameters for the serving simulation — the sim
/// half of the engine's bounded-depth slot queue, so sim and engine keep
/// running identical policy.
///
/// Every round bills its host work (`sync_s + host_plan_s`) through
/// [`pipelined_round_time_s`]: at `depth = 1` that is the additive
/// unpipelined loop **bitwise** (the depth-1 identity gate), at
/// `depth >= 2` round N+1's planning overlaps round N's device
/// execution and only `max(0, host − device)` remains visible. Depth
/// beyond 2 changes nothing — one device, one host — which the sweep
/// and the equality test below both pin.
#[derive(Clone, Copy, Debug)]
pub struct PipelineSimConfig {
    /// Bounded pipeline depth (slots in flight). 1 = today's loop.
    pub depth: usize,
    /// Host planning work per round — admission, capacity reservation,
    /// prefill-pack assembly — billed on top of `sync_s` (s).
    pub host_plan_s: f64,
}

impl Default for PipelineSimConfig {
    fn default() -> Self {
        PipelineSimConfig { depth: 1, host_plan_s: 0.0 }
    }
}

/// What a workload run produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingSimReport {
    pub rounds: usize,
    pub completed: usize,
    pub total_s: f64,
    pub decode_s: f64,
    pub prefill_s: f64,
    /// Block-table gather indirection billed to paged rounds
    /// ([`paged_gather_overhead_s`]); 0 under the dense lifetime layout.
    pub gather_s: f64,
    pub generated_tokens: usize,
    /// All prefilled positions, initial prefills *and* re-prefills.
    pub prefill_tokens: usize,
    pub preemptions: usize,
    /// Positions recomputed because of eviction.
    pub reprefill_tokens: usize,
    /// Mean executed decode-batch size over rounds that decoded.
    pub mean_occupancy: f64,
    pub peak_occupancy: usize,
    pub peak_blocks_in_use: usize,
    /// Peak concurrent live sequences (what the pre-paging dense runtime
    /// would have held a full-capacity KV tensor for — the device-memory
    /// sweep's dense baseline).
    pub peak_seqs: usize,
    /// Peak device bytes committed to KV blocks
    /// (`peak_blocks_in_use × block_bytes` — the same watermark the
    /// engine's [`crate::kv::PagedKvStore`] reports for real storage).
    pub peak_device_bytes: usize,
    /// Worst internal fragmentation snapshot across the run.
    pub peak_fragmentation_bytes: usize,
    /// Speculative decode: draft-phase seconds (subset of `decode_s`).
    pub draft_s: f64,
    /// Speculative decode: proposals offered across all rounds.
    pub spec_proposed_tokens: usize,
    /// Speculative decode: proposals accepted (emitted beyond the one
    /// pending token per member per round).
    pub spec_accepted_tokens: usize,
    /// Median time-to-first-token across completed prefills. A request's
    /// first token exists only after its **final** prefill chunk's
    /// logits — partial chunks deposit KV rows, not tokens — so this is
    /// the simulated clock at the end of the round whose pack carried
    /// that final chunk (all requests arrive at t = 0).
    pub ttft_p50_s: f64,
    /// p95 of the same distribution.
    pub ttft_p95_s: f64,
    /// TTFT p95 over the arrivals **behind the FIFO head** (every
    /// request but the first-submitted). This is the cohort a long
    /// head-of-line prompt delays under sequential prefill — the head's
    /// own TTFT is bounded below by its prompt length in *any*
    /// discipline, so the packing win shows up here.
    pub ttft_behind_head_p95_s: f64,
    /// Prompt positions *skipped* at admission because published prefix
    /// blocks were attached instead of re-prefilled (0 unless the run
    /// models a shared-prefix workload). Counts re-admissions too: each
    /// attach is prefill compute the device never ran.
    pub prefix_shared_tokens: usize,
    /// Copy-on-write block copies performed when a sequence grew into a
    /// block it shared (0 unless sharing).
    pub cow_copies: u64,
    /// Peak extra references held onto shared blocks across the run
    /// (Σ `refcount − 1`) — the blocks the arena did *not* have to hold
    /// twice.
    pub peak_shared_blocks: usize,
    /// f32 re-materialization billed for int8 KV block reads
    /// ([`crate::sim::exec::kv_dequant_overhead_s`]); exactly 0 unless
    /// the run models quantized KV blocks.
    pub dequant_s: f64,
    /// Host seconds the pipeline *hid* — Σ over billed rounds of
    /// `(device + host) − pipelined_round_time_s(device, host, depth)`.
    /// Exactly 0 at depth 1 (the additive loop hides nothing); at depth
    /// ≥ 2 this is the cost model's **billed** overlap saving, the
    /// number the async-overlap bench compares its *realized*
    /// wall-clock saving against (realized ≥ 0.8× billed is the gate).
    pub overlap_hidden_s: f64,
}

impl ServingSimReport {
    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.total_s
    }
}

/// Drive `workload` (all requests arrive at t=0 — saturating offered
/// load) through the round scheduler against a fixed-size arena, pricing
/// every round with the batched cost model. Panics only on internal
/// invariant violations; arena misconfiguration (a request that can
/// never fit) surfaces as a round-limit bailout with `completed <
/// workload.len()`.
pub fn simulate_serving(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    cfg: &ServingSimConfig,
    workload: &[SimRequest],
) -> ServingSimReport {
    simulate_serving_impl(
        decode_plan,
        prefill_plan,
        None,
        cfg,
        PipelineSimConfig::default(),
        workload,
        None,
        false,
    )
}

/// [`simulate_serving`] under the bounded-depth **pipelined executor**:
/// identical scheduler/arena/admission policy, but every round's host
/// work (`cfg.sync_s + pipe.host_plan_s`) is billed through
/// [`pipelined_round_time_s`] at `pipe.depth`. `depth = 1` with
/// `host_plan_s = 0` reproduces [`simulate_serving`] bitwise — the
/// equality test below is the sim half of the engine's depth-1 gate.
pub fn simulate_serving_pipelined(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    cfg: &ServingSimConfig,
    pipe: PipelineSimConfig,
    workload: &[SimRequest],
) -> ServingSimReport {
    simulate_serving_impl(decode_plan, prefill_plan, None, cfg, pipe, workload, None, false)
}

/// [`simulate_serving`] over a **shared-prefix workload**. Prompts are
/// synthesized from each request's `(prefix_group, shared_prefix_tokens)`
/// so identical prefixes hash to identical block keys; admission runs
/// [`AdmissionPolicy::admit_prefixed`] (only unique blocks gate
/// capacity), newly admitted sequences start prefill *after* their
/// attached positions, and every committed chunk publishes its blocks
/// for later arrivals. `quantized` switches the arena accounting to
/// int8 block bytes ([`KvArenaConfig::quantized_block_bytes`]) and
/// bills each decode round the f32 re-materialization of the positions
/// its gather touches — size the arena's `num_blocks` from the same
/// byte budget on both sides to compare at fixed memory.
pub fn simulate_serving_shared(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    cfg: &ServingSimConfig,
    workload: &[PrefixSimRequest],
    quantized: bool,
) -> ServingSimReport {
    let base: Vec<SimRequest> = workload
        .iter()
        .map(|r| SimRequest {
            prompt_tokens: r.prompt_tokens,
            max_new_tokens: r.max_new_tokens,
            actual_new_tokens: r.actual_new_tokens,
        })
        .collect();
    let prompts: Vec<Vec<i32>> = workload
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let shared = r.shared_prefix_tokens.min(r.prompt_tokens);
            (0..r.prompt_tokens)
                .map(|p| {
                    if p < shared {
                        synth_token(0xA5A5_0000 ^ r.prefix_group, p)
                    } else {
                        synth_token(0x5151_0000_0000 ^ (i as u64 + 1), p)
                    }
                })
                .collect()
        })
        .collect();
    simulate_serving_impl(
        decode_plan,
        prefill_plan,
        None,
        cfg,
        PipelineSimConfig::default(),
        &base,
        Some(&prompts),
        quantized,
    )
}

/// [`simulate_serving`] with greedy draft-k **speculative decoding**: the
/// same scheduler/arena/admission loop, but each decode round proposes
/// `spec.k` tokens per member with `draft_plan`, verifies all `k + 1`
/// positions with the target in one priced pass
/// ([`verify_time_s`]), and emits `1 + a` tokens per member with `a`
/// driven by `spec.acceptance` — so the draft-k amortization claim is
/// checkable across acceptance rates before real hardware. KV rows are
/// ensured at `k + 1` per member (the provisional scatter) and appended
/// at `1 + a` (the accepted prefix), mirroring the engine's rollback
/// seam; pricing uses the configured `k` even when a member's remaining
/// budget clamps its width (conservative — the batch waits for the
/// widest member anyway).
pub fn simulate_serving_spec(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    draft_plan: &ExecutionPlan,
    spec: SpecSim,
    cfg: &ServingSimConfig,
    workload: &[SimRequest],
) -> ServingSimReport {
    simulate_serving_impl(
        decode_plan,
        prefill_plan,
        Some((draft_plan, spec)),
        cfg,
        PipelineSimConfig::default(),
        workload,
        None,
        false,
    )
}

#[allow(clippy::too_many_arguments)]
fn simulate_serving_impl(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    spec: Option<(&ExecutionPlan, SpecSim)>,
    cfg: &ServingSimConfig,
    pipe: PipelineSimConfig,
    workload: &[SimRequest],
    prompts: Option<&[Vec<i32>]>,
    quantized: bool,
) -> ServingSimReport {
    let mut sched = Scheduler::new(cfg.sched);
    let mut arena = KvArena::new(cfg.arena);
    let mut handles: HashMap<RequestId, KvSeqHandle> = HashMap::new();
    let mut actual: HashMap<RequestId, usize> = HashMap::new();
    // Prefix keys per request, computed once from the prompt (empty map
    // on the plain path — `admit_prefixed` with no keys is bit-for-bit
    // the plain gate, so the two paths share one admission call).
    let mut keys_by_id: HashMap<RequestId, Vec<PrefixKey>> = HashMap::new();
    for (i, r) in workload.iter().enumerate() {
        let id = i as u64;
        actual.insert(id, r.actual_new_tokens.min(r.max_new_tokens));
        let prompt = match prompts {
            Some(ps) => ps[i].clone(),
            None => vec![0; r.prompt_tokens],
        };
        if prompts.is_some() {
            keys_by_id.insert(id, shareable_prefix_keys(&prompt, cfg.arena.block_tokens));
        }
        sched.submit(InferenceRequest::new(id, prompt, r.max_new_tokens));
    }

    let mut rep = ServingSimReport::default();
    let mut occupancy_sum = 0usize;
    let mut decode_rounds = 0usize;
    let mut completed_gen = 0usize;
    let mut completed_lens: Vec<usize> = Vec::new();
    // First-token timestamp per request (set once, at the first round
    // whose pack carried the request's final prefill chunk).
    let mut ttft_by_id: HashMap<RequestId, f64> = HashMap::new();
    let chunked = cfg.sched.prefill_chunk_tokens > 0;
    // TTFT-adaptive chunk sizing — the same [`ChunkAutotuner`] ladder the
    // engine loops step once per round, fed the p95 of completed
    // requests' first-token times (the engine samples its completion
    // histogram; the sim keeps the equivalent vector below).
    let chunk_tuner = cfg
        .sched
        .ttft_p95_target_s
        .map(|t| ChunkAutotuner::new(cfg.sched.prefill_chunk_tokens, t));
    let mut completed_ttfts: Vec<f64> = Vec::new();
    // The reservation discipline maps onto the shared admission policy:
    // lifetime IS worst-case admission (gate + claim the whole
    // footprint), paged gates on the expectation and claims the context.
    let (policy, paged) = match cfg.reservation {
        KvReservation::Lifetime => (AdmissionPolicy::WorstCase, false),
        KvReservation::Paged { policy } => (policy, true),
    };
    // Cache the per-round/per-context prices that repeat within a run.
    let mut round_cost: HashMap<usize, f64> = HashMap::new();
    let mut draft_cost: HashMap<usize, f64> = HashMap::new();
    let mut prefill_cost: HashMap<usize, f64> = HashMap::new();
    // Speculative acceptance: per-sequence fractional credit so integer
    // emissions match the expected acceptance over the run.
    let mut credit: HashMap<RequestId, f64> = HashMap::new();
    // Device profile for the paged gather pricing; unknown devices (plans
    // built against a test profile) just skip the overhead.
    let gather_dev = crate::device::registry::device(decode_plan.device_name);

    while !sched.is_idle() {
        // Admission: the *same* gate-and-claim the engine runs
        // ([`AdmissionPolicy::admit`]), fed the simulated estimate.
        let mean_gen = match cfg.estimator {
            GenLenEstimator::CompletedOnly => {
                if rep.completed > 0 {
                    Some(completed_gen as f64 / rep.completed as f64)
                } else {
                    None
                }
            }
            GenLenEstimator::Blended => {
                let (inflight, inflight_tokens) = sched.inflight_gen();
                blended_mean_gen(
                    rep.completed as u64,
                    completed_gen as u64,
                    inflight,
                    inflight_tokens,
                )
            }
            GenLenEstimator::P90 => {
                let (inflight, inflight_tokens) = sched.inflight_gen();
                blended_mean_gen(
                    rep.completed as u64,
                    completed_gen as u64,
                    inflight,
                    inflight_tokens,
                )
                .map(|blended| {
                    let mut pool: Vec<f64> =
                        completed_lens.iter().map(|&l| l as f64).collect();
                    pool.extend(sched.inflight_gen_lens().iter().map(|&l| l as f64));
                    Summary::from_samples(pool).percentile(90.0).max(blended)
                })
            }
        };
        let mut newly_admitted: Vec<RequestId> = Vec::new();
        sched.admit_where(|req, ctx_tokens| {
            let keys: &[PrefixKey] =
                keys_by_id.get(&req.id).map_or(&[], |k| k.as_slice());
            match policy.admit_prefixed(&mut arena, req, ctx_tokens, mean_gen, keys) {
                Some(h) => {
                    handles.insert(req.id, h);
                    newly_admitted.push(req.id);
                    true
                }
                None => false,
            }
        });
        // A claim that attached published prefix blocks starts life with
        // committed positions — prefill resumes *after* them (the
        // chunks the attach made redundant are never planned, so their
        // compute is never billed; re-admissions re-attach too).
        for id in newly_admitted {
            let skip = arena.len(handles[&id]);
            if skip > 0 {
                rep.prefix_shared_tokens += skip;
                sched.seq_mut(id).expect("admitted above").prefill_progress = skip;
            }
        }

        let round = sched.next_round();

        // Paged growth, with preemption on exhaustion — the *same* loop
        // the engine runs ([`Scheduler::ensure_round_capacity`]), so the
        // simulator can never diverge from the serving policy. (One row
        // per emission here, final tokens included — see module docs.)
        // Speculative members need `k_eff + 1` rows (the provisional
        // draft/verify scatter), plain members one.
        let mut spec_width: HashMap<RequestId, usize> = HashMap::new();
        let needs: Vec<(RequestId, usize)> = round
            .decode_batch
            .iter()
            .map(|&id| {
                let k_eff = match spec {
                    Some((_, s)) => {
                        let seq = sched.seq(id).expect("scheduled seq exists");
                        let remaining = seq
                            .request
                            .max_new_tokens
                            .saturating_sub(seq.generated.len() + 1);
                        s.k.min(remaining)
                    }
                    None => 0,
                };
                spec_width.insert(id, k_eff);
                (id, k_eff + 1)
            })
            .collect();
        // Prefill chunks go through the same loop: their rows were
        // reserved at admission, so this is a no-op — *except* when the
        // chunk's write window opens inside a shared block, where
        // `ensure` must take a copy-on-write block and exhaustion must
        // preempt exactly like a failed grow.
        let mut needs = needs;
        needs.extend(round.prefills.iter().filter(|c| c.len > 0).map(|c| (c.id, c.len)));
        let held_out: HashSet<RequestId> = sched.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &needs,
            |_victim, bill, _bytes_freed| {
                rep.preemptions += 1;
                rep.reprefill_tokens += bill;
            },
        );

        // Decode: each surviving member emits its pending token plus any
        // accepted proposals, priced as one batched round (weights stream
        // once; KV/activations scale with B — and with the k+1 scored
        // positions under speculation). Under the paged layout each
        // member's attention also walks its block table per scored
        // position — that indirection is billed per layer per block
        // touched.
        let mut executed = 0usize;
        let mut gather_blocks = 0usize;
        let mut dequant_positions = 0usize;
        for &id in &round.decode_batch {
            if held_out.contains(&id) {
                continue;
            }
            let k_eff = spec_width.get(&id).copied().unwrap_or(0);
            // Blocks this member's gather touches: its context so far
            // (written rows), per attention layer, per scored position.
            gather_blocks += div_ceil(arena.len(handles[&id]).max(1), cfg.arena.block_tokens)
                * cfg.arena.layers
                * (k_eff + 1);
            // Quantized KV: every context position the gather reads is
            // re-materialized to f32 per scored position.
            dequant_positions += arena.len(handles[&id]).max(1) * (k_eff + 1);
            let seq = sched.seq_mut(id).expect("scheduled seq exists");
            let gen0 = seq.generated.len();
            // Acceptance: expected value accumulated as per-sequence
            // credit, capped by the draft width and by EOS (the target
            // emits EOS and stops — nothing is accepted past it).
            let accepted = if k_eff > 0 {
                let (_, s) = spec.expect("spec width implies spec mode");
                let c = credit.entry(id).or_insert(0.0);
                *c += expected_accepted_tokens(k_eff, s.acceptance);
                let a = (c.floor() as usize)
                    .min(k_eff)
                    .min(actual[&id].saturating_sub(gen0 + 1));
                *c -= a as f64;
                if *c > s.k as f64 {
                    *c = s.k as f64; // EOS-capped credit must not bank up
                }
                a
            } else {
                0
            };
            let emit = 1 + accepted;
            arena.append(handles[&id], emit).expect("capacity ensured above");
            for _ in 0..emit {
                seq.generated.push(0);
            }
            seq.pos += emit;
            rep.generated_tokens += emit;
            rep.spec_proposed_tokens += k_eff;
            rep.spec_accepted_tokens += accepted;
            executed += 1;
            // EOS: the model stops early; the scheduler (which only knows
            // the budget) sees the request finish at its actual length.
            if seq.generated.len() >= actual[&id] {
                seq.request.max_new_tokens = seq.generated.len();
            }
        }
        if executed > 0 {
            let t = match spec {
                Some((draft_plan, s)) => {
                    // One draft round at this occupancy, scaled by the
                    // expected steps (k proposals + the αᵏ catch-up that
                    // follows a fully-accepted round) so high-acceptance
                    // rounds are not under-billed.
                    let d1 = *draft_cost
                        .entry(executed)
                        .or_insert_with(|| simulate_batched(draft_plan, executed).total_s);
                    let dt = expected_draft_steps(s.k, s.acceptance) * d1;
                    let vt = *round_cost
                        .entry(executed)
                        .or_insert_with(|| verify_time_s(decode_plan, executed, s.k));
                    rep.draft_s += dt;
                    dt + vt
                }
                None => *round_cost
                    .entry(executed)
                    .or_insert_with(|| simulate_batched(decode_plan, executed).total_s),
            };
            // Decode-round host work (next-round planning + sync)
            // overlaps the device past depth 1; at depth 1 this is
            // `t + cfg.sync_s` bitwise (host_plan_s defaults to 0).
            let host = cfg.sync_s + pipe.host_plan_s;
            let billed = pipelined_round_time_s(t, host, pipe.depth);
            rep.overlap_hidden_s += t + host - billed;
            rep.decode_s += billed;
            if paged {
                if let Some(dev) = &gather_dev {
                    rep.gather_s += paged_gather_overhead_s(dev, gather_blocks);
                }
            }
            if quantized {
                if let Some(dev) = &gather_dev {
                    rep.dequant_s += kv_dequant_overhead_s(
                        dev,
                        dequant_positions,
                        cfg.arena.quantized_bytes_per_token(),
                    );
                }
            }
            occupancy_sum += executed;
            decode_rounds += 1;
            rep.peak_occupancy = rep.peak_occupancy.max(executed);
        }

        // Prefills: one chunk pack per round, initial and re-prefills
        // alike (an evicted sequence restarts its chunks at token 0 and
        // pays for its whole context again — quadratic attention term
        // included, so thrashing is priced, not hidden). With chunking
        // off every chunk covers its whole context and is billed as its
        // own prompt-sized launch + sync — exactly the sequential path;
        // with chunking on the pack is one flattened GEMM: one launch
        // set and one host sync per round however many prompts
        // contribute chunks ([`packed_prefill_time_s`]).
        let prefill_base = rep.decode_s + rep.prefill_s + rep.gather_s;
        let mut pack: Vec<PackedChunkCost> = Vec::new();
        let mut finished_prefill: Vec<RequestId> = Vec::new();
        let mut sequential_prefill_s = 0.0;
        for c in &round.prefills {
            if held_out.contains(&c.id) {
                continue; // evicted this round before its chunk ran
            }
            let seq = sched.seq_mut(c.id).expect("scheduled seq exists");
            debug_assert_eq!(c.start, seq.prefill_progress, "chunk off its progress: {c:?}");
            seq.prefill_progress += c.len;
            if c.last {
                seq.prefill_done = true;
                // Immediate EOS (actual 0): finish straight out of
                // prefill, before the decode loop could over-generate.
                if seq.generated.len() >= actual[&c.id] {
                    seq.request.max_new_tokens = seq.generated.len();
                }
            }
            rep.prefill_tokens += c.len;
            arena.append(handles[&c.id], c.len).expect("capacity ensured above");
            // Publish the freshly committed blocks so later arrivals
            // with the same prefix attach instead of re-prefilling
            // (no-op when the keys are already indexed or the tail
            // block is still partial).
            if let Some(keys) = keys_by_id.get(&c.id) {
                arena
                    .publish_prefix(handles[&c.id], keys)
                    .expect("handle is live within the round");
            }
            pack.push(PackedChunkCost { tokens: c.len, context_end: c.end() });
            if !chunked {
                // One prompt-sized pack per prompt: the SAME cost model
                // as the chunked path (full launch set + weight stream
                // per execution — running a compiled plan on a shorter
                // context shrinks its work, never its kernel count), so
                // chunked-vs-sequential comparisons differ only in
                // scheduling and launch amortization, never in pricing
                // rules.
                let ctx = c.end();
                let dev = *prefill_cost.entry(ctx).or_insert_with(|| {
                    packed_prefill_time_s(
                        prefill_plan,
                        cfg.prefill_plan_tokens,
                        &[PackedChunkCost { tokens: c.len, context_end: ctx }],
                    )
                });
                // Each sequential prompt is its own pipeline slot.
                let host = cfg.sync_s + pipe.host_plan_s;
                let billed = pipelined_round_time_s(dev, host, pipe.depth);
                rep.overlap_hidden_s += dev + host - billed;
                sequential_prefill_s += billed;
                // Sequential prompts run back-to-back, so each one's
                // logits — and first token — land at the end of its OWN
                // prefill, not the round's (a shared end-of-round stamp
                // would inflate the sequential baseline's TTFT whenever
                // the cap packs several prompts into one round).
                if c.last {
                    ttft_by_id.entry(c.id).or_insert(prefill_base + sequential_prefill_s);
                }
            } else if c.last {
                finished_prefill.push(c.id);
            }
        }
        if !pack.is_empty() {
            rep.prefill_s += if chunked {
                let dev = packed_prefill_time_s(prefill_plan, cfg.prefill_plan_tokens, &pack);
                let host = cfg.sync_s + pipe.host_plan_s;
                let billed = pipelined_round_time_s(dev, host, pipe.depth);
                rep.overlap_hidden_s += dev + host - billed;
                billed
            } else {
                sequential_prefill_s
            };
        }
        // Packed first-token timestamps: the first token exists only
        // after the FINAL chunk's logits (partial chunks deposit KV
        // rows, not tokens), and the pack is ONE flattened GEMM — every
        // final chunk's logits land together at the end of the round's
        // pack. All requests arrive at t = 0; a re-prefill after
        // eviction keeps the original stamp (its first token was
        // already delivered).
        if !finished_prefill.is_empty() {
            let now = rep.decode_s + rep.prefill_s + rep.gather_s;
            for id in finished_prefill {
                ttft_by_id.entry(id).or_insert(now);
            }
        }

        let stats = arena.stats();
        rep.peak_blocks_in_use = rep.peak_blocks_in_use.max(stats.blocks_in_use);
        rep.peak_seqs = rep.peak_seqs.max(stats.sequences);
        rep.peak_shared_blocks = rep.peak_shared_blocks.max(arena.shared_blocks());
        rep.peak_fragmentation_bytes =
            rep.peak_fragmentation_bytes.max(stats.internal_fragmentation_bytes);

        for done in sched.reap_finished() {
            if let Some(h) = handles.remove(&done.request.id) {
                arena.release(h);
            }
            rep.completed += 1;
            completed_gen += done.generated.len();
            completed_lens.push(done.generated.len());
            if let Some(&t) = ttft_by_id.get(&done.request.id) {
                completed_ttfts.push(t);
            }
        }
        // Retune the prefill granule from completed-request TTFTs (no-op
        // without a target, and silent before the first completion —
        // exactly the engine's guard on `requests_completed`).
        if let Some(tuner) = &chunk_tuner {
            if !completed_ttfts.is_empty() {
                let p95 =
                    Summary::from_samples(completed_ttfts.clone()).percentile(95.0);
                let next = tuner.update(sched.prefill_chunk_tokens(), p95);
                if next != sched.prefill_chunk_tokens() {
                    sched.set_prefill_chunk_tokens(next);
                }
            }
        }

        rep.rounds += 1;
        if rep.rounds > 100_000 {
            break; // misconfigured workload: report what completed
        }
    }

    arena.verify().expect("arena invariants after drain");
    rep.cow_copies = arena.cow_copies();
    // Quantized runs hold real device bytes at the int8 block size —
    // the watermark the engine's quantized region reports.
    let device_block_bytes = if quantized {
        cfg.arena.quantized_block_bytes()
    } else {
        cfg.arena.block_bytes()
    };
    rep.peak_device_bytes = rep.peak_blocks_in_use * device_block_bytes;
    rep.total_s = rep.decode_s + rep.prefill_s + rep.gather_s + rep.dequant_s;
    if decode_rounds > 0 {
        rep.mean_occupancy = occupancy_sum as f64 / decode_rounds as f64;
    }
    let all = Summary::from_samples(ttft_by_id.values().copied().collect());
    if !all.is_empty() {
        rep.ttft_p50_s = all.percentile(50.0);
        rep.ttft_p95_s = all.percentile(95.0);
    }
    // Request id 0 is the first submitted (the FIFO head): everyone else
    // is an arrival *behind* it — the cohort a head-of-line prompt can
    // delay.
    let behind = Summary::from_samples(
        ttft_by_id.iter().filter(|&(&id, _)| id != 0).map(|(_, &t)| t).collect(),
    );
    if !behind.is_empty() {
        rep.ttft_behind_head_p95_s = behind.percentile(95.0);
    }
    rep
}

/// One draft model in a fleet simulation: its decode plan and the
/// widest k the market may bid.
#[derive(Clone, Copy)]
pub struct FleetDraftSim<'a> {
    pub plan: &'a ExecutionPlan,
    pub k_max: usize,
}

/// How the fleet sim picks a draft width per sequence per round — the
/// three modes the `fleet_serving_sweep` compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetKPolicy {
    /// No speculation anywhere: every member decodes plainly.
    Plain,
    /// Every drafted member runs its draft's `k_max`, every round — the
    /// static config the adaptive market must beat.
    StaticK,
    /// The registry's per-sequence controller: EWMA acceptance against
    /// the [`SpecRoundCost`] breakeven at shared-round pricing
    /// ([`DraftController::choose_k_in_round`] — the round's weight
    /// stream is billed once, so a bid pays marginal rows only), so
    /// low-α members drop to plain decode instead of paying draft
    /// overhead.
    Adaptive,
}

/// One sequence of a fleet workload: decode-only (all members resident
/// from t = 0 — prefill is identical across the three policies, so it
/// cancels out of the comparison the gate is about).
#[derive(Clone, Copy, Debug)]
pub struct FleetSimRequest {
    /// Tokens to generate before this member leaves the batch.
    pub new_tokens: usize,
    /// True per-token draft/target agreement α ∈ [0, 1] — what the
    /// controller's EWMA estimates from observed rounds.
    pub acceptance: f64,
    /// Index into the draft fleet serving this member (`None` = no
    /// draft fits; always plain).
    pub draft: Option<usize>,
}

/// What a fleet run produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetSimReport {
    pub rounds: usize,
    pub total_s: f64,
    /// Draft-phase seconds (subset of `total_s`).
    pub draft_s: f64,
    /// Target verify/decode seconds (subset of `total_s`).
    pub verify_s: f64,
    pub generated_tokens: usize,
    pub spec_proposed_tokens: usize,
    pub spec_accepted_tokens: usize,
    /// Mean planned k over member-rounds (0-width plain members
    /// included) — the market's aggregate bid, reported so "adaptive
    /// stopped paying for the low-α cohort" is visible, not inferred.
    pub mean_planned_k: f64,
}

impl FleetSimReport {
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.total_s
    }
}

/// Closed-loop fleet serving simulation: a resident batch of mixed-α
/// sequences decoded against one target with zero or more draft models,
/// under one of the three k policies. Per round:
///
/// * each member bids a width (`k = 0` ⇒ plain decode member);
/// * each draft's group runs its proposal steps at **shrinking width**
///   (`B_j` = members still drafting at step `j` — a member with a
///   small k leaves the draft batch early), plus the probability-`αᵏ`
///   catch-up step billed fractionally at the group's width;
/// * the target scores everyone — plain members and all draft groups —
///   in ONE mixed-width pass ([`mixed_verify_time_s`]: `k_i + 1` rows
///   per drafted member, 1 per plain member), so target weights stream
///   once per round for the whole batch, never per model group;
/// * emissions use the per-member fractional-credit accumulator over
///   `E[a] = Σ αⁱ` ([`expected_accepted_tokens`]), the same mechanism
///   as [`simulate_serving_spec`], and the controller's EWMA observes
///   the realized (proposed, accepted) exactly as the engine's does.
///
/// Adaptive mode prices bids with [`SpecRoundCost::from_plans`] at the
/// initial batch width — the same secant the engine feeds its
/// controller — so sim and engine run identical market policy.
pub fn simulate_serving_fleet(
    target_decode_plan: &ExecutionPlan,
    drafts: &[FleetDraftSim],
    policy: FleetKPolicy,
    sync_s: f64,
    workload: &[FleetSimRequest],
) -> FleetSimReport {
    struct Member {
        remaining: usize,
        alpha: f64,
        draft: Option<usize>,
        ewma: AcceptanceEwma,
        credit: f64,
    }
    let mut live: Vec<Member> = workload
        .iter()
        .filter(|r| r.new_tokens > 0)
        .map(|r| Member {
            remaining: r.new_tokens,
            alpha: r.acceptance.clamp(0.0, 1.0),
            draft: r.draft.filter(|&d| d < drafts.len() && drafts[d].k_max > 0),
            ewma: AcceptanceEwma::new(0.3),
            credit: 0.0,
        })
        .collect();
    let costs: Vec<SpecRoundCost> = drafts
        .iter()
        .map(|d| {
            SpecRoundCost::from_plans(
                d.plan,
                target_decode_plan,
                workload.len().max(1),
                d.k_max.max(1),
            )
        })
        .collect();

    let mut rep = FleetSimReport::default();
    let mut planned_k_sum = 0usize;
    let mut member_rounds = 0usize;
    while !live.is_empty() {
        // Bid: one width per member. The +1 pending emission always
        // happens, so k never needs to exceed remaining − 1.
        let ks: Vec<usize> = live
            .iter()
            .map(|m| {
                let d = match m.draft {
                    Some(d) => d,
                    None => return 0,
                };
                let k_max = drafts[d].k_max;
                let k = match policy {
                    FleetKPolicy::Plain => 0,
                    FleetKPolicy::StaticK => k_max,
                    // Shared-round pricing: the execution model below
                    // bills the target's weight stream once per round
                    // (one mixed verify pass), so the bid must price a
                    // width at its *marginal* cost — the dedicated-round
                    // `choose_k` would charge every member the full
                    // stream and sit out traffic the round carries for
                    // the price of its extra rows.
                    FleetKPolicy::Adaptive => DraftController { k_max, ..Default::default() }
                        .choose_k_in_round(m.ewma.estimate(), &costs[d], true),
                };
                k.min(m.remaining.saturating_sub(1))
            })
            .collect();
        planned_k_sum += ks.iter().sum::<usize>();
        member_rounds += live.len();

        // Draft phase: per-model groups at shrinking width.
        for (di, d) in drafts.iter().enumerate() {
            let group: Vec<usize> = (0..live.len())
                .filter(|&i| live[i].draft == Some(di) && ks[i] > 0)
                .collect();
            if group.is_empty() {
                continue;
            }
            let k_top = group.iter().map(|&i| ks[i]).max().unwrap_or(0);
            for j in 0..k_top {
                let width = group.iter().filter(|&&i| ks[i] > j).count();
                rep.draft_s += simulate_batched(d.plan, width).total_s;
            }
            // Catch-up after a fully-accepted round (probability αᵏ per
            // member), billed as that fraction of one group-wide step.
            let catchup: f64 =
                group.iter().map(|&i| live[i].alpha.powi(ks[i] as i32)).sum::<f64>()
                    / group.len() as f64;
            rep.draft_s += catchup * simulate_batched(d.plan, group.len()).total_s;
        }

        // Verify: one mixed-width target pass over the whole batch.
        let widths: Vec<usize> = ks.iter().map(|&k| k + 1).collect();
        rep.verify_s += mixed_verify_time_s(target_decode_plan, &widths);
        rep.total_s += sync_s;

        // Emission + acceptance observation.
        for (i, m) in live.iter_mut().enumerate() {
            let k = ks[i];
            let mut emitted = 1usize; // the pending token
            if k > 0 {
                m.credit += expected_accepted_tokens(k, m.alpha);
                let accepted = (m.credit.floor() as usize).min(k).min(m.remaining - 1);
                m.credit -= accepted as f64;
                emitted += accepted;
                rep.spec_proposed_tokens += k;
                rep.spec_accepted_tokens += accepted;
                m.ewma.observe(k, accepted);
            }
            m.remaining -= emitted.min(m.remaining);
            rep.generated_tokens += emitted;
        }
        live.retain(|m| m.remaining > 0);

        rep.rounds += 1;
        if rep.rounds > 100_000 {
            break; // misconfigured workload: report what completed
        }
    }
    rep.total_s += rep.draft_s + rep.verify_s;
    if member_rounds > 0 {
        rep.mean_planned_k = planned_k_sum as f64 / member_rounds as f64;
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry::device;
    use crate::engine::compile::CompileOptions;
    use crate::engine::llm::simulate_llm;
    use crate::models::llm_config;
    use crate::quant::QuantScheme;

    /// Gemma2-2B plans on the Adreno 750 profile — the fixed-memory
    /// comparison the ISSUE's acceptance bar names.
    fn plans() -> (ExecutionPlan, ExecutionPlan, usize) {
        let cfg = llm_config("gemma2_2b").unwrap();
        let dev = device("adreno_750").unwrap();
        let opts = CompileOptions::default();
        let p = simulate_llm(&cfg, &dev, QuantScheme::Mixed844, 1024, 256, &opts).unwrap();
        (p.decode.plan.clone(), p.prefill.plan.clone(), 1024)
    }

    fn arena(num_blocks: usize) -> KvArenaConfig {
        KvArenaConfig {
            layers: 26,
            heads_kv: 4,
            head_dim: 256,
            block_tokens: 16,
            num_blocks,
        }
    }

    fn sim_cfg(
        reservation: KvReservation,
        num_blocks: usize,
        max_active: usize,
    ) -> ServingSimConfig {
        ServingSimConfig {
            sched: SchedulerConfig {
                max_active,
                max_prefills_per_round: 2,
                ..Default::default()
            },
            arena: arena(num_blocks),
            reservation,
            sync_s: 150e-6,
            prefill_plan_tokens: 1024,
            estimator: GenLenEstimator::default(),
        }
    }

    #[test]
    fn paged_admission_sustains_1_5x_occupancy_at_fixed_memory() {
        // The acceptance bar: long budgets (max_new 192) with short
        // actual generations (16 tokens) — lifetime reservation strands
        // 176 tokens per sequence; paged admission reclaims them. Same
        // arena (48 blocks), same workload, same scheduler.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 192, actual_new_tokens: 16 };
            24
        ];
        let lifetime = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, 48, 16),
            &workload,
        );
        let paged = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(
                KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.5 } },
                48,
                16,
            ),
            &workload,
        );
        assert_eq!(lifetime.completed, 24, "lifetime run must drain");
        assert_eq!(paged.completed, 24, "paged run must drain");
        assert!(
            paged.mean_occupancy >= 1.5 * lifetime.mean_occupancy,
            "paged occupancy {:.2} must be ≥ 1.5× lifetime {:.2} at equal arena bytes",
            paged.mean_occupancy,
            lifetime.mean_occupancy
        );
        assert!(
            paged.tokens_per_s() > lifetime.tokens_per_s(),
            "higher occupancy must buy throughput: {:.1} vs {:.1} tok/s",
            paged.tokens_per_s(),
            lifetime.tokens_per_s()
        );
        // The mechanism: lifetime's stranded reservations show up as
        // internal fragmentation the paged run does not carry.
        assert!(
            paged.peak_fragmentation_bytes < lifetime.peak_fragmentation_bytes,
            "paged frag {} must undercut lifetime frag {}",
            paged.peak_fragmentation_bytes,
            lifetime.peak_fragmentation_bytes
        );
    }

    #[test]
    fn exhaustion_preempts_requeues_and_charges_reprefill() {
        // Arena too small for the workload's *actual* footprints: paged
        // admission over-admits, growth exhausts the arena mid-round,
        // and the run must degrade to eviction + re-prefill — every
        // request still completes, and the recompute is billed.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 64, actual_new_tokens: 64 };
            3
        ];
        let rep = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(
                KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
                8,
                4,
            ),
            &workload,
        );
        assert_eq!(rep.completed, 3, "exhaustion must degrade to queuing, not failure");
        assert_eq!(rep.generated_tokens, 3 * 64, "no tokens lost to eviction");
        assert!(rep.preemptions >= 1, "this workload must evict: {rep:?}");
        assert!(rep.reprefill_tokens > 0);
        assert!(
            rep.prefill_tokens > 3 * 32,
            "re-prefill work must be billed on top of the initial prefills: {rep:?}"
        );
        // Lifetime on the same arena never preempts — it just queues.
        let lifetime = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, 8, 4),
            &workload,
        );
        assert_eq!(lifetime.completed, 3);
        assert_eq!(lifetime.preemptions, 0);
    }

    #[test]
    fn lifetime_and_paged_agree_when_memory_is_plentiful() {
        // With an arena big enough for every worst case, the disciplines
        // admit identically — same schedule, same occupancy, no
        // preemptions — so paged mode is a strict generalization, not a
        // different scheduler. The only difference left is the priced
        // block-table gather indirection: paged is billed it (a ~1e-4
        // relative sliver), lifetime's dense layout is not.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 32, actual_new_tokens: 32 };
            6
        ];
        let big = 6 * 6 + 4; // 6 seqs × ceil(96/16) blocks, plus slack
        let l = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, big, 8),
            &workload,
        );
        let p = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Paged { policy: AdmissionPolicy::default() }, big, 8),
            &workload,
        );
        assert_eq!(l.completed, 6);
        assert_eq!(p.completed, 6);
        assert_eq!(p.preemptions, 0, "no pressure, no eviction");
        assert_eq!(l.rounds, p.rounds, "identical schedules");
        assert!((l.mean_occupancy - p.mean_occupancy).abs() < 1e-12);
        // Gather indirection: billed to paged only, and tiny.
        assert_eq!(l.gather_s, 0.0, "dense layout pays no gather");
        assert!(p.gather_s > 0.0, "paged layout must be billed the indirection");
        assert!(
            (p.total_s - l.total_s - p.gather_s).abs() < 1e-12 * l.total_s,
            "identical schedules may differ only by the gather bill"
        );
        assert!(
            p.gather_s < 1e-2 * l.total_s,
            "the indirection must not eat the paging win: {} vs {}",
            p.gather_s,
            l.total_s
        );
    }

    /// Plans for the speculative sweep: target Llama-3.1-8B on M4 Pro at
    /// a short interactive context (the draft-k sweet spot — the verify
    /// pass multiplies per-position KV reads, which a short context keeps
    /// small next to the ~4.5 GB weight stream), draft TinyLM on the same
    /// device. Returns (target decode, target prefill, draft decode).
    fn spec_plans() -> (ExecutionPlan, ExecutionPlan, ExecutionPlan) {
        let dev = device("m4_pro").unwrap();
        let opts = CompileOptions::default();
        let t = simulate_llm(
            &llm_config("llama3.1_8b").unwrap(),
            &dev,
            QuantScheme::Mixed844,
            256,
            64,
            &opts,
        )
        .unwrap();
        let d = simulate_llm(&llm_config("tinylm").unwrap(), &dev, QuantScheme::Q8, 256, 64, &opts)
            .unwrap();
        (t.decode.plan.clone(), t.prefill.plan.clone(), d.decode.plan.clone())
    }

    fn spec_cfg(num_blocks: usize, max_active: usize) -> ServingSimConfig {
        ServingSimConfig {
            sched: SchedulerConfig {
                max_active,
                max_prefills_per_round: 2,
                ..Default::default()
            },
            arena: KvArenaConfig {
                layers: 32,
                heads_kv: 8,
                head_dim: 128,
                block_tokens: 16,
                num_blocks,
            },
            reservation: KvReservation::Lifetime,
            sync_s: 150e-6,
            prefill_plan_tokens: 256,
            estimator: GenLenEstimator::Blended,
        }
    }

    #[test]
    fn spec_decode_amortizes_at_high_acceptance_and_bounds_overhead_at_zero() {
        // The ISSUE's acceptance bars, at the simulator level: with a
        // TinyLM draft against an 8B target, spec decode must buy ≥ 1.5×
        // tokens/s at acceptance 0.7 and cost ≤ 10% at acceptance 0 (a
        // draft that is always wrong) — the verify pass streams weights
        // once, so its overhead is the k extra per-position shares, not
        // k extra rounds.
        let (decode, prefill, draft) = spec_plans();
        let cfg = spec_cfg(2 * 8 + 2, 2);
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 64, actual_new_tokens: 64 };
            8
        ];
        let plain = simulate_serving(&decode, &prefill, &cfg, &workload);
        assert_eq!(plain.completed, 8, "plain run must drain");
        assert_eq!(plain.spec_proposed_tokens, 0, "plain mode never proposes");

        let hi = simulate_serving_spec(
            &decode,
            &prefill,
            &draft,
            SpecSim { k: 2, acceptance: 0.7 },
            &cfg,
            &workload,
        );
        assert_eq!(hi.completed, 8, "spec run must drain");
        assert_eq!(
            hi.generated_tokens, plain.generated_tokens,
            "speculation changes rounds, never the tokens delivered"
        );
        assert!(hi.rounds < plain.rounds, "acceptance must collapse rounds");
        assert!(hi.draft_s > 0.0 && hi.draft_s < hi.decode_s, "draft split billed: {hi:?}");
        assert!(
            hi.tokens_per_s() >= 1.5 * plain.tokens_per_s(),
            "spec @ α=0.7 must be ≥ 1.5×: {:.1} vs {:.1} tok/s",
            hi.tokens_per_s(),
            plain.tokens_per_s()
        );

        let zero = simulate_serving_spec(
            &decode,
            &prefill,
            &draft,
            SpecSim { k: 2, acceptance: 0.0 },
            &cfg,
            &workload,
        );
        assert_eq!(zero.completed, 8);
        assert_eq!(zero.spec_accepted_tokens, 0, "α = 0 accepts nothing");
        assert!(zero.spec_proposed_tokens > 0, "…but still pays for proposing");
        assert_eq!(zero.rounds, plain.rounds, "α = 0 degenerates to one token/round");
        assert!(
            zero.tokens_per_s() >= 0.9 * plain.tokens_per_s(),
            "verify overhead must stay bounded at α = 0: {:.1} vs {:.1} tok/s",
            zero.tokens_per_s(),
            plain.tokens_per_s()
        );
    }

    #[test]
    fn full_acceptance_emits_k_plus_one_tokens_per_member_round() {
        // α = 1 (draft ≡ target): every round emits exactly k + 1 tokens
        // per member — the deterministic ceiling the engine's
        // draft-= -target e2e reproduces with real PJRT.
        let (decode, prefill, draft) = spec_plans();
        let cfg = spec_cfg(2 * 8 + 2, 2);
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 64, actual_new_tokens: 64 };
            8
        ];
        let rep = simulate_serving_spec(
            &decode,
            &prefill,
            &draft,
            SpecSim { k: 3, acceptance: 1.0 },
            &cfg,
            &workload,
        );
        assert_eq!(rep.completed, 8);
        assert_eq!(rep.generated_tokens, 8 * 64);
        // 64 = 16 rounds × (1 pending + 3 accepted) per sequence.
        assert_eq!(rep.spec_accepted_tokens, 8 * 48, "exactly k accepted per round");
        let plain = simulate_serving(&decode, &prefill, &cfg, &workload);
        assert!(
            rep.tokens_per_s() > 2.5 * plain.tokens_per_s(),
            "full acceptance must approach the (k+1)× ceiling: {:.1} vs {:.1}",
            rep.tokens_per_s(),
            plain.tokens_per_s()
        );
    }

    #[test]
    fn spec_decode_survives_preemption_and_loses_no_tokens() {
        // Spec rounds reserve k + 1 provisional rows, so exhaustion can
        // strike mid-speculation — the shared growth/preemption loop must
        // degrade it to eviction + re-prefill exactly like plain decode:
        // every request completes with its full token count.
        let (decode, prefill, draft) = spec_plans();
        let mut cfg = spec_cfg(8, 4);
        cfg.reservation = KvReservation::Paged {
            policy: AdmissionPolicy::Expected { safety_margin: 1.0 },
        };
        let workload = vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 64, actual_new_tokens: 64 };
            3
        ];
        let rep = simulate_serving_spec(
            &decode,
            &prefill,
            &draft,
            SpecSim { k: 2, acceptance: 0.7 },
            &cfg,
            &workload,
        );
        assert_eq!(rep.completed, 3, "exhaustion must degrade to queuing, not failure");
        assert_eq!(rep.generated_tokens, 3 * 64, "no tokens lost to eviction");
        assert!(rep.preemptions >= 1, "this workload must evict: {rep:?}");
        assert!(rep.reprefill_tokens > 0);
    }

    #[test]
    fn chunked_prefill_conserves_work_and_tokens() {
        // Chunking moves *when* prefill work happens, never how much:
        // same workload, same arena, chunked vs sequential must deliver
        // identical token counts and identical total prefilled positions
        // (the quadratic attention shares telescope across chunks), and
        // every request's TTFT must be recorded.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 96, max_new_tokens: 16, actual_new_tokens: 16 };
            6
        ];
        let run = |chunk: usize| {
            let mut cfg = sim_cfg(KvReservation::Lifetime, 96, 8);
            cfg.sched.prefill_chunk_tokens = chunk;
            cfg.sched.max_prefills_per_round = if chunk == 0 { 2 } else { 4 };
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let seq = run(0);
        let chunked = run(32);
        assert_eq!(seq.completed, 6);
        assert_eq!(chunked.completed, 6);
        assert_eq!(chunked.generated_tokens, seq.generated_tokens);
        assert_eq!(
            chunked.prefill_tokens, seq.prefill_tokens,
            "chunks must cover each context exactly once"
        );
        assert_eq!(chunked.preemptions, 0);
        assert!(chunked.ttft_p95_s > 0.0 && seq.ttft_p95_s > 0.0, "TTFT must be sampled");
        assert!(chunked.ttft_p50_s <= chunked.ttft_p95_s);
    }

    #[test]
    fn packed_prefill_cuts_ttft_behind_a_long_prompt() {
        // The HOL shape the bench's burst sweep gates: one long prompt
        // at the FIFO head, short prompts behind it. Sequential prefill
        // makes every short wait out the long's whole GEMM (plus each
        // other's); chunked + packed prefill completes the shorts within
        // the first round-robin rounds. Directional here (tier-1 must
        // stay robust); the ≥ 1.5× bar is gated in
        // `bench_batched_serving` on the M4 Pro profile.
        let (decode, prefill, _) = plans();
        let mut workload =
            vec![SimRequest { prompt_tokens: 768, max_new_tokens: 16, actual_new_tokens: 16 }];
        workload.extend(vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 16, actual_new_tokens: 16 };
            7
        ]);
        let run = |chunk: usize, cap: usize| {
            let mut cfg = sim_cfg(KvReservation::Lifetime, 120, 8);
            cfg.sched.prefill_chunk_tokens = chunk;
            cfg.sched.max_prefills_per_round = cap;
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let seq = run(0, 1);
        let packed = run(64, 4);
        assert_eq!(seq.completed, 8);
        assert_eq!(packed.completed, 8);
        assert!(
            packed.ttft_behind_head_p95_s < seq.ttft_behind_head_p95_s,
            "packing must cut the blocked cohort's TTFT p95: {:.3}s vs {:.3}s",
            packed.ttft_behind_head_p95_s,
            seq.ttft_behind_head_p95_s
        );
        assert!(
            packed.ttft_p50_s < seq.ttft_p50_s,
            "median TTFT must improve too: {:.3}s vs {:.3}s",
            packed.ttft_p50_s,
            seq.ttft_p50_s
        );
        assert!(
            packed.tokens_per_s() >= 0.95 * seq.tokens_per_s(),
            "packing must not tax throughput: {:.1} vs {:.1} tok/s",
            packed.tokens_per_s(),
            seq.tokens_per_s()
        );
    }

    #[test]
    fn ttft_adaptive_chunking_cuts_tail_ttft_on_a_bursty_backlog() {
        // The TTFT-adaptive satellite's regression shape: a huge prompt
        // at the FIFO head streams prefill chunks through every round
        // while short requests flow through behind it in admission
        // waves (max_active 4). With the fixed 64-token granule the
        // head soaks up a 64-token quantum per shared round — and the
        // whole 4-quantum budget whenever it is the only pending
        // prefill — so each wave's first token queues behind that
        // bandwidth. With a p95 target set, the first completion (the
        // one-token canary) reports a TTFT far over target, the
        // autotuner walks the granule down to its floor (base/4 = 16),
        // and later waves stop subsidizing the head's chunks: their
        // first tokens land earlier in wall-clock even though the
        // head's own prefill stretches over more (cheaper) rounds.
        let (decode, prefill, _) = plans();
        let mut workload =
            vec![SimRequest { prompt_tokens: 2048, max_new_tokens: 16, actual_new_tokens: 16 }];
        // The canary: done one decode round after its single chunk —
        // the autotuner acts only once a completion has landed, exactly
        // like the engine's `requests_completed` guard.
        workload.push(SimRequest { prompt_tokens: 16, max_new_tokens: 1, actual_new_tokens: 1 });
        workload.extend(vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 4, actual_new_tokens: 4 };
            8
        ]);
        let run = |target: Option<f64>| {
            let mut cfg = sim_cfg(KvReservation::Lifetime, 160, 4);
            cfg.sched.prefill_chunk_tokens = 64;
            cfg.sched.max_prefills_per_round = 4;
            cfg.sched.ttft_p95_target_s = target;
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let fixed = run(None);
        // Any positive target far below the observable TTFTs keeps the
        // ladder pinned at its floor for the rest of the run — the
        // steady state a persistently missed target produces.
        let adaptive = run(Some(1e-4));
        assert_eq!(fixed.completed, 10);
        assert_eq!(adaptive.completed, 10);
        assert_eq!(adaptive.generated_tokens, fixed.generated_tokens);
        assert_eq!(
            adaptive.prefill_tokens, fixed.prefill_tokens,
            "retuning moves when prefill runs, never how much"
        );
        assert!(
            adaptive.rounds > fixed.rounds,
            "a shrunken granule must spread the head's prefill over more rounds: {} vs {}",
            adaptive.rounds,
            fixed.rounds
        );
        assert!(
            adaptive.ttft_behind_head_p95_s < fixed.ttft_behind_head_p95_s,
            "adaptive granule must cut the waves' TTFT p95: {:.4}s vs {:.4}s",
            adaptive.ttft_behind_head_p95_s,
            fixed.ttft_behind_head_p95_s
        );
    }

    #[test]
    fn p90_estimator_cuts_preemptions_below_blended_on_bimodal_workload() {
        // ROADMAP "smarter expected-footprint estimators": the blended
        // mean still splits a bimodal workload's modes — admission keeps
        // over-admitting the long mode against an estimate the short
        // mode drags down. The p90 of the pooled length samples tracks
        // the long mode itself, so the same workload on the same arena
        // preempts less (and never bills more recompute).
        let (decode, prefill, _) = plans();
        let mut workload = vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 1 };
            8
        ];
        workload.extend(vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 96 };
            8
        ]);
        let run = |estimator: GenLenEstimator| {
            let cfg = ServingSimConfig {
                sched: SchedulerConfig {
                    max_active: 8,
                    max_prefills_per_round: 2,
                    ..Default::default()
                },
                arena: arena(30), // 480 tokens: ~4 fully-grown longs
                reservation: KvReservation::Paged {
                    policy: AdmissionPolicy::Expected { safety_margin: 1.0 },
                },
                sync_s: 150e-6,
                prefill_plan_tokens: 1024,
                estimator,
            };
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let blended = run(GenLenEstimator::Blended);
        let p90 = run(GenLenEstimator::P90);
        assert_eq!(blended.completed, 16, "blended run must drain");
        assert_eq!(p90.completed, 16, "p90 run must drain");
        assert!(
            blended.preemptions > 0,
            "the bimodal workload must stress blended admission: {blended:?}"
        );
        assert!(
            p90.preemptions < blended.preemptions,
            "p90 admission must preempt less: {} vs blended {}",
            p90.preemptions,
            blended.preemptions
        );
        assert!(p90.reprefill_tokens <= blended.reprefill_tokens);
        // Conservatism must not collapse concurrency: the arena still
        // fits the same steady-state population of fully-grown longs.
        assert!(
            p90.mean_occupancy >= 0.7 * blended.mean_occupancy,
            "p90 occupancy {:.2} collapsed vs blended {:.2}",
            p90.mean_occupancy,
            blended.mean_occupancy
        );
    }

    #[test]
    fn blended_estimator_cuts_warmup_preemptions_on_bimodal_workload() {
        // Survivorship-bias regression. Bimodal workload, shorts first:
        // the shorts complete almost immediately and drag the
        // completed-only mean to ~1 token, so admission (and especially
        // re-admission of evicted sequences, whose gate is
        // context + mean) over-admits the longs and the warm-up phase
        // thrashes. Blending the in-flight generated-so-far lower bounds
        // raises the estimate as the longs keep decoding, so the same
        // workload on the same arena preempts less — and never more.
        let (decode, prefill, _) = plans();
        let mut workload = vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 1 };
            8
        ];
        workload.extend(vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 96 };
            8
        ]);
        let run = |estimator: GenLenEstimator| {
            let cfg = ServingSimConfig {
                sched: SchedulerConfig {
                    max_active: 8,
                    max_prefills_per_round: 2,
                    ..Default::default()
                },
                arena: arena(30), // 480 tokens: ~4 fully-grown longs
                reservation: KvReservation::Paged {
                    policy: AdmissionPolicy::Expected { safety_margin: 1.0 },
                },
                sync_s: 150e-6,
                prefill_plan_tokens: 1024,
                estimator,
            };
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let biased = run(GenLenEstimator::CompletedOnly);
        let blended = run(GenLenEstimator::Blended);
        assert_eq!(biased.completed, 16, "biased run must still drain");
        assert_eq!(blended.completed, 16, "blended run must still drain");
        assert!(
            biased.preemptions > 0,
            "the bimodal workload must expose the over-admission pathology: {biased:?}"
        );
        assert!(
            blended.preemptions < biased.preemptions,
            "blending in-flight lower bounds must cut warm-up preemptions: \
             blended {} vs completed-only {}",
            blended.preemptions,
            biased.preemptions
        );
        // Fewer evictions ⇒ less recompute billed.
        assert!(blended.reprefill_tokens <= biased.reprefill_tokens);
    }

    #[test]
    fn prefix_sharing_multiplies_admitted_concurrency_at_fixed_arena_bytes() {
        // The tentpole's acceptance bar at the simulator level: 24
        // requests with one identical 256-token prompt (the
        // system-prompt shape) on a gemma2-2b-class arena. Without
        // sharing every sequence owns its whole 16-block context plus
        // growth; with content-addressed blocks each follower attaches
        // the published prefix and pays only its divergence — one
        // copy-on-write block at the boundary plus generated tokens —
        // so the same 60 blocks hold several times the concurrency.
        let (decode, prefill, _) = plans();
        let shared_workload = vec![
            PrefixSimRequest {
                prompt_tokens: 256,
                max_new_tokens: 32,
                actual_new_tokens: 32,
                prefix_group: 7,
                shared_prefix_tokens: 256,
            };
            24
        ];
        let plain_workload = vec![
            SimRequest { prompt_tokens: 256, max_new_tokens: 32, actual_new_tokens: 32 };
            24
        ];
        let cfg = sim_cfg(
            KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
            60,
            24,
        );
        let plain = simulate_serving(&decode, &prefill, &cfg, &plain_workload);
        let shared = simulate_serving_shared(&decode, &prefill, &cfg, &shared_workload, false);
        assert_eq!(plain.completed, 24, "plain run must drain");
        assert_eq!(shared.completed, 24, "shared run must drain");
        assert_eq!(
            shared.generated_tokens, plain.generated_tokens,
            "sharing changes capacity, never the tokens delivered"
        );
        assert!(
            shared.prefix_shared_tokens > 0,
            "followers must attach published prefixes: {shared:?}"
        );
        assert!(
            shared.prefill_tokens < plain.prefill_tokens,
            "attached positions are prefill compute never run: {} vs {}",
            shared.prefill_tokens,
            plain.prefill_tokens
        );
        assert!(
            shared.cow_copies > 0,
            "divergence inside the shared boundary block must copy-on-write: {shared:?}"
        );
        assert!(shared.peak_shared_blocks > 0, "blocks must actually be held shared");
        assert!(
            shared.mean_occupancy >= 3.0 * plain.mean_occupancy,
            "sharing must multiply admitted concurrency ≥ 3× at fixed arena bytes: \
             {:.2} vs {:.2}",
            shared.mean_occupancy,
            plain.mean_occupancy
        );
        assert!(
            shared.tokens_per_s() > plain.tokens_per_s(),
            "the extra concurrency must buy throughput: {:.1} vs {:.1} tok/s",
            shared.tokens_per_s(),
            plain.tokens_per_s()
        );
    }

    #[test]
    fn quantized_kv_blocks_double_admitted_concurrency_at_fixed_arena_bytes() {
        // Same byte budget, two block formats: fp16-accounted blocks vs
        // int8 blocks with per-row scales (~2× smaller, ~4× vs fp32).
        // The quantized run must hold ≥ 2× the concurrency on the same
        // shared-prefix workload — and must be billed the f32
        // re-materialization its gathers perform, so the multiplier is
        // priced, never free.
        let (decode, prefill, _) = plans();
        let workload = vec![
            PrefixSimRequest {
                prompt_tokens: 256,
                max_new_tokens: 32,
                actual_new_tokens: 32,
                prefix_group: 3,
                shared_prefix_tokens: 256,
            };
            24
        ];
        let fp_blocks = 40;
        let acfg = arena(fp_blocks);
        let budget = fp_blocks * acfg.block_bytes();
        let q_blocks = budget / acfg.quantized_block_bytes();
        assert!(
            q_blocks as f64 >= 1.9 * fp_blocks as f64,
            "int8 blocks must ~double block capacity at fixed bytes: {q_blocks} vs {fp_blocks}"
        );
        let reservation =
            KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } };
        let fp = simulate_serving_shared(
            &decode,
            &prefill,
            &sim_cfg(reservation, fp_blocks, 24),
            &workload,
            false,
        );
        let q = simulate_serving_shared(
            &decode,
            &prefill,
            &sim_cfg(reservation, q_blocks, 24),
            &workload,
            true,
        );
        assert_eq!(fp.completed, 24, "fp run must drain");
        assert_eq!(q.completed, 24, "quantized run must drain");
        assert_eq!(q.generated_tokens, fp.generated_tokens, "format never changes tokens");
        assert_eq!(fp.dequant_s, 0.0, "the fp path must pay exactly zero dequant");
        assert!(
            q.dequant_s > 0.0,
            "int8 KV reads must be billed their f32 re-materialization: {q:?}"
        );
        assert!(
            q.peak_device_bytes <= budget,
            "quantized watermark must stay inside the same byte budget: {} vs {}",
            q.peak_device_bytes,
            budget
        );
        assert!(
            q.mean_occupancy >= 2.0 * fp.mean_occupancy,
            "quantized blocks must buy ≥ 2× admitted concurrency at fixed bytes: \
             {:.2} vs {:.2}",
            q.mean_occupancy,
            fp.mean_occupancy
        );
    }

    #[test]
    fn unshared_prompts_through_the_sharing_path_match_plain_sim_exactly() {
        // Bit-exactness guard for the unshared path: unique prompts
        // (shared_prefix_tokens = 0) driven through
        // `simulate_serving_shared` must reproduce `simulate_serving`
        // *exactly* — zero-match `admit_prefixed` IS the plain gate,
        // publishing unique keys attaches nothing, and no CoW or
        // dequant term may fire — so enabling the sharing machinery on
        // a workload with nothing to share costs nothing.
        let (decode, prefill, _) = plans();
        let shared_workload = vec![
            PrefixSimRequest {
                prompt_tokens: 64,
                max_new_tokens: 32,
                actual_new_tokens: 32,
                prefix_group: 0,
                shared_prefix_tokens: 0,
            };
            6
        ];
        let plain_workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 32, actual_new_tokens: 32 };
            6
        ];
        let cfg = sim_cfg(
            KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
            48,
            8,
        );
        let plain = simulate_serving(&decode, &prefill, &cfg, &plain_workload);
        let shared = simulate_serving_shared(&decode, &prefill, &cfg, &shared_workload, false);
        assert_eq!(plain.completed, 6);
        assert_eq!(shared.completed, 6);
        assert_eq!(shared.prefix_shared_tokens, 0, "nothing to attach");
        assert_eq!(shared.cow_copies, 0, "nothing shared, nothing copied");
        assert_eq!(shared.rounds, plain.rounds, "identical schedules");
        assert_eq!(shared.preemptions, plain.preemptions);
        assert_eq!(shared.prefill_tokens, plain.prefill_tokens);
        assert_eq!(shared.generated_tokens, plain.generated_tokens);
        assert!(
            shared.total_s == plain.total_s,
            "identical float sequences must price identically: {} vs {}",
            shared.total_s,
            plain.total_s
        );
    }

    #[test]
    fn pipelined_depth1_matches_the_unpipelined_loop_exactly() {
        // The sim half of the tentpole's depth-1 identity gate (the PR-6
        // unshared-path idiom): driving a mixed prefill+decode workload
        // through `simulate_serving_pipelined` at depth 1 with zero
        // modeled plan cost must reproduce `simulate_serving` *bitwise*
        // — same schedules, same float sequences, same totals — so the
        // pipelined machinery at depth 1 IS today's loop, not an
        // approximation of it.
        let (decode, prefill, _) = plans();
        let mut workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 48, actual_new_tokens: 48 };
            6
        ];
        workload
            .extend(vec![SimRequest { prompt_tokens: 96, max_new_tokens: 16, actual_new_tokens: 16 }; 4]);
        let mut cfg = sim_cfg(
            KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
            64,
            6,
        );
        cfg.sched.prefill_chunk_tokens = 32; // chunked + packed prefill path too
        let plain = simulate_serving(&decode, &prefill, &cfg, &workload);
        let piped = simulate_serving_pipelined(
            &decode,
            &prefill,
            &cfg,
            PipelineSimConfig::default(),
            &workload,
        );
        assert_eq!(piped.completed, plain.completed);
        assert_eq!(piped.rounds, plain.rounds, "identical schedules");
        assert_eq!(piped.preemptions, plain.preemptions);
        assert_eq!(piped.generated_tokens, plain.generated_tokens);
        assert_eq!(piped.prefill_tokens, plain.prefill_tokens);
        assert!(piped.decode_s == plain.decode_s, "{} vs {}", piped.decode_s, plain.decode_s);
        assert!(piped.prefill_s == plain.prefill_s, "{} vs {}", piped.prefill_s, plain.prefill_s);
        assert!(piped.gather_s == plain.gather_s);
        assert!(piped.ttft_p50_s == plain.ttft_p50_s);
        assert!(piped.ttft_p95_s == plain.ttft_p95_s);
        assert!(
            piped.total_s == plain.total_s,
            "depth 1 must be bitwise identical: {} vs {}",
            piped.total_s,
            plain.total_s
        );
        assert!(
            piped.overlap_hidden_s == 0.0,
            "the additive depth-1 loop hides nothing: {}",
            piped.overlap_hidden_s
        );
    }

    #[test]
    fn pipelined_depth2_hides_host_plan_time_and_depth3_adds_nothing() {
        // The overlap claim at the simulator level, and the depth sweep's
        // shape: with host planning at 30% of a device decode round,
        // depth 2 must buy ≥ 1.25× tokens/s (the bench gate's bar), and
        // depth 3 must price *bitwise identically* to depth 2 — one
        // device and one host are both saturated by a single
        // planned-ahead slot, which is why the engine defaults to 2.
        let (decode, prefill, _) = plans();
        // Decode-heavy mixed workload: short prompts, long generations —
        // the regime where per-round host overhead dominates.
        let workload = vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 128, actual_new_tokens: 128 };
            12
        ];
        let mut cfg = sim_cfg(
            KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
            192,
            6,
        );
        cfg.sched.prefill_chunk_tokens = 32;
        let host_plan_s = 0.3 * simulate_batched(&decode, 6).total_s;
        let run = |depth: usize| {
            simulate_serving_pipelined(
                &decode,
                &prefill,
                &cfg,
                PipelineSimConfig { depth, host_plan_s },
                &workload,
            )
        };
        let (d1, d2, d3) = (run(1), run(2), run(3));
        assert_eq!(d1.completed, 12, "depth-1 run must drain");
        assert_eq!(d2.completed, 12, "depth-2 run must drain");
        assert_eq!(d2.rounds, d1.rounds, "pipelining reprices rounds, never reschedules them");
        assert_eq!(d2.generated_tokens, d1.generated_tokens);
        assert!(
            d2.tokens_per_s() >= 1.25 * d1.tokens_per_s(),
            "depth 2 must buy ≥ 1.25× at 30% host share: {:.1} vs {:.1} tok/s",
            d2.tokens_per_s(),
            d1.tokens_per_s()
        );
        assert!(
            d3.total_s == d2.total_s,
            "depth 3 must price bitwise like depth 2: {} vs {}",
            d3.total_s,
            d2.total_s
        );
        // Billed-overlap accounting: depth 1 hides nothing, and at depth
        // 2 the hidden host seconds are exactly the additive-vs-billed
        // gap (up to float summation order) — the denominator the
        // async-overlap bench's realized-efficiency gate divides by.
        assert!(d1.overlap_hidden_s == 0.0, "{}", d1.overlap_hidden_s);
        assert!(d2.overlap_hidden_s > 0.0, "depth 2 must hide host work");
        let gap = d1.total_s - d2.total_s;
        assert!(
            (d2.overlap_hidden_s - gap).abs() <= 1e-9 * gap.max(1.0),
            "hidden accounting must match the billed gap: {} vs {}",
            d2.overlap_hidden_s,
            gap
        );
        // Host-bound regime: plan time past the device round stays
        // visible — the overlap clamps at max(dev, host), it never
        // invents free host work.
        let heavy = simulate_serving_pipelined(
            &decode,
            &prefill,
            &cfg,
            PipelineSimConfig { depth: 2, host_plan_s: 10.0 * host_plan_s },
            &workload,
        );
        assert!(heavy.total_s > d2.total_s, "host-bound rounds must still bill the residual");
    }

    /// Fleet plans: gemma2-2b target + TinyLM draft on the Adreno 750
    /// profile — the phone-class pairing the fleet gate names.
    fn fleet_plans() -> (ExecutionPlan, ExecutionPlan) {
        let dev = device("adreno_750").unwrap();
        let opts = CompileOptions::default();
        let t = simulate_llm(
            &llm_config("gemma2_2b").unwrap(),
            &dev,
            QuantScheme::Mixed844,
            1024,
            256,
            &opts,
        )
        .unwrap();
        let d =
            simulate_llm(&llm_config("tinylm").unwrap(), &dev, QuantScheme::Q8, 1024, 256, &opts)
                .unwrap();
        (t.decode.plan.clone(), d.decode.plan.clone())
    }

    #[test]
    fn fleet_plain_mode_prices_exactly_like_plain_batched_rounds() {
        // Identity anchor: with every member plain, the fleet sim is a
        // closed-loop batched decode — n rounds at width B, each billed
        // one mixed-width pass of all-1 widths (= simulate_batched(B))
        // plus the sync. No draft seconds, no proposals.
        let (target, draft) = fleet_plans();
        let n = 32usize;
        let b = 6usize;
        let workload =
            vec![FleetSimRequest { new_tokens: n, acceptance: 0.9, draft: None }; b];
        let sync = 150e-6;
        let rep = simulate_serving_fleet(
            &target,
            &[FleetDraftSim { plan: &draft, k_max: 4 }],
            FleetKPolicy::StaticK, // draft: None ⇒ plain regardless of policy
            sync,
            &workload,
        );
        assert_eq!(rep.rounds, n);
        assert_eq!(rep.generated_tokens, n * b);
        assert_eq!(rep.spec_proposed_tokens, 0, "draft-less members never propose");
        assert_eq!(rep.draft_s, 0.0);
        assert_eq!(rep.mean_planned_k, 0.0);
        let round = simulate_batched(&target, b).total_s + sync;
        assert!(
            (rep.total_s - n as f64 * round).abs() < 1e-9 * rep.total_s,
            "{} vs {}",
            rep.total_s,
            n as f64 * round
        );
        // Explicit Plain policy prices identically even with drafts
        // assigned — the policy, not the assignment, decides.
        let assigned =
            vec![FleetSimRequest { new_tokens: n, acceptance: 0.9, draft: Some(0) }; b];
        let plain = simulate_serving_fleet(
            &target,
            &[FleetDraftSim { plan: &draft, k_max: 4 }],
            FleetKPolicy::Plain,
            sync,
            &assigned,
        );
        assert_eq!(plain.total_s, rep.total_s);
    }

    #[test]
    fn fleet_static_uniform_round_matches_the_speculative_round_model() {
        // Pricing anchor: a uniform static-k batch must reproduce
        // speculative_round_time_s per round — the fleet decomposition
        // (shrinking-width draft steps + fractional catch-up + one
        // mixed-width verify) collapses to the closed form when every
        // member bids the same k at the same α.
        let (target, draft) = fleet_plans();
        let (n, b, k, alpha) = (200usize, 8usize, 4usize, 0.7f64);
        let sync = 150e-6;
        let workload =
            vec![FleetSimRequest { new_tokens: n, acceptance: alpha, draft: Some(0) }; b];
        let rep = simulate_serving_fleet(
            &target,
            &[FleetDraftSim { plan: &draft, k_max: k }],
            FleetKPolicy::StaticK,
            sync,
            &workload,
        );
        assert_eq!(rep.generated_tokens, n * b, "closed loop drains every budget");
        // Identical members run in lockstep, so every round but the
        // budget-clamped tail is a full-width, full-k speculative round.
        // The aggregate rate must therefore match the closed form
        // `(1 + E[a])·B / (round + sync)` to within the tail's O(1/rounds)
        // share.
        let spec_round =
            crate::sim::exec::speculative_round_time_s(&draft, &target, b, k, alpha);
        let modeled_rate =
            (1.0 + expected_accepted_tokens(k, alpha)) * b as f64 / (spec_round + sync);
        let rate = rep.tokens_per_s();
        assert!(
            (rate - modeled_rate).abs() < 0.05 * modeled_rate,
            "uniform fleet rounds must price as speculative rounds: {rate:.1} vs {modeled_rate:.1} tok/s"
        );
        // Every member proposed ~k per round (tail clamps excepted).
        assert!(rep.spec_proposed_tokens > (rep.rounds - 2) * b * k);
        assert!(rep.spec_accepted_tokens > 0 && rep.spec_accepted_tokens < rep.spec_proposed_tokens);
        assert!(rep.draft_s > 0.0 && rep.verify_s > 0.0);
    }

    #[test]
    fn fleet_round_prices_target_stream_once_across_dispatch_groups() {
        // The weight-streaming fix, pinned end to end: the fleet round
        // executes ONE mixed verify pass (weights stream once for plain
        // members + every draft group), so the market's bid must price a
        // width at its marginal rows — not charge each member the full
        // stream as the dedicated-round `choose_k` does. There is
        // provably an α band where the two disagree (the dedicated test
        // is the shared test plus the already-paid base), and inside it
        // the shared market keeps speculating in steady state while a
        // per-group-priced market would quit after the EWMA converges.
        let (target, draft) = fleet_plans();
        let b = 8usize;
        let k_max = 4usize;
        let cost = SpecRoundCost::from_plans(&draft, &target, b, k_max);
        let ctl = DraftController { k_max, ..Default::default() };
        let mut flip = None;
        let mut a = 0.01f64;
        while a < 0.99 {
            let dedicated = ctl.choose_k(Some(a), &cost);
            let shared = ctl.choose_k_in_round(Some(a), &cost, true);
            assert!(
                dedicated == 0 || shared >= 1,
                "α = {a:.3}: dedicated bid {dedicated} but shared-round pricing sat out"
            );
            if dedicated == 0 && shared == 1 {
                flip = Some(a); // keep the largest such α: maximal shared margin
            }
            a += 0.002;
        }
        let flip = flip.expect(
            "hysteresis opens a band where only shared-round pricing speculates \
             (dedicated threshold = shared threshold + (h−1)·base)",
        );
        // At true α = flip with k = 1 the EWMA is unbiased (accepted /
        // proposed has expectation exactly α), so bids persist for the
        // whole run — and the execution side shares the draft step
        // across the group, making the realized margin strictly larger
        // than the per-member price the bid cleared.
        let workload =
            vec![FleetSimRequest { new_tokens: 48, acceptance: flip, draft: Some(0) }; b];
        let fleet = [FleetDraftSim { plan: &draft, k_max }];
        let sync = 150e-6;
        let run = |policy| simulate_serving_fleet(&target, &fleet, policy, sync, &workload);
        let (plain, adap) = (run(FleetKPolicy::Plain), run(FleetKPolicy::Adaptive));
        assert_eq!(adap.generated_tokens, 48 * b);
        assert!(
            (adap.spec_proposed_tokens as f64) > 0.4 * (adap.rounds * b) as f64,
            "shared pricing must keep bidding at α = {flip:.3}: proposed {} over {} member-rounds",
            adap.spec_proposed_tokens,
            adap.rounds * b
        );
        assert!(
            adap.tokens_per_s() >= plain.tokens_per_s(),
            "a bid that cleared marginal pricing must not lose to plain: {:.1} vs {:.1} tok/s",
            adap.tokens_per_s(),
            plain.tokens_per_s()
        );
    }

    #[test]
    fn fleet_adaptive_market_beats_static_k_on_mixed_alpha_traffic() {
        // The fleet gate's bar, at the simulator level: mixed traffic —
        // half high-α (a draft that mostly agrees), half essentially
        // adversarial (α = 0.05) — on one cheap draft. Static-k pays
        // draft + wide-verify overhead for the low-α cohort and loses;
        // the adaptive market reads the EWMA, drops those members to
        // plain decode, and must buy ≥ 1.2× aggregate tokens/s. It must
        // also never lose to all-plain (the market can always bid 0).
        let (target, draft) = fleet_plans();
        let sync = 150e-6;
        let mut workload = Vec::new();
        for _ in 0..6 {
            workload.push(FleetSimRequest { new_tokens: 64, acceptance: 0.9, draft: Some(0) });
        }
        for _ in 0..6 {
            workload.push(FleetSimRequest { new_tokens: 64, acceptance: 0.05, draft: Some(0) });
        }
        let fleet = [FleetDraftSim { plan: &draft, k_max: 4 }];
        let run = |policy| simulate_serving_fleet(&target, &fleet, policy, sync, &workload);
        let (plain, stat, adap) = (
            run(FleetKPolicy::Plain),
            run(FleetKPolicy::StaticK),
            run(FleetKPolicy::Adaptive),
        );
        assert_eq!(plain.generated_tokens, 64 * 12);
        assert_eq!(stat.generated_tokens, 64 * 12);
        assert_eq!(adap.generated_tokens, 64 * 12);
        assert!(
            adap.tokens_per_s() >= 1.2 * stat.tokens_per_s(),
            "adaptive must beat static-k by ≥ 1.2× on mixed α: {:.1} vs {:.1} tok/s",
            adap.tokens_per_s(),
            stat.tokens_per_s()
        );
        assert!(
            adap.tokens_per_s() >= plain.tokens_per_s(),
            "the market can always bid 0 — it must never lose to plain: {:.1} vs {:.1}",
            adap.tokens_per_s(),
            plain.tokens_per_s()
        );
        // The mechanism, not just the outcome: adaptive stops paying for
        // the low-α cohort (fewer proposals, smaller mean bid) while
        // still speculating on the high-α one.
        assert!(adap.spec_proposed_tokens < stat.spec_proposed_tokens);
        assert!(adap.mean_planned_k < stat.mean_planned_k);
        assert!(adap.spec_accepted_tokens > 0, "high-α members must still speculate");
    }
}
