//! Serving-level simulation: scheduler + KV arena + batched cost model.
//!
//! The kernel-level simulator ([`crate::sim::exec`]) prices one round at
//! a given batch size; this module closes the loop and prices a whole
//! *workload* — admission, paged growth, preemption, re-prefill — so KV
//! reservation disciplines can be compared at **fixed arena memory**:
//!
//! * [`KvReservation::Lifetime`]: claim `prompt + max_new_tokens` at
//!   admission (PR-1 discipline). Overflow-free, but short-generating
//!   sequences strand their unwritten reservation as internal
//!   fragmentation, capping concurrency.
//! * [`KvReservation::Paged`]: claim the prompt, grow block-by-block,
//!   gate admission on the expected footprint
//!   ([`crate::serving::AdmissionPolicy`]). Occupancy tracks actual
//!   footprints; mid-round exhaustion preempts (evict → requeue →
//!   re-prefill), and the simulator charges that re-prefill via
//!   [`crate::sim::exec::prefill_time_s`] so thrashing is priced, not
//!   hidden.
//!
//! Per-token KV accounting is one row per emitted token (the
//! final-emission row the engine skips is ≤ one block per sequence and
//! identical across disciplines, so comparisons are unaffected).

use std::collections::{HashMap, HashSet};

use crate::kv::{KvArena, KvArenaConfig, KvSeqHandle};
use crate::serving::request::{InferenceRequest, RequestId};
use crate::serving::scheduler::{Scheduler, SchedulerConfig};
use crate::serving::{blended_mean_gen, AdmissionPolicy};
use crate::sim::exec::{paged_gather_overhead_s, prefill_time_s, simulate_batched, ExecutionPlan};
use crate::util::div_ceil;

/// One simulated request: what the client *asks for* vs what the model
/// *actually generates* (the gap lifetime reservation pays for).
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub prompt_tokens: usize,
    /// The client's generation budget — what admission must assume.
    pub max_new_tokens: usize,
    /// Tokens actually generated before EOS (≤ `max_new_tokens`).
    pub actual_new_tokens: usize,
}

/// KV reservation discipline under test.
#[derive(Clone, Copy, Debug)]
pub enum KvReservation {
    /// Whole-lifetime claim at admission; never grows, never preempts.
    Lifetime,
    /// Prompt-only claim, on-demand growth, expectation-gated admission,
    /// preemption on exhaustion.
    Paged { policy: AdmissionPolicy },
}

/// Which mean-generation-length estimate admission is fed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GenLenEstimator {
    /// Average completed sequences only — the survivorship-biased pre-fix
    /// form, kept as an ablation: short generations finish first, so the
    /// warm-up mean is biased low and admission over-admits.
    CompletedOnly,
    /// Blend in-flight generated-so-far lower bounds into the estimate
    /// ([`blended_mean_gen`]) — the engine's behaviour.
    #[default]
    Blended,
}

/// Serving-simulation tuning.
#[derive(Clone, Copy, Debug)]
pub struct ServingSimConfig {
    pub sched: SchedulerConfig,
    pub arena: KvArenaConfig,
    pub reservation: KvReservation,
    /// Host/GPU sync per executed round (s).
    pub sync_s: f64,
    /// Sequence length the prefill plan was compiled at ([`prefill_time_s`]
    /// scales its linear and quadratic parts from it).
    pub prefill_plan_tokens: usize,
    /// Mean-generation estimator admission is fed.
    pub estimator: GenLenEstimator,
}

/// What a workload run produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingSimReport {
    pub rounds: usize,
    pub completed: usize,
    pub total_s: f64,
    pub decode_s: f64,
    pub prefill_s: f64,
    /// Block-table gather indirection billed to paged rounds
    /// ([`paged_gather_overhead_s`]); 0 under the dense lifetime layout.
    pub gather_s: f64,
    pub generated_tokens: usize,
    /// All prefilled positions, initial prefills *and* re-prefills.
    pub prefill_tokens: usize,
    pub preemptions: usize,
    /// Positions recomputed because of eviction.
    pub reprefill_tokens: usize,
    /// Mean executed decode-batch size over rounds that decoded.
    pub mean_occupancy: f64,
    pub peak_occupancy: usize,
    pub peak_blocks_in_use: usize,
    /// Peak concurrent live sequences (what the pre-paging dense runtime
    /// would have held a full-capacity KV tensor for — the device-memory
    /// sweep's dense baseline).
    pub peak_seqs: usize,
    /// Peak device bytes committed to KV blocks
    /// (`peak_blocks_in_use × block_bytes` — the same watermark the
    /// engine's [`crate::kv::PagedKvStore`] reports for real storage).
    pub peak_device_bytes: usize,
    /// Worst internal fragmentation snapshot across the run.
    pub peak_fragmentation_bytes: usize,
}

impl ServingSimReport {
    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.total_s
    }
}

/// Drive `workload` (all requests arrive at t=0 — saturating offered
/// load) through the round scheduler against a fixed-size arena, pricing
/// every round with the batched cost model. Panics only on internal
/// invariant violations; arena misconfiguration (a request that can
/// never fit) surfaces as a round-limit bailout with `completed <
/// workload.len()`.
pub fn simulate_serving(
    decode_plan: &ExecutionPlan,
    prefill_plan: &ExecutionPlan,
    cfg: &ServingSimConfig,
    workload: &[SimRequest],
) -> ServingSimReport {
    let mut sched = Scheduler::new(cfg.sched);
    let mut arena = KvArena::new(cfg.arena);
    let mut handles: HashMap<RequestId, KvSeqHandle> = HashMap::new();
    let mut actual: HashMap<RequestId, usize> = HashMap::new();
    for (i, r) in workload.iter().enumerate() {
        let id = i as u64;
        actual.insert(id, r.actual_new_tokens.min(r.max_new_tokens));
        sched.submit(InferenceRequest::new(id, vec![0; r.prompt_tokens], r.max_new_tokens));
    }

    let mut rep = ServingSimReport::default();
    let mut occupancy_sum = 0usize;
    let mut decode_rounds = 0usize;
    let mut completed_gen = 0usize;
    // The reservation discipline maps onto the shared admission policy:
    // lifetime IS worst-case admission (gate + claim the whole
    // footprint), paged gates on the expectation and claims the context.
    let (policy, paged) = match cfg.reservation {
        KvReservation::Lifetime => (AdmissionPolicy::WorstCase, false),
        KvReservation::Paged { policy } => (policy, true),
    };
    // Cache the per-round/per-context prices that repeat within a run.
    let mut round_cost: HashMap<usize, f64> = HashMap::new();
    let mut prefill_cost: HashMap<usize, f64> = HashMap::new();
    // Device profile for the paged gather pricing; unknown devices (plans
    // built against a test profile) just skip the overhead.
    let gather_dev = crate::device::registry::device(decode_plan.device_name);

    while !sched.is_idle() {
        // Admission: the *same* gate-and-claim the engine runs
        // ([`AdmissionPolicy::admit`]), fed the simulated estimate.
        let mean_gen = match cfg.estimator {
            GenLenEstimator::CompletedOnly => {
                if rep.completed > 0 {
                    Some(completed_gen as f64 / rep.completed as f64)
                } else {
                    None
                }
            }
            GenLenEstimator::Blended => {
                let (inflight, inflight_tokens) = sched.inflight_gen();
                blended_mean_gen(
                    rep.completed as u64,
                    completed_gen as u64,
                    inflight,
                    inflight_tokens,
                )
            }
        };
        sched.admit_where(|req, ctx_tokens| {
            match policy.admit(&mut arena, req, ctx_tokens, mean_gen) {
                Some(h) => {
                    handles.insert(req.id, h);
                    true
                }
                None => false,
            }
        });

        let round = sched.next_round();

        // Paged growth, with preemption on exhaustion — the *same* loop
        // the engine runs ([`Scheduler::ensure_round_capacity`]), so the
        // simulator can never diverge from the serving policy. (One row
        // per emission here, final tokens included — see module docs.)
        let held_out: HashSet<RequestId> = sched.ensure_round_capacity(
            &mut arena,
            &mut handles,
            &round.decode_batch,
            |_victim, bill, _bytes_freed| {
                rep.preemptions += 1;
                rep.reprefill_tokens += bill;
            },
        );

        // Decode: one token per surviving member, priced as one batched
        // round (weights stream once; KV/activations scale with B). Under
        // the paged layout each member's attention also walks its block
        // table — that indirection is billed per layer per block touched.
        let mut executed = 0usize;
        let mut gather_blocks = 0usize;
        for &id in &round.decode_batch {
            if held_out.contains(&id) {
                continue;
            }
            // Blocks this member's gather touches: its context so far
            // (written rows), per attention layer.
            gather_blocks +=
                div_ceil(arena.len(handles[&id]).max(1), cfg.arena.block_tokens) * cfg.arena.layers;
            arena.append(handles[&id], 1).expect("capacity ensured above");
            let seq = sched.seq_mut(id).expect("scheduled seq exists");
            seq.generated.push(0);
            seq.pos += 1;
            rep.generated_tokens += 1;
            executed += 1;
            // EOS: the model stops early; the scheduler (which only knows
            // the budget) sees the request finish at its actual length.
            if seq.generated.len() >= actual[&id] {
                seq.request.max_new_tokens = seq.generated.len();
            }
        }
        if executed > 0 {
            let t = *round_cost
                .entry(executed)
                .or_insert_with(|| simulate_batched(decode_plan, executed).total_s);
            rep.decode_s += t + cfg.sync_s;
            if paged {
                if let Some(dev) = &gather_dev {
                    rep.gather_s += paged_gather_overhead_s(dev, gather_blocks);
                }
            }
            occupancy_sum += executed;
            decode_rounds += 1;
            rep.peak_occupancy = rep.peak_occupancy.max(executed);
        }

        // Prefills (initial and re-prefills alike: an evicted sequence
        // re-enters here with its whole context, and pays for it — at the
        // plan priced for its *actual* context length, quadratic
        // attention term included).
        for &id in &round.prefills {
            if held_out.contains(&id) {
                continue; // evicted this round before its prefill ran
            }
            let seq = sched.seq_mut(id).expect("scheduled seq exists");
            let ctx = seq.context_len();
            seq.prefill_done = true;
            let t = *prefill_cost
                .entry(ctx)
                .or_insert_with(|| prefill_time_s(prefill_plan, cfg.prefill_plan_tokens, ctx));
            rep.prefill_s += t + cfg.sync_s;
            rep.prefill_tokens += ctx;
            // Immediate EOS (actual 0): finish straight out of prefill,
            // before the decode loop could over-generate a token.
            if seq.generated.len() >= actual[&id] {
                seq.request.max_new_tokens = seq.generated.len();
            }
            arena.append(handles[&id], ctx).expect("admission claimed the context");
        }

        let stats = arena.stats();
        rep.peak_blocks_in_use = rep.peak_blocks_in_use.max(stats.blocks_in_use);
        rep.peak_seqs = rep.peak_seqs.max(stats.sequences);
        rep.peak_fragmentation_bytes =
            rep.peak_fragmentation_bytes.max(stats.internal_fragmentation_bytes);

        for done in sched.reap_finished() {
            if let Some(h) = handles.remove(&done.request.id) {
                arena.release(h);
            }
            rep.completed += 1;
            completed_gen += done.generated.len();
        }

        rep.rounds += 1;
        if rep.rounds > 100_000 {
            break; // misconfigured workload: report what completed
        }
    }

    arena.verify().expect("arena invariants after drain");
    rep.peak_device_bytes = rep.peak_blocks_in_use * cfg.arena.block_bytes();
    rep.total_s = rep.decode_s + rep.prefill_s + rep.gather_s;
    if decode_rounds > 0 {
        rep.mean_occupancy = occupancy_sum as f64 / decode_rounds as f64;
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry::device;
    use crate::engine::compile::CompileOptions;
    use crate::engine::llm::simulate_llm;
    use crate::models::llm_config;
    use crate::quant::QuantScheme;

    /// Gemma2-2B plans on the Adreno 750 profile — the fixed-memory
    /// comparison the ISSUE's acceptance bar names.
    fn plans() -> (ExecutionPlan, ExecutionPlan, usize) {
        let cfg = llm_config("gemma2_2b").unwrap();
        let dev = device("adreno_750").unwrap();
        let opts = CompileOptions::default();
        let p = simulate_llm(&cfg, &dev, QuantScheme::Mixed844, 1024, 256, &opts).unwrap();
        (p.decode.plan.clone(), p.prefill.plan.clone(), 1024)
    }

    fn arena(num_blocks: usize) -> KvArenaConfig {
        KvArenaConfig {
            layers: 26,
            heads_kv: 4,
            head_dim: 256,
            block_tokens: 16,
            num_blocks,
        }
    }

    fn sim_cfg(
        reservation: KvReservation,
        num_blocks: usize,
        max_active: usize,
    ) -> ServingSimConfig {
        ServingSimConfig {
            sched: SchedulerConfig {
                max_active,
                max_prefills_per_round: 2,
                ..Default::default()
            },
            arena: arena(num_blocks),
            reservation,
            sync_s: 150e-6,
            prefill_plan_tokens: 1024,
            estimator: GenLenEstimator::default(),
        }
    }

    #[test]
    fn paged_admission_sustains_1_5x_occupancy_at_fixed_memory() {
        // The acceptance bar: long budgets (max_new 192) with short
        // actual generations (16 tokens) — lifetime reservation strands
        // 176 tokens per sequence; paged admission reclaims them. Same
        // arena (48 blocks), same workload, same scheduler.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 192, actual_new_tokens: 16 };
            24
        ];
        let lifetime = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, 48, 16),
            &workload,
        );
        let paged = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(
                KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.5 } },
                48,
                16,
            ),
            &workload,
        );
        assert_eq!(lifetime.completed, 24, "lifetime run must drain");
        assert_eq!(paged.completed, 24, "paged run must drain");
        assert!(
            paged.mean_occupancy >= 1.5 * lifetime.mean_occupancy,
            "paged occupancy {:.2} must be ≥ 1.5× lifetime {:.2} at equal arena bytes",
            paged.mean_occupancy,
            lifetime.mean_occupancy
        );
        assert!(
            paged.tokens_per_s() > lifetime.tokens_per_s(),
            "higher occupancy must buy throughput: {:.1} vs {:.1} tok/s",
            paged.tokens_per_s(),
            lifetime.tokens_per_s()
        );
        // The mechanism: lifetime's stranded reservations show up as
        // internal fragmentation the paged run does not carry.
        assert!(
            paged.peak_fragmentation_bytes < lifetime.peak_fragmentation_bytes,
            "paged frag {} must undercut lifetime frag {}",
            paged.peak_fragmentation_bytes,
            lifetime.peak_fragmentation_bytes
        );
    }

    #[test]
    fn exhaustion_preempts_requeues_and_charges_reprefill() {
        // Arena too small for the workload's *actual* footprints: paged
        // admission over-admits, growth exhausts the arena mid-round,
        // and the run must degrade to eviction + re-prefill — every
        // request still completes, and the recompute is billed.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 32, max_new_tokens: 64, actual_new_tokens: 64 };
            3
        ];
        let rep = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(
                KvReservation::Paged { policy: AdmissionPolicy::Expected { safety_margin: 1.0 } },
                8,
                4,
            ),
            &workload,
        );
        assert_eq!(rep.completed, 3, "exhaustion must degrade to queuing, not failure");
        assert_eq!(rep.generated_tokens, 3 * 64, "no tokens lost to eviction");
        assert!(rep.preemptions >= 1, "this workload must evict: {rep:?}");
        assert!(rep.reprefill_tokens > 0);
        assert!(
            rep.prefill_tokens > 3 * 32,
            "re-prefill work must be billed on top of the initial prefills: {rep:?}"
        );
        // Lifetime on the same arena never preempts — it just queues.
        let lifetime = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, 8, 4),
            &workload,
        );
        assert_eq!(lifetime.completed, 3);
        assert_eq!(lifetime.preemptions, 0);
    }

    #[test]
    fn lifetime_and_paged_agree_when_memory_is_plentiful() {
        // With an arena big enough for every worst case, the disciplines
        // admit identically — same schedule, same occupancy, no
        // preemptions — so paged mode is a strict generalization, not a
        // different scheduler. The only difference left is the priced
        // block-table gather indirection: paged is billed it (a ~1e-4
        // relative sliver), lifetime's dense layout is not.
        let (decode, prefill, _) = plans();
        let workload = vec![
            SimRequest { prompt_tokens: 64, max_new_tokens: 32, actual_new_tokens: 32 };
            6
        ];
        let big = 6 * 6 + 4; // 6 seqs × ceil(96/16) blocks, plus slack
        let l = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Lifetime, big, 8),
            &workload,
        );
        let p = simulate_serving(
            &decode,
            &prefill,
            &sim_cfg(KvReservation::Paged { policy: AdmissionPolicy::default() }, big, 8),
            &workload,
        );
        assert_eq!(l.completed, 6);
        assert_eq!(p.completed, 6);
        assert_eq!(p.preemptions, 0, "no pressure, no eviction");
        assert_eq!(l.rounds, p.rounds, "identical schedules");
        assert!((l.mean_occupancy - p.mean_occupancy).abs() < 1e-12);
        // Gather indirection: billed to paged only, and tiny.
        assert_eq!(l.gather_s, 0.0, "dense layout pays no gather");
        assert!(p.gather_s > 0.0, "paged layout must be billed the indirection");
        assert!(
            (p.total_s - l.total_s - p.gather_s).abs() < 1e-12 * l.total_s,
            "identical schedules may differ only by the gather bill"
        );
        assert!(
            p.gather_s < 1e-2 * l.total_s,
            "the indirection must not eat the paging win: {} vs {}",
            p.gather_s,
            l.total_s
        );
    }

    #[test]
    fn blended_estimator_cuts_warmup_preemptions_on_bimodal_workload() {
        // Survivorship-bias regression. Bimodal workload, shorts first:
        // the shorts complete almost immediately and drag the
        // completed-only mean to ~1 token, so admission (and especially
        // re-admission of evicted sequences, whose gate is
        // context + mean) over-admits the longs and the warm-up phase
        // thrashes. Blending the in-flight generated-so-far lower bounds
        // raises the estimate as the longs keep decoding, so the same
        // workload on the same arena preempts less — and never more.
        let (decode, prefill, _) = plans();
        let mut workload = vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 1 };
            8
        ];
        workload.extend(vec![
            SimRequest { prompt_tokens: 16, max_new_tokens: 96, actual_new_tokens: 96 };
            8
        ]);
        let run = |estimator: GenLenEstimator| {
            let cfg = ServingSimConfig {
                sched: SchedulerConfig {
                    max_active: 8,
                    max_prefills_per_round: 2,
                    ..Default::default()
                },
                arena: arena(30), // 480 tokens: ~4 fully-grown longs
                reservation: KvReservation::Paged {
                    policy: AdmissionPolicy::Expected { safety_margin: 1.0 },
                },
                sync_s: 150e-6,
                prefill_plan_tokens: 1024,
                estimator,
            };
            simulate_serving(&decode, &prefill, &cfg, &workload)
        };
        let biased = run(GenLenEstimator::CompletedOnly);
        let blended = run(GenLenEstimator::Blended);
        assert_eq!(biased.completed, 16, "biased run must still drain");
        assert_eq!(blended.completed, 16, "blended run must still drain");
        assert!(
            biased.preemptions > 0,
            "the bimodal workload must expose the over-admission pathology: {biased:?}"
        );
        assert!(
            blended.preemptions < biased.preemptions,
            "blending in-flight lower bounds must cut warm-up preemptions: \
             blended {} vs completed-only {}",
            blended.preemptions,
            biased.preemptions
        );
        // Fewer evictions ⇒ less recompute billed.
        assert!(blended.reprefill_tokens <= biased.reprefill_tokens);
    }
}
