//! GPU roofline performance simulator.
//!
//! The paper evaluates on physical GPUs; none are reachable here, so
//! latency comes from an analytical roofline over the *real execution
//! plans* the compiler emits (substitution documented in DESIGN.md §1).
//! For every planned kernel:
//!
//! ```text
//! t = max(flops / effective_compute, bytes / effective_bandwidth) + launch
//! ```
//!
//! with per-device effective compute (fp16 / fp32 / int8-extension paths),
//! effective bandwidth, texture-cache boosts, and launch overheads from
//! [`crate::device`]. The paper's headline phenomena all emerge from this
//! model because they are roofline phenomena: prefill is compute-bound,
//! decode is memory-bound (so weight quantization speeds decode by the
//! byte ratio but barely moves prefill), int8 extensions move only
//! prefill, and missing tensor-core access costs NVIDIA prefill 4–7×.
//!
//! Everything in this module runs on **virtual time only**: simulated
//! seconds come from the roofline formula, never from the host clock,
//! which is what makes every simulated latency bit-reproducible across
//! machines and CI runs. `mldrift lint` (rule `sim-wall-clock`,
//! [`crate::check::lint`]) enforces this — `Instant`/`SystemTime` are
//! banned tokens anywhere under `src/sim/`.

pub mod cost;
pub mod exec;
pub mod serving;

pub use cost::{kernel_cost, KernelCost};
pub use exec::{
    draft_time_s, expected_accepted_tokens, expected_draft_steps, kv_dequant_overhead_s,
    mixed_verify_time_s, packed_prefill_time_s, paged_gather_overhead_s, pipelined_round_time_s,
    simulate_batched, simulate_graph, speculative_round_time_s, verify_time_s, ExecutionPlan,
    PackedChunkCost, PlannedKernel, SimReport,
};
pub use serving::{
    simulate_serving, simulate_serving_fleet, simulate_serving_pipelined,
    simulate_serving_shared, simulate_serving_spec, FleetDraftSim, FleetKPolicy,
    FleetSimReport, FleetSimRequest, GenLenEstimator, KvReservation, PipelineSimConfig,
    PrefixSimRequest, ServingSimConfig, ServingSimReport, SimRequest, SpecSim,
};
