//! Execution-plan construction and whole-graph simulation.

use crate::codegen::select::{select_kernel, KernelChoice, KernelVariant, Stage};
use crate::device::profile::DeviceProfile;
use crate::error::{DriftError, Result};
use crate::graph::Graph;
use crate::memory::{lifetimes, plan as mem_plan, Strategy};
use crate::sim::cost::{kernel_cost, KernelCost};
use crate::tensor::DType;

/// One planned kernel: node + specialization + cost.
#[derive(Clone, Debug)]
pub struct PlannedKernel {
    pub node: usize,
    pub name: String,
    pub choice: KernelChoice,
    pub cost: KernelCost,
}

/// A compiled execution plan for one graph on one device.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub graph_name: String,
    pub device_name: &'static str,
    pub stage: Stage,
    pub kernels: Vec<PlannedKernel>,
    /// Intermediate-tensor arena size from the memory planner.
    pub arena_bytes: usize,
    /// Total weight bytes (quantized widths).
    pub weight_bytes: usize,
}

/// Simulation results for a plan.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub total_s: f64,
    pub launch_s: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub kernel_count: usize,
    pub flops: f64,
    pub bytes: f64,
    /// Fraction of kernel time spent in compute-bound kernels.
    pub compute_bound_frac: f64,
}

impl SimReport {
    pub fn tokens_per_s(&self, tokens: usize) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        tokens as f64 / self.total_s
    }
}

/// Build an execution plan: per-node kernel selection, activation-quant
/// kernel insertion accounting (§3.7), memory planning, and an OOM check
/// against the device budget.
pub fn build_plan(
    g: &Graph,
    dev: &DeviceProfile,
    stage: Stage,
    memory_strategy: Strategy,
) -> Result<ExecutionPlan> {
    g.validate()?;
    let mut kernels = Vec::new();
    for n in &g.nodes {
        if !n.kind.is_compute() || n.absorbed_into.is_some() {
            continue;
        }
        let choice = select_kernel(n, dev, stage);
        // §3.7: the prefill int8 path needs a dedicated activation-quant
        // kernel before each matmul-family op. Its cost: read+write the
        // input activations once, trivial compute.
        if choice.needs_act_quant {
            let in_node = &g.nodes[n.inputs[0]];
            let in_bytes =
                in_node.dtype.bytes_for(in_node.shape.padded_elements()) as f64;
            let quant_cost = KernelCost {
                flops: 2.0 * in_node.shape.elements() as f64,
                bytes: in_bytes + in_bytes / 2.0, // read fp16, write int8+scales
                weight_bytes: 0.0,               // pure activation traffic
                t_compute: 2.0 * in_node.shape.elements() as f64
                    / (dev.effective_gflops(crate::device::profile::Precision::Fp16) * 1e9),
                t_memory: (in_bytes * 1.5) / (dev.effective_bandwidth() * 1e9),
                t_launch: dev.launch_overhead_us * 1e-6,
            };
            kernels.push(PlannedKernel {
                node: n.id,
                name: format!("{}_act_quant", n.name),
                choice: KernelChoice {
                    variant: KernelVariant::QuantizeAct,
                    ..choice.clone()
                },
                cost: quant_cost,
            });
        }
        let cost = kernel_cost(g, n, &choice, dev, stage);
        kernels.push(PlannedKernel { node: n.id, name: n.name.clone(), choice, cost });
    }

    let usages = lifetimes(g, DType::F16);
    let mplan = mem_plan(&usages, memory_strategy);
    let weight_bytes = g.weight_bytes();
    let required = weight_bytes as u64 + mplan.total_bytes as u64;
    if required > dev.mem_budget_bytes {
        return Err(DriftError::OutOfMemory {
            required_bytes: required,
            budget_bytes: dev.mem_budget_bytes,
        });
    }
    Ok(ExecutionPlan {
        graph_name: g.name.clone(),
        device_name: dev.name,
        stage,
        kernels,
        arena_bytes: mplan.total_bytes,
        weight_bytes,
    })
}

/// Simulate a plan: sequential kernel execution (the paper synchronizes
/// after each token; within a token, kernels serialize on data deps and
/// mobile GPUs execute one compute kernel at a time). Structurally the
/// B=1 point of [`simulate_batched`], so the two can never diverge.
pub fn simulate(plan: &ExecutionPlan) -> SimReport {
    simulate_batched(plan, 1)
}

/// Simulate a plan executed as one **batched decode round** over `batch`
/// sequences: every kernel launches once, weight bytes stream once for
/// the whole batch, activation/KV bytes and FLOPs scale per sequence
/// ([`KernelCost::batched_total`]). `simulate_batched(plan, 1)` is the
/// bit-exact single-stream simulation ([`simulate`] delegates here). The
/// reported `total_s` is the *round* latency; divide token count by it
/// for round throughput.
pub fn simulate_batched(plan: &ExecutionPlan, batch: usize) -> SimReport {
    let b = batch.max(1) as f64;
    let mut r = SimReport { kernel_count: plan.kernels.len(), ..Default::default() };
    let mut compute_bound_time = 0.0;
    for k in &plan.kernels {
        let t = k.cost.batched_total(batch);
        let t_memory = k.cost.batched_t_memory(batch);
        r.total_s += t;
        r.launch_s += k.cost.t_launch;
        r.compute_s += k.cost.t_compute * b;
        r.memory_s += t_memory;
        r.flops += k.cost.flops * b;
        r.bytes += if batch <= 1 {
            k.cost.bytes
        } else {
            k.cost.weight_bytes + b * (k.cost.bytes - k.cost.weight_bytes)
        };
        if k.cost.t_compute * b >= t_memory {
            compute_bound_time += t;
        }
    }
    if r.total_s > 0.0 {
        r.compute_bound_frac = compute_bound_time / r.total_s;
    }
    r
}

/// Time to (re-)prefill a context of `tokens` positions, given a prefill
/// plan compiled at `plan_tokens`.
///
/// The cost splits by how each kernel scales with sequence length `S` at
/// fixed model/hardware:
///
/// * **linear** — the FC/conv GEMMs, norms, RoPE, embedding: work and
///   activation traffic ∝ S;
/// * **quadratic** — the attention score/context matmuls and the softmax
///   over the `S × S` score matrix: ∝ S².
///
/// Structurally, the quadratic kernels are exactly the weightless
/// [`KernelVariant::MatMulTiled`] launches (attention reads per-sequence
/// K/V, not shared weights) plus [`KernelVariant::Softmax`]; everything
/// else is linear. Total: `t(S) = linear·r + quad·r²` with
/// `r = S / plan_tokens` — monotone and super-linear, so eviction thrash
/// on *long* contexts is billed at its true quadratic rate instead of
/// the old linear extrapolation that under-billed it. At `r = 1` this is
/// exactly `simulate(plan).total_s`.
///
/// **Model scope:** this prices an *idealized right-sized* execution —
/// launch overhead and weight streaming scale with `r` too, as if a
/// plan compiled at exactly `tokens` existed. Running the one compiled
/// plan on a shorter context actually pays its full launch set and
/// weight stream; that as-executed form is
/// [`packed_prefill_time_s`] with a single chunk, which is what the
/// serving simulator bills every prefill (and re-prefill) with — the
/// two share the [`attention_quadratic`] kernel split and agree exactly
/// at `r = 1`.
pub fn prefill_time_s(plan: &ExecutionPlan, plan_tokens: usize, tokens: usize) -> f64 {
    let r = tokens as f64 / plan_tokens.max(1) as f64;
    let mut linear = 0.0;
    let mut quad = 0.0;
    for k in &plan.kernels {
        let t = k.cost.total();
        if attention_quadratic(k) {
            quad += t;
        } else {
            linear += t;
        }
    }
    linear * r + quad * r * r
}

/// Does this planned kernel scale **quadratically** with sequence
/// length? Structurally: the weightless attention score/context matmuls
/// ([`KernelVariant::MatMulTiled`] reading per-sequence K/V, not shared
/// weights) and the softmax over the `S × S` score matrix; everything
/// else (FC/conv GEMMs, norms, RoPE, embedding) is linear. The single
/// classification both prefill pricers share — [`prefill_time_s`] and
/// [`packed_prefill_time_s`] may bill launches differently (see below)
/// but must never disagree about which kernels are quadratic.
fn attention_quadratic(k: &PlannedKernel) -> bool {
    matches!(k.choice.variant, KernelVariant::MatMulTiled | KernelVariant::Softmax)
        && k.cost.weight_bytes == 0.0
}

/// One sequence's chunk in a packed prefill round, for pricing
/// ([`packed_prefill_time_s`]).
#[derive(Clone, Copy, Debug)]
pub struct PackedChunkCost {
    /// Context positions this chunk processes.
    pub tokens: usize,
    /// Context length once the chunk has run (`start + tokens`): every
    /// chunk position attends over *all* earlier positions, so the
    /// chunk's quadratic attention share is `end² − start²`, not
    /// `tokens²` — chunking a prompt never discounts its attention bill.
    pub context_end: usize,
}

/// Time for one round's **packed prefill**: chunks from several
/// sequences executed as one flattened `(Σ tokens, d_model)` GEMM per
/// kernel — one launch per kernel per round however many prompts are
/// packed, weight bytes streamed once for the pack
/// ([`KernelCost::packed_prefill_total`]).
///
/// Per-sequence shares follow the same linear/quadratic split as
/// [`prefill_time_s`]: the FC/conv GEMMs, norms, RoPE and embedding
/// scale with the chunk's token count; the weightless attention
/// score/softmax kernels scale with `end² − start²` (the chunk's rows
/// attend over the whole context so far). Summed over a prompt's
/// chunks the shares telescope to exactly the one-shot prompt's —
/// chunking moves *when* prefill work happens (and how many launches it
/// takes), never how much compute it is.
///
/// A pack holding one full-plan chunk (`tokens == context_end ==
/// plan_tokens`) reproduces `simulate(plan).total_s` exactly.
pub fn packed_prefill_time_s(
    plan: &ExecutionPlan,
    plan_tokens: usize,
    chunks: &[PackedChunkCost],
) -> f64 {
    if chunks.is_empty() {
        return 0.0;
    }
    let pt = plan_tokens.max(1) as f64;
    let mut linear = Vec::with_capacity(chunks.len());
    let mut quad = Vec::with_capacity(chunks.len());
    for c in chunks {
        debug_assert!(c.tokens <= c.context_end, "chunk longer than its context: {c:?}");
        let end = c.context_end as f64 / pt;
        let start = c.context_end.saturating_sub(c.tokens) as f64 / pt;
        linear.push(c.tokens as f64 / pt);
        quad.push(end * end - start * start);
    }
    plan.kernels
        .iter()
        .map(|k| {
            k.cost.packed_prefill_total(if attention_quadratic(k) { &quad } else { &linear })
        })
        .sum()
}

/// Extra time a **paged-KV** decode round pays over the dense layout for
/// reading K/V through per-sequence block tables (the §3.5/§3.8
/// indirection [`crate::kv::PagedKvStore`] performs): per block touched,
/// one table-entry read plus the burst the memory system loses at each
/// block boundary (the KV stream restarts at a new address, costing ~two
/// DRAM transactions for K and V each). `blocks_touched` is summed over
/// the round's sequences **and attention layers** (every layer's
/// attention walks its sequence's table).
///
/// This is deliberately the same structural operation the runtime's
/// gather performs, so the simulator and the engine stay in lockstep
/// about what paging costs; it is priced from the device's effective
/// bandwidth, and at mobile block sizes it is ~0.1 % of a decode round —
/// the paging win (occupancy at fixed memory) is not eaten by the
/// indirection.
pub fn paged_gather_overhead_s(dev: &DeviceProfile, blocks_touched: usize) -> f64 {
    const TABLE_ENTRY_BYTES: f64 = 4.0;
    // Two lost 64 B bursts at each block boundary, for K and for V.
    const BOUNDARY_BYTES: f64 = 2.0 * 64.0 * 2.0;
    blocks_touched as f64 * (TABLE_ENTRY_BYTES + BOUNDARY_BYTES)
        / (dev.effective_bandwidth().max(1e-9) * 1e9)
}

/// Extra time a decode round pays when KV blocks are stored **int8
/// quantized** ([`crate::kv::PagedKvStore::new_quantized`]): the gather
/// dequantizes every position it touches — per K/V row it reads the int8
/// payload plus its f32 scale and writes the f32 row into the dense
/// scratch, so the billed traffic is the int8 read + the f32 write
/// (5 bytes moved per element against the fp16 baseline's 2 + 2). Priced
/// from effective bandwidth like
/// [`paged_gather_overhead_s`](paged_gather_overhead_s), and billed only
/// in quantized mode — the fp32/fp16 path pays exactly zero here, which
/// the lifetime-vs-paged exactness test relies on.
///
/// `positions_touched` is summed over the round's sequences (each
/// contributes its context length) and `row_bytes` is the per-position
/// K+V int8 payload ([`crate::kv::KvArenaConfig::quantized_bytes_per_token`]
/// minus the two f32 scales — pass the config value directly; the 8
/// scale bytes are part of the read).
pub fn kv_dequant_overhead_s(
    dev: &DeviceProfile,
    positions_touched: usize,
    quantized_bytes_per_token: usize,
) -> f64 {
    let bytes_per_pos = crate::sim::cost::kv_dequant_bytes_per_position(quantized_bytes_per_token);
    positions_touched as f64 * bytes_per_pos / (dev.effective_bandwidth().max(1e-9) * 1e9)
}

/// Expected draft tokens accepted per speculative round under a
/// per-token draft/target agreement probability `acceptance` ∈ [0, 1]:
/// proposal `i` survives only if all before it did, so
/// `E[a] = Σ_{i=1..k} acceptance^i` (the greedy-decode special case of
/// Leviathan et al.'s acceptance analysis). `k` at `acceptance = 1`,
/// `0` at `acceptance = 0`.
pub fn expected_accepted_tokens(k: usize, acceptance: f64) -> f64 {
    let a = acceptance.clamp(0.0, 1.0);
    let mut term = 1.0;
    let mut sum = 0.0;
    for _ in 0..k {
        term *= a;
        sum += term;
    }
    sum
}

/// Expected draft decode rounds per speculative round: the `k` proposal
/// steps plus the **catch-up** step that follows a fully-accepted round
/// — the draft never consumed the last accepted proposal
/// ([`crate::runtime::speculative_step_greedy`] leaves it one row
/// behind), and full acceptance happens with probability
/// `acceptance^k`. `k = 0` means no speculation at all: zero draft
/// work, not a catch-up.
pub fn expected_draft_steps(k: usize, acceptance: f64) -> f64 {
    if k == 0 {
        return 0.0;
    }
    k as f64 + acceptance.clamp(0.0, 1.0).powi(k as i32)
}

/// Time for the proposal phase of one speculative round: `k` sequential
/// draft decode rounds at batch `batch` (each proposal feeds the next,
/// so the draft cannot batch across its own k — only across sequences).
/// Callers pricing whole rounds should scale one draft round by
/// [`expected_draft_steps`] instead, so the catch-up step after
/// fully-accepted rounds is billed too.
pub fn draft_time_s(draft_plan: &ExecutionPlan, batch: usize, k: usize) -> f64 {
    k as f64 * simulate_batched(draft_plan, batch).total_s
}

/// Time for the verify phase: the target scores all `k + 1` positions of
/// every sequence in **one** pass — priced per kernel by
/// [`KernelCost::speculative_verify_total`] (weights stream once, like a
/// `(k + 1)`-token prefill per sequence batched over the round). `k = 0`
/// equals the plain decode round exactly.
pub fn verify_time_s(target_decode_plan: &ExecutionPlan, batch: usize, k: usize) -> f64 {
    target_decode_plan
        .kernels
        .iter()
        .map(|kn| kn.cost.speculative_verify_total(batch, k))
        .sum()
}

/// Verify pass for a **mixed-width** round: sequence `i` contributes
/// `widths[i]` scored positions (`k_i + 1` for a draft-k member, `1`
/// for a plain-decode member). The fleet controller assigns k per
/// sequence, so a single target pass carries unequal widths — and
/// because [`KernelCost::speculative_verify_total`] prices `(batch, k)`
/// as one pass over `batch·(k+1)` rows, the mixed round collapses to a
/// plain batched round at `Σ widths` rows: weights stream once for the
/// whole mixed batch, exactly the within-model sharing the registry's
/// round grouping exists to preserve. An empty/zero-width round is
/// free.
pub fn mixed_verify_time_s(target_decode_plan: &ExecutionPlan, widths: &[usize]) -> f64 {
    let rows: usize = widths.iter().sum();
    if rows == 0 {
        return 0.0;
    }
    simulate_batched(target_decode_plan, rows).total_s
}

/// One whole speculative round at per-token acceptance `acceptance`:
/// the expected draft steps (k proposals + the probability-`α^k`
/// catch-up) then the k-wide verify. The serving simulator and the
/// bench's breakeven sweep both price rounds with this split, so "where
/// does draft-k pay?" is answerable from the cost model before real
/// hardware:
/// `speedup(α, k) = (1 + E[a]) · T / ((k + αᵏ)·D + V)` with `T` the
/// plain round, `D` a draft round, `V` the verify pass.
pub fn speculative_round_time_s(
    draft_plan: &ExecutionPlan,
    target_decode_plan: &ExecutionPlan,
    batch: usize,
    k: usize,
    acceptance: f64,
) -> f64 {
    expected_draft_steps(k, acceptance) * simulate_batched(draft_plan, batch).total_s
        + verify_time_s(target_decode_plan, batch, k)
}

/// One serving round under the bounded-depth pipelined executor —
/// [`KernelCost::pipelined_round_time_s`] exposed next to the other
/// round-time models. `depth <= 1` is the unpipelined loop
/// (`device + host`, bitwise); `depth >= 2` overlaps round N+1's host
/// planning with round N's device execution, so the visible host
/// overhead is `max(0, host_plan_s − device_exec_s)` instead of
/// additive. Depth beyond 2 is identical to depth 2: one device and one
/// host are both already busy with a single planned-ahead slot.
pub fn pipelined_round_time_s(device_exec_s: f64, host_plan_s: f64, depth: usize) -> f64 {
    KernelCost::pipelined_round_time_s(device_exec_s, host_plan_s, depth)
}

/// Convenience: plan + simulate.
pub fn simulate_graph(
    g: &Graph,
    dev: &DeviceProfile,
    stage: Stage,
    memory_strategy: Strategy,
) -> Result<(ExecutionPlan, SimReport)> {
    let plan = build_plan(g, dev, stage, memory_strategy)?;
    let report = simulate(&plan);
    Ok((plan, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry::device;
    use crate::graph::Graph;
    use crate::tensor::{DType, Shape};

    fn mlp(seq: usize, wdtype: DType) -> Graph {
        let mut g = Graph::new("mlp");
        let x = g.input("x", Shape::bhwc(1, 1, seq, 1024), DType::F16);
        let h = g.fully_connected("up", x, 4096, wdtype).unwrap();
        let h = g.unary("gelu", h, crate::graph::EwOp::Gelu).unwrap();
        let y = g.fully_connected("down", h, 1024, wdtype).unwrap();
        g.output(y);
        g
    }

    #[test]
    fn plan_and_simulate_smoke() {
        let dev = device("adreno_750").unwrap();
        let g = mlp(128, DType::I8);
        let (plan, rep) = simulate_graph(&g, &dev, Stage::Prefill, Strategy::GreedyBySize).unwrap();
        assert!(rep.total_s > 0.0);
        assert!(rep.flops > 0.0);
        assert!(plan.weight_bytes > 0);
        // Prefill int8 path inserts act-quant kernels before each FC.
        let quants = plan
            .kernels
            .iter()
            .filter(|k| k.choice.variant == KernelVariant::QuantizeAct)
            .count();
        assert_eq!(quants, 2);
    }

    #[test]
    fn oom_on_huge_model() {
        let dev = device("adreno_750").unwrap(); // ~4.96 GB budget
        let mut g = Graph::new("huge");
        let x = g.input("x", Shape::bhwc(1, 1, 1, 8192), DType::F16);
        // 8192×8192 fp16 ≈ 134 MB per layer × 48 layers ≈ 6.4 GB.
        let mut h = x;
        for i in 0..48 {
            h = g.fully_connected(&format!("fc{i}"), h, 8192, DType::F16).unwrap();
        }
        g.output(h);
        let err = build_plan(&g, &dev, Stage::Decode, Strategy::GreedyBySize).unwrap_err();
        assert!(matches!(err, DriftError::OutOfMemory { .. }), "{err}");
    }

    #[test]
    fn fusion_reduces_simulated_time() {
        let dev = device("adreno_750").unwrap();
        let mut fused = mlp(256, DType::I8);
        crate::fusion::passes::fuse_all(&mut fused, None);
        let unfused = mlp(256, DType::I8);
        let (_, t_fused) =
            simulate_graph(&fused, &dev, Stage::Prefill, Strategy::GreedyBySize).unwrap();
        let (_, t_unfused) =
            simulate_graph(&unfused, &dev, Stage::Prefill, Strategy::GreedyBySize).unwrap();
        assert!(
            t_fused.total_s < t_unfused.total_s,
            "fused {} vs unfused {}",
            t_fused.total_s,
            t_unfused.total_s
        );
        assert!(t_fused.kernel_count < t_unfused.kernel_count);
    }

    #[test]
    fn prefill_pricing_is_monotone_and_superlinear() {
        // Regression for the linear re-prefill extrapolation: attention
        // is quadratic in context, so doubling the context must MORE
        // than double the price (the old `base × ctx` model under-billed
        // eviction thrash on long contexts).
        let cfg = crate::models::llm_config("gemma2_2b").unwrap();
        let dev = device("adreno_750").unwrap();
        let p = crate::engine::llm::simulate_llm(
            &cfg,
            &dev,
            crate::quant::QuantScheme::Mixed844,
            1024,
            256,
            &crate::engine::compile::CompileOptions::default(),
        )
        .unwrap();
        let plan = &p.prefill.plan;
        // Anchor: at the compiled length the split model reproduces the
        // straight simulation exactly.
        let t_plan = prefill_time_s(plan, 1024, 1024);
        assert!((t_plan - simulate(plan).total_s).abs() < 1e-9 * t_plan);
        // Monotone super-linear: t(2n) > 2·t(n), strictly, at every scale.
        let mut prev = prefill_time_s(plan, 1024, 256);
        for tokens in [512usize, 1024, 2048, 4096] {
            let t = prefill_time_s(plan, 1024, tokens);
            assert!(
                t > 2.0 * prev,
                "prefill cost must be super-linear: t({tokens}) = {t} vs 2×t({}) = {}",
                tokens / 2,
                2.0 * prev
            );
            prev = t;
        }
    }

    #[test]
    fn packed_prefill_pricing_is_consistent_and_amortizes_launches() {
        let cfg = crate::models::llm_config("gemma2_2b").unwrap();
        let dev = device("adreno_750").unwrap();
        let p = crate::engine::llm::simulate_llm(
            &cfg,
            &dev,
            crate::quant::QuantScheme::Mixed844,
            1024,
            256,
            &crate::engine::compile::CompileOptions::default(),
        )
        .unwrap();
        let plan = &p.prefill.plan;
        // Anchor: one full-plan chunk reproduces the straight simulation.
        let full = PackedChunkCost { tokens: 1024, context_end: 1024 };
        let t_full = packed_prefill_time_s(plan, 1024, &[full]);
        let t_sim = simulate(plan).total_s;
        assert!((t_full - t_sim).abs() < 1e-9 * t_sim, "{t_full} vs {t_sim}");
        // Splitting one prompt across chunk entries of the SAME pack is
        // free: the linear shares sum and the quadratic shares telescope
        // (end² − start²), so the bill is identical to the one chunk.
        let halves = [
            PackedChunkCost { tokens: 512, context_end: 512 },
            PackedChunkCost { tokens: 512, context_end: 1024 },
        ];
        let t_halves = packed_prefill_time_s(plan, 1024, &halves);
        assert!((t_halves - t_full).abs() < 1e-9 * t_full, "{t_halves} vs {t_full}");
        // Splitting across ROUNDS pays one extra launch set per round —
        // more than the one-shot, but far less than twice it.
        let t_rounds = packed_prefill_time_s(plan, 1024, &halves[..1])
            + packed_prefill_time_s(plan, 1024, &halves[1..]);
        assert!(t_rounds > t_full, "per-round launches must be billed");
        assert!(t_rounds < 1.5 * t_full, "chunking must not double the bill");
        // Packing four prompts' chunks into one round beats running the
        // same four chunks as four sequential prefill rounds — by at
        // least the three launch sets the pack does not pay (weight
        // streams shared across the pack widen the gap further).
        let four: Vec<PackedChunkCost> =
            (0..4).map(|_| PackedChunkCost { tokens: 64, context_end: 64 }).collect();
        let packed = packed_prefill_time_s(plan, 1024, &four);
        let sequential: f64 =
            four.iter().map(|c| packed_prefill_time_s(plan, 1024, &[*c])).sum();
        let launch_set: f64 = plan.kernels.iter().map(|k| k.cost.t_launch).sum();
        assert!(
            sequential - packed >= 3.0 * launch_set * (1.0 - 1e-9),
            "short-chunk packs must amortize launches: {packed} vs {sequential} \
             (launch set {launch_set})"
        );
        // Empty pack: no work, no launch.
        assert_eq!(packed_prefill_time_s(plan, 1024, &[]), 0.0);
    }

    #[test]
    fn paged_gather_overhead_is_small_and_linear_in_blocks() {
        let dev = device("adreno_750").unwrap();
        assert_eq!(paged_gather_overhead_s(&dev, 0), 0.0);
        let one = paged_gather_overhead_s(&dev, 1);
        assert!(one > 0.0);
        let many = paged_gather_overhead_s(&dev, 26 * 8);
        assert!((many - 208.0 * one).abs() < 1e-18, "linear in blocks touched");
        // A full Gemma-scale round's gather (26 layers × 8 blocks × B=8)
        // must stay far below one decode round (~tens of ms): the
        // indirection cannot eat the paging win.
        assert!(paged_gather_overhead_s(&dev, 26 * 8 * 8) < 1e-4);
    }

    #[test]
    fn kv_dequant_overhead_is_linear_and_stays_below_a_round() {
        let dev = device("adreno_750").unwrap();
        // gemma2-2b-class per-token int8 KV payload.
        let qbpt = 2 * 26 * 4 * 256 + 8;
        assert_eq!(kv_dequant_overhead_s(&dev, 0, qbpt), 0.0);
        let one = kv_dequant_overhead_s(&dev, 1, qbpt);
        assert!(one > 0.0);
        let many = kv_dequant_overhead_s(&dev, 512, qbpt);
        assert!((many - 512.0 * one).abs() < 1e-15, "linear in positions");
        // A batch of 8 sequences at 512-token contexts re-materializes
        // ~1 GB of f32 scratch: tens of ms — a real, visible cost (the
        // sweep reports it), but bounded and linear, not runaway.
        let batch = kv_dequant_overhead_s(&dev, 8 * 512, qbpt);
        assert!(batch > 1e-3 && batch < 1e-1, "dequant bill out of range: {batch}");
    }

    #[test]
    fn expected_accepted_is_the_geometric_partial_sum() {
        assert_eq!(expected_accepted_tokens(4, 0.0), 0.0);
        assert_eq!(expected_accepted_tokens(4, 1.0), 4.0);
        assert!((expected_accepted_tokens(2, 0.5) - 0.75).abs() < 1e-12);
        assert!((expected_accepted_tokens(3, 0.7) - (0.7 + 0.49 + 0.343)).abs() < 1e-12);
        // Out-of-range inputs clamp instead of exploding the series.
        assert_eq!(expected_accepted_tokens(3, 1.5), 3.0);
        assert_eq!(expected_accepted_tokens(3, -0.2), 0.0);
    }

    #[test]
    fn verify_pass_prices_like_a_short_prefill_not_k_rounds() {
        let dev = device("adreno_750").unwrap();
        let g = mlp(1, DType::I4);
        let plan = build_plan(&g, &dev, Stage::Decode, Strategy::GreedyBySize).unwrap();
        let t = simulate(&plan).total_s;
        // k = 0 is the plain round bit-exactly (no model fork).
        assert_eq!(verify_time_s(&plan, 1, 0), t);
        // The k-wide verify streams weights once: far below k+1 rounds,
        // strictly above one round.
        let k = 3;
        let v = verify_time_s(&plan, 1, k);
        assert!(v > t);
        assert!(v < 0.5 * (k + 1) as f64 * t, "verify {v} vs {} rounds", (k + 1) as f64 * t);
        // Draft phase is k sequential rounds of the draft plan — plus the
        // catch-up round that follows a fully-accepted round.
        assert_eq!(draft_time_s(&plan, 1, k), k as f64 * t);
        assert_eq!(expected_draft_steps(0, 0.9), 0.0, "k = 0: no draft, no catch-up");
        assert_eq!(expected_draft_steps(k, 0.0), k as f64);
        assert_eq!(expected_draft_steps(k, 1.0), (k + 1) as f64);
        assert_eq!(
            speculative_round_time_s(&plan, &plan, 1, k, 0.0),
            draft_time_s(&plan, 1, k) + v
        );
        assert_eq!(
            speculative_round_time_s(&plan, &plan, 1, k, 1.0),
            (k + 1) as f64 * t + v,
            "full acceptance bills the catch-up draft step"
        );
    }

    #[test]
    fn mixed_verify_collapses_to_uniform_when_widths_agree() {
        let dev = device("adreno_750").unwrap();
        let g = mlp(1, DType::I4);
        let plan = build_plan(&g, &dev, Stage::Decode, Strategy::GreedyBySize).unwrap();
        // Uniform widths reproduce the (batch, k) verify bit-exactly:
        // both are one pass over batch·(k+1) rows.
        for (batch, k) in [(1usize, 0usize), (4, 0), (4, 3), (8, 2)] {
            let widths = vec![k + 1; batch];
            assert_eq!(
                mixed_verify_time_s(&plan, &widths),
                verify_time_s(&plan, batch, k),
                "batch {batch} k {k}"
            );
        }
        // A genuinely mixed round (half plain, half draft-3) costs the
        // same as any uniform round with the same total row count —
        // row-permutation invariance of the one-pass pricing.
        let mixed = [1usize, 4, 1, 4, 1, 4];
        assert_eq!(
            mixed_verify_time_s(&plan, &mixed),
            simulate_batched(&plan, 15).total_s
        );
        // Degenerate rounds are free.
        assert_eq!(mixed_verify_time_s(&plan, &[]), 0.0);
        assert_eq!(mixed_verify_time_s(&plan, &[0, 0]), 0.0);
    }

    #[test]
    fn decode_dominated_by_memory() {
        let dev = device("adreno_750").unwrap();
        let g = mlp(1, DType::I4);
        let (_, rep) = simulate_graph(&g, &dev, Stage::Decode, Strategy::GreedyBySize).unwrap();
        assert!(rep.compute_bound_frac < 0.2, "decode should be memory-bound: {rep:?}");
    }

    #[test]
    fn pipelined_round_time_overlaps_host_plan_past_depth_1() {
        let (dev, host) = (4e-3, 1.5e-3);
        // Depth 1 is the unpipelined loop, bitwise additive.
        assert_eq!(pipelined_round_time_s(dev, host, 1), dev + host);
        assert_eq!(pipelined_round_time_s(dev, host, 0), dev + host);
        // Depth 2: host planning hides under the device entirely when it
        // is shorter than the round.
        assert_eq!(pipelined_round_time_s(dev, host, 2), dev);
        // A host-bound round degenerates to max(dev, host).
        assert_eq!(pipelined_round_time_s(dev, 9e-3, 2), 9e-3);
        // Depth beyond 2 adds nothing — one device, one host.
        for depth in 3..6 {
            assert_eq!(
                pipelined_round_time_s(dev, host, depth),
                pipelined_round_time_s(dev, host, 2)
            );
            assert_eq!(
                pipelined_round_time_s(dev, 9e-3, depth),
                pipelined_round_time_s(dev, 9e-3, 2)
            );
        }
        // Overhead never goes negative and never exceeds the additive
        // model.
        for host in [0.0, 1e-4, 4e-3, 8e-3] {
            let t2 = pipelined_round_time_s(dev, host, 2);
            assert!(t2 >= dev && t2 <= dev + host);
        }
    }
}
