//! Shape and dtype inference for every operator.

use crate::graph::op::{OpKind, WeightInfo};
use crate::tensor::{DType, Shape};

/// Infer the output shape of `kind` given input shapes (and weights where
/// relevant). Returns a human-readable error string on mismatch (wrapped
/// into `DriftError::Shape` by the builder).
pub fn infer_shape(
    kind: &OpKind,
    inputs: &[Shape],
    weight: Option<&WeightInfo>,
) -> Result<Shape, String> {
    let one = |name: &str| -> Result<Shape, String> {
        inputs.first().copied().ok_or_else(|| format!("{name} needs an input"))
    };
    match kind {
        OpKind::Input | OpKind::Const => Err("inputs/consts are created directly".into()),

        OpKind::Conv2D { out_c, kh, kw, stride, pad } => {
            let x = one("conv2d")?;
            let w = weight.ok_or("conv2d needs weights")?;
            if w.shape.i != x.c {
                return Err(format!("conv2d weight I={} != input C={}", w.shape.i, x.c));
            }
            if w.shape.o != *out_c || w.shape.h != *kh || w.shape.w != *kw {
                return Err("conv2d weight shape inconsistent with attributes".into());
            }
            let oh = (x.h + 2 * pad).checked_sub(*kh).ok_or("conv2d kernel larger than padded input")? / stride + 1;
            let ow = (x.w + 2 * pad).checked_sub(*kw).ok_or("conv2d kernel larger than padded input")? / stride + 1;
            Ok(Shape::bhwc(x.b, oh, ow, *out_c))
        }

        OpKind::FullyConnected { out_c } => {
            let x = one("fully_connected")?;
            let w = weight.ok_or("fully_connected needs weights")?;
            if w.shape.i != x.c {
                return Err(format!("fc weight I={} != input C={}", w.shape.i, x.c));
            }
            Ok(Shape { c: *out_c, ..x })
        }

        OpKind::MatMul { transpose_b } => {
            let (a, b) = (inputs[0], inputs[1]);
            if a.b != b.b || a.h != b.h || a.d != b.d {
                return Err(format!("matmul batch dims mismatch: {a} vs {b}"));
            }
            // A: (B,1,M,K) as w=M, c=K. B: (B,1,K,N) or transposed (B,1,N,K).
            let (k_b, n) = if *transpose_b { (b.c, b.w) } else { (b.w, b.c) };
            if a.c != k_b {
                return Err(format!("matmul K mismatch: A K={} vs B K={k_b}", a.c));
            }
            Ok(Shape::bhwc(a.b, a.h, a.w, n))
        }

        OpKind::Elementwise(_) | OpKind::QuantAct => one("elementwise"),

        OpKind::Binary(_) => {
            let (a, b) = (inputs[0], inputs[1]);
            if a != b {
                return Err(format!("binary op shape mismatch: {a} vs {b}"));
            }
            Ok(a)
        }

        OpKind::RmsNorm { .. } | OpKind::LayerNorm { .. } | OpKind::Softmax => one("norm"),

        OpKind::GroupNorm { groups, .. } => {
            let x = one("group_norm")?;
            if x.c % groups != 0 {
                return Err(format!("group_norm: C={} not divisible by groups={groups}", x.c));
            }
            Ok(x)
        }

        OpKind::Rope { .. } => {
            let x = one("rope")?;
            if x.c % 2 != 0 {
                return Err("rope needs even channel count".into());
            }
            Ok(x)
        }

        OpKind::Reshape { out } => {
            let x = one("reshape")?;
            if x.elements() != out.elements() {
                return Err(format!(
                    "reshape element count mismatch: {x} ({}) vs {out} ({})",
                    x.elements(),
                    out.elements()
                ));
            }
            Ok(*out)
        }

        OpKind::Transpose { perm } => {
            let x = one("transpose")?;
            let mut sorted = *perm;
            sorted.sort();
            if sorted != [0, 1, 2, 3, 4] {
                return Err(format!("transpose perm {perm:?} is not a permutation"));
            }
            let dims = [x.b, x.h, x.w, x.d, x.c];
            Ok(Shape {
                b: dims[perm[0]],
                h: dims[perm[1]],
                w: dims[perm[2]],
                d: dims[perm[3]],
                c: dims[perm[4]],
                rank: 5,
            })
        }

        OpKind::Concat { axis } => {
            if *axis > 4 {
                return Err(format!("concat axis {axis} out of range"));
            }
            let first = inputs[0];
            let mut total = 0;
            for s in inputs {
                let dims_a = [s.b, s.h, s.w, s.d, s.c];
                let dims_f = [first.b, first.h, first.w, first.d, first.c];
                for ax in 0..5 {
                    if ax != *axis && dims_a[ax] != dims_f[ax] {
                        return Err(format!("concat: non-axis dims differ: {first} vs {s}"));
                    }
                }
                total += dims_a[*axis];
            }
            let mut dims = [first.b, first.h, first.w, first.d, first.c];
            dims[*axis] = total;
            Ok(Shape { b: dims[0], h: dims[1], w: dims[2], d: dims[3], c: dims[4], rank: first.rank })
        }

        OpKind::Embedding { dim, .. } => {
            let ids = one("embedding")?;
            Ok(Shape::bhwc(ids.b, ids.h.max(1), ids.w, *dim))
        }

        OpKind::Upsample2x => {
            let x = one("upsample2x")?;
            Ok(Shape { h: x.h * 2, w: x.w * 2, ..x })
        }

        OpKind::AvgPool { k } => {
            let x = one("avg_pool")?;
            if x.h % k != 0 || x.w % k != 0 {
                return Err(format!("avg_pool: {x} not divisible by k={k}"));
            }
            Ok(Shape { h: x.h / k, w: x.w / k, ..x })
        }

        OpKind::FusedAddRmsNorm { .. } => {
            let (a, b) = (inputs[0], inputs[1]);
            if a != b {
                return Err(format!("fused_add_rms_norm shape mismatch: {a} vs {b}"));
            }
            Ok(a)
        }

        OpKind::FusedQkvRope { heads_q, heads_kv, head_dim } => {
            let x = one("fused_qkv_rope")?;
            let packed = (heads_q + 2 * heads_kv) * head_dim;
            if x.c != packed {
                return Err(format!(
                    "fused_qkv_rope: input C={} != (h_q + 2·h_kv)·d_h = {packed}",
                    x.c
                ));
            }
            // Paper §3.6: Q emerges as (B·h_kv, S·h_q/h_kv, d_h).
            let s = x.w;
            Ok(Shape::bhwc(x.b * heads_kv, 1, s * heads_q / heads_kv, *head_dim))
        }
    }
}

/// Output dtype: quantizing ops emit I8; everything else propagates the
/// first input's dtype.
pub fn infer_dtype(kind: &OpKind, input_dtypes: &[DType]) -> DType {
    match kind {
        OpKind::QuantAct => DType::I8,
        OpKind::Embedding { .. } => DType::F16,
        _ => input_dtypes.first().copied().unwrap_or(DType::F16),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::WeightShape;

    fn wi(o: usize, h: usize, w: usize, i: usize) -> WeightInfo {
        WeightInfo { shape: WeightShape::ohwi(o, h, w, i), dtype: DType::F16 }
    }

    #[test]
    fn conv_same_padding() {
        let kind = OpKind::Conv2D { out_c: 320, kh: 3, kw: 3, stride: 1, pad: 1 };
        let out = infer_shape(&kind, &[Shape::bhwc(1, 64, 64, 4)], Some(&wi(320, 3, 3, 4))).unwrap();
        assert_eq!(out, Shape::bhwc(1, 64, 64, 320));
    }

    #[test]
    fn conv_stride_two() {
        let kind = OpKind::Conv2D { out_c: 8, kh: 3, kw: 3, stride: 2, pad: 1 };
        let out = infer_shape(&kind, &[Shape::bhwc(1, 64, 64, 4)], Some(&wi(8, 3, 3, 4))).unwrap();
        assert_eq!(out, Shape::bhwc(1, 32, 32, 8));
    }

    #[test]
    fn conv_channel_mismatch_rejected() {
        let kind = OpKind::Conv2D { out_c: 8, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert!(infer_shape(&kind, &[Shape::bhwc(1, 8, 8, 5)], Some(&wi(8, 3, 3, 4))).is_err());
    }

    #[test]
    fn matmul_shapes() {
        // (1,1,128,64) × (1,1,64,256) → (1,1,128,256)
        let out = infer_shape(
            &OpKind::MatMul { transpose_b: false },
            &[Shape::bhwc(1, 1, 128, 64), Shape::bhwc(1, 1, 64, 256)],
            None,
        )
        .unwrap();
        assert_eq!(out, Shape::bhwc(1, 1, 128, 256));
        // transposed B: (1,1,256,64)
        let out = infer_shape(
            &OpKind::MatMul { transpose_b: true },
            &[Shape::bhwc(1, 1, 128, 64), Shape::bhwc(1, 1, 256, 64)],
            None,
        )
        .unwrap();
        assert_eq!(out, Shape::bhwc(1, 1, 128, 256));
    }

    #[test]
    fn qkv_rope_paper_layout() {
        // Gemma2-2B-like: h_q=8, h_kv=4, d_h=256, S=128.
        let kind = OpKind::FusedQkvRope { heads_q: 8, heads_kv: 4, head_dim: 256 };
        let packed_c = (8 + 2 * 4) * 256;
        let out = infer_shape(&kind, &[Shape::bhwc(1, 1, 128, packed_c)], None).unwrap();
        // (B·h_kv, S·h_q/h_kv, d_h) = (4, 256, 256)
        assert_eq!(out, Shape::bhwc(4, 1, 128 * 2, 256));
    }

    #[test]
    fn transpose_and_reshape() {
        let out = infer_shape(
            &OpKind::Transpose { perm: [0, 2, 1, 3, 4] },
            &[Shape::bhwdc(2, 3, 4, 1, 5)],
            None,
        )
        .unwrap();
        assert_eq!((out.h, out.w), (4, 3));
        assert!(infer_shape(
            &OpKind::Reshape { out: Shape::linear(10) },
            &[Shape::bhwc(1, 1, 3, 4)],
            None
        )
        .is_err());
    }

    #[test]
    fn concat_axis_checks() {
        let a = Shape::bhwc(1, 4, 4, 8);
        let b = Shape::bhwc(1, 4, 4, 16);
        let out = infer_shape(&OpKind::Concat { axis: 4 }, &[a, b], None).unwrap();
        assert_eq!(out.c, 24);
        assert!(infer_shape(&OpKind::Concat { axis: 1 }, &[a, b], None).is_err());
    }

    #[test]
    fn quant_act_emits_i8() {
        assert_eq!(infer_dtype(&OpKind::QuantAct, &[DType::F16]), DType::I8);
        assert_eq!(infer_dtype(&OpKind::Softmax, &[DType::F32]), DType::F32);
    }
}
