//! Host graph interpreter: execute a (possibly fused) graph numerically.
//!
//! This is the compiler's semantic oracle. Shader execution is simulated
//! in this reproduction, so the interpreter is what makes graph-level
//! transformations *testable as math*, not just as shapes:
//!
//! * **Fusion equivalence** — a fused graph must produce the same values
//!   as the unfused one (`tests` below run both and compare), covering
//!   the elementwise/branch/residual+RMSNorm passes of §3.6.
//! * **Quantization semantics** — quantized weight dtypes are
//!   quantize-dequantized through [`crate::quant`], so the interpreter
//!   reproduces deployment numerics, and `QuantAct` performs the real
//!   §3.7 dynamic activation quantization round-trip.
//!
//! Weights come from a seeded [`WeightStore`] keyed by node name, so two
//! structurally-different-but-equivalent graphs see identical parameters.

use std::collections::HashMap;

use crate::error::{DriftError, Result};
use crate::graph::op::{BinOp, EwOp, OpKind};
use crate::graph::{Graph, NodeId};
use crate::quant::{dequantize_i4, dequantize_i8, quantize_i4, quantize_i8};
use crate::tensor::{DType, HostTensor, Shape};
use crate::util::rng::Pcg32;

/// Deterministic weight provider: weights are generated from the node
/// name's hash so equivalent nodes in different graphs agree.
pub struct WeightStore {
    seed: u64,
    cache: HashMap<String, Vec<f32>>,
}

impl WeightStore {
    pub fn new(seed: u64) -> Self {
        WeightStore { seed, cache: HashMap::new() }
    }

    fn name_seed(&self, name: &str) -> u64 {
        // FNV-1a over the name, mixed with the store seed.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h ^ self.seed
    }

    /// Raw f32 weights for a node (`rows = O`, `cols = H·W·D·I`), scaled
    /// small so deep graphs stay numerically tame.
    pub fn weights(&mut self, name: &str, rows: usize, cols: usize) -> &[f32] {
        let seed = self.name_seed(name);
        self.cache.entry(name.to_string()).or_insert_with(|| {
            let mut rng = Pcg32::seeded(seed);
            (0..rows * cols).map(|_| (rng.gen_f32() * 2.0 - 1.0) * 0.1).collect()
        })
    }

    /// Weights after the deployment quantization round-trip for `dtype`.
    pub fn deployed_weights(
        &mut self,
        name: &str,
        rows: usize,
        cols: usize,
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let w = self.weights(name, rows, cols).to_vec();
        Ok(match dtype {
            DType::I8 => dequantize_i8(&quantize_i8(rows, cols, &w)?),
            DType::I4 => dequantize_i4(&quantize_i4(rows, cols, &w)?),
            _ => w,
        })
    }
}

/// Execute `g` with the given input feeds; returns the values of
/// `g.outputs` in order.
pub fn execute(
    g: &Graph,
    feeds: &HashMap<String, HostTensor>,
    store: &mut WeightStore,
) -> Result<Vec<HostTensor>> {
    g.validate()?;
    let mut values: Vec<Option<HostTensor>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        let val = |id: NodeId| -> Result<&HostTensor> {
            values[id]
                .as_ref()
                .ok_or_else(|| DriftError::Graph(format!("node {id} evaluated out of order")))
        };
        let mut out = match &n.kind {
            OpKind::Input => feeds
                .get(&n.name)
                .cloned()
                .ok_or_else(|| DriftError::Graph(format!("missing feed for input {}", n.name)))?,
            OpKind::Const => HostTensor::zeros(n.shape),
            OpKind::FullyConnected { out_c } => {
                let x = val(n.inputs[0])?;
                let wi = n.weight.expect("fc weights");
                let w = store.deployed_weights(&n.name, *out_c, wi.shape.i, wi.dtype)?;
                fully_connected(x, &w, *out_c)
            }
            OpKind::Conv2D { out_c, kh, kw, stride, pad } => {
                let x = val(n.inputs[0])?;
                let wi = n.weight.expect("conv weights");
                let w = store.deployed_weights(
                    &n.name,
                    *out_c,
                    kh * kw * wi.shape.i,
                    wi.dtype,
                )?;
                conv2d(x, &w, *out_c, *kh, *kw, *stride, *pad)
            }
            OpKind::MatMul { transpose_b } => {
                matmul(val(n.inputs[0])?, val(n.inputs[1])?, *transpose_b)
            }
            OpKind::Elementwise(op) => unary(val(n.inputs[0])?, *op),
            OpKind::Binary(op) => binary(val(n.inputs[0])?, val(n.inputs[1])?, *op),
            OpKind::RmsNorm { eps } => rms_norm(val(n.inputs[0])?, *eps),
            OpKind::FusedAddRmsNorm { eps } => {
                let sum = binary(val(n.inputs[0])?, val(n.inputs[1])?, BinOp::Add);
                rms_norm(&sum, *eps)
            }
            OpKind::LayerNorm { eps } => layer_norm(val(n.inputs[0])?, *eps),
            OpKind::Softmax => softmax(val(n.inputs[0])?),
            OpKind::Rope { theta } => rope(val(n.inputs[0])?, *theta),
            OpKind::Reshape { out } => {
                HostTensor::from_vec(*out, val(n.inputs[0])?.data.clone())?
            }
            OpKind::QuantAct => quant_act(val(n.inputs[0])?),
            OpKind::Upsample2x => upsample2x(val(n.inputs[0])?),
            OpKind::AvgPool { k } => avg_pool(val(n.inputs[0])?, *k),
            other => {
                return Err(DriftError::Graph(format!(
                    "interpreter does not implement {} (node {})",
                    other.name(),
                    n.name
                )))
            }
        };
        // Fused state on live kernels: consumers read the post-epilogue
        // value from this node's buffer.
        for (other, op) in &n.fused_adds {
            out = binary(&out, val(*other)?, *op);
        }
        for e in &n.epilogue {
            out = unary(&out, *e);
        }
        values[n.id] = Some(out);
    }
    g.outputs
        .iter()
        .map(|&o| {
            values[o]
                .clone()
                .ok_or_else(|| DriftError::Graph(format!("output {o} not evaluated")))
        })
        .collect()
}

// ---- op kernels (reference semantics) -----------------------------------

fn fully_connected(x: &HostTensor, w: &[f32], out_c: usize) -> HostTensor {
    let s = x.shape;
    let in_c = s.c;
    let rows = s.elements() / in_c;
    let mut out = vec![0f32; rows * out_c];
    for r in 0..rows {
        for o in 0..out_c {
            let mut acc = 0f32;
            for i in 0..in_c {
                acc += x.data[r * in_c + i] * w[o * in_c + i];
            }
            out[r * out_c + o] = acc;
        }
    }
    HostTensor::from_vec(Shape { c: out_c, ..s }, out).unwrap()
}

fn conv2d(
    x: &HostTensor,
    w: &[f32],
    out_c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> HostTensor {
    let s = x.shape;
    let (oh, ow) = ((s.h + 2 * pad - kh) / stride + 1, (s.w + 2 * pad - kw) / stride + 1);
    let out_shape = Shape::bhwc(s.b, oh, ow, out_c);
    let mut out = HostTensor::zeros(out_shape);
    for b in 0..s.b {
        for y in 0..oh {
            for xx in 0..ow {
                for o in 0..out_c {
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        let iy = (y * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (xx * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            for i in 0..s.c {
                                // w layout: (O, KH, KW, I) row-major.
                                acc += x.get(b, iy as usize, ix as usize, 0, i)
                                    * w[((o * kh + ky) * kw + kx) * s.c + i];
                            }
                        }
                    }
                    out.set(b, y, xx, 0, o, acc);
                }
            }
        }
    }
    out
}

fn matmul(a: &HostTensor, b: &HostTensor, transpose_b: bool) -> HostTensor {
    let (sa, sb) = (a.shape, b.shape);
    let (m, k) = (sa.w, sa.c);
    let n = if transpose_b { sb.w } else { sb.c };
    let out_shape = Shape::bhwc(sa.b, sa.h, m, n);
    let mut out = HostTensor::zeros(out_shape);
    for bi in 0..sa.b {
        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0f32;
                for ki in 0..k {
                    let bv = if transpose_b {
                        b.get(bi, 0, ni, 0, ki)
                    } else {
                        b.get(bi, 0, ki, 0, ni)
                    };
                    acc += a.get(bi, 0, mi, 0, ki) * bv;
                }
                out.set(bi, 0, mi, 0, ni, acc);
            }
        }
    }
    out
}

fn unary(x: &HostTensor, op: EwOp) -> HostTensor {
    let f = |v: f32| -> f32 {
        match op {
            EwOp::Relu => v.max(0.0),
            EwOp::Gelu => 0.5 * v * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (v + 0.044715 * v * v * v)).tanh()),
            EwOp::Silu => v / (1.0 + (-v).exp()),
            EwOp::Tanh => v.tanh(),
            EwOp::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            EwOp::Exp => v.exp(),
            EwOp::Rsqrt => 1.0 / v.sqrt(),
            EwOp::Neg => -v,
            EwOp::Scale(s) => v * s,
            EwOp::Offset(o) => v + o,
        }
    };
    HostTensor::from_vec(x.shape, x.data.iter().map(|v| f(*v)).collect()).unwrap()
}

fn binary(a: &HostTensor, b: &HostTensor, op: BinOp) -> HostTensor {
    let f = |x: f32, y: f32| match op {
        BinOp::Add => x + y,
        BinOp::Sub => x - y,
        BinOp::Mul => x * y,
        BinOp::Div => x / y,
    };
    HostTensor::from_vec(
        a.shape,
        a.data.iter().zip(&b.data).map(|(x, y)| f(*x, *y)).collect(),
    )
    .unwrap()
}

fn per_row<F: Fn(&[f32], &mut [f32])>(x: &HostTensor, f: F) -> HostTensor {
    let c = x.shape.c;
    let mut out = vec![0f32; x.data.len()];
    for (xr, or) in x.data.chunks(c).zip(out.chunks_mut(c)) {
        f(xr, or);
    }
    HostTensor::from_vec(x.shape, out).unwrap()
}

fn rms_norm(x: &HostTensor, eps: f32) -> HostTensor {
    per_row(x, |xr, or| {
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / xr.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (o, v) in or.iter_mut().zip(xr) {
            *o = v * inv;
        }
    })
}

fn layer_norm(x: &HostTensor, eps: f32) -> HostTensor {
    per_row(x, |xr, or| {
        let mean = xr.iter().sum::<f32>() / xr.len() as f32;
        let var = xr.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / xr.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (o, v) in or.iter_mut().zip(xr) {
            *o = (v - mean) * inv;
        }
    })
}

fn softmax(x: &HostTensor) -> HostTensor {
    per_row(x, |xr, or| {
        let m = xr.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0;
        for (o, v) in or.iter_mut().zip(xr) {
            *o = (v - m).exp();
            sum += *o;
        }
        for o in or.iter_mut() {
            *o /= sum;
        }
    })
}

fn rope(x: &HostTensor, theta: f32) -> HostTensor {
    // Positions run along W; rotate (even, odd) halves of C.
    let s = x.shape;
    let half = s.c / 2;
    let mut out = HostTensor::zeros(s);
    for b in 0..s.b {
        for t in 0..s.w {
            for j in 0..half {
                let freq = 1.0 / theta.powf(j as f32 / half as f32);
                let (sin, cos) = (t as f32 * freq).sin_cos();
                let x1 = x.get(b, 0, t, 0, j);
                let x2 = x.get(b, 0, t, 0, j + half);
                out.set(b, 0, t, 0, j, x1 * cos - x2 * sin);
                out.set(b, 0, t, 0, j + half, x1 * sin + x2 * cos);
            }
        }
    }
    out
}

fn quant_act(x: &HostTensor) -> HostTensor {
    // Dynamic per-row int8 quantize + dequantize (§3.7 round-trip).
    per_row(x, |xr, or| {
        let absmax = xr.iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        for (o, v) in or.iter_mut().zip(xr) {
            *o = (v / scale).round().clamp(-127.0, 127.0) * scale;
        }
    })
}

fn upsample2x(x: &HostTensor) -> HostTensor {
    let s = x.shape;
    let mut out = HostTensor::zeros(Shape { h: s.h * 2, w: s.w * 2, ..s });
    for b in 0..s.b {
        for y in 0..s.h * 2 {
            for xx in 0..s.w * 2 {
                for c in 0..s.c {
                    let v = x.get(b, y / 2, xx / 2, 0, c);
                    out.set(b, y, xx, 0, c, v);
                }
            }
        }
    }
    out
}

fn avg_pool(x: &HostTensor, k: usize) -> HostTensor {
    let s = x.shape;
    let mut out = HostTensor::zeros(Shape { h: s.h / k, w: s.w / k, ..s });
    let inv = 1.0 / (k * k) as f32;
    for b in 0..s.b {
        for y in 0..s.h / k {
            for xx in 0..s.w / k {
                for c in 0..s.c {
                    let mut acc = 0f32;
                    for dy in 0..k {
                        for dx in 0..k {
                            acc += x.get(b, y * k + dy, xx * k + dx, 0, c);
                        }
                    }
                    out.set(b, y, xx, 0, c, acc * inv);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::passes::fuse_all;
    use crate::graph::Graph;
    use crate::util::propcheck::assert_close;

    fn feed(name: &str, t: HostTensor) -> HashMap<String, HostTensor> {
        let mut m = HashMap::new();
        m.insert(name.to_string(), t);
        m
    }

    fn run(g: &Graph, feeds: &HashMap<String, HostTensor>) -> Vec<HostTensor> {
        let mut store = WeightStore::new(99);
        execute(g, feeds, &mut store).unwrap()
    }

    /// The key property: fusion must not change the computed values.
    fn assert_fusion_equivalent(mut g: Graph, feeds: HashMap<String, HostTensor>) {
        let unfused = run(&g, &feeds);
        fuse_all(&mut g, None);
        let fused = run(&g, &feeds);
        assert_eq!(unfused.len(), fused.len());
        for (a, b) in unfused.iter().zip(&fused) {
            assert_close(&a.data, &b.data, 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("fusion changed values: {e}"));
        }
    }

    #[test]
    fn fc_matches_manual() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 2, 3), DType::F32);
        let y = g.fully_connected("fc", x, 2, DType::F32).unwrap();
        g.output(y);
        let mut store = WeightStore::new(1);
        let xs = HostTensor::from_vec(
            Shape::bhwc(1, 1, 2, 3),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
        .unwrap();
        let out = execute(&g, &feed("x", xs.clone()), &mut store).unwrap();
        let w = store.weights("fc", 2, 3).to_vec();
        // row 0 · w[o]
        let want00 = 1.0 * w[0] + 2.0 * w[1] + 3.0 * w[2];
        assert!((out[0].data[0] - want00).abs() < 1e-6);
    }

    #[test]
    fn quantized_weights_change_values_slightly() {
        let mut build = |dt: DType| {
            let mut g = Graph::new("t");
            let x = g.input("x", Shape::bhwc(1, 1, 4, 32), DType::F32);
            let y = g.fully_connected("fc", x, 16, dt).unwrap();
            g.output(y);
            let mut rng = Pcg32::seeded(3);
            let xs = HostTensor::random(Shape::bhwc(1, 1, 4, 32), &mut rng);
            run(&g, &feed("x", xs))
        };
        let f = build(DType::F32);
        let q8 = build(DType::I8);
        let q4 = build(DType::I4);
        let err = |a: &HostTensor, b: &HostTensor| {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max)
        };
        let e8 = err(&f[0], &q8[0]);
        let e4 = err(&f[0], &q4[0]);
        assert!(e8 > 0.0 && e4 > e8, "quant error ordering: {e8} vs {e4}");
        assert!(e4 < 0.2, "int4 error bounded: {e4}");
    }

    #[test]
    fn fusion_preserves_ffn_semantics() {
        // The Fig. 4 patterns all at once: residual + rmsnorm + gated FFN.
        let mut g = Graph::new("ffn");
        let x = g.input("x", Shape::bhwc(1, 1, 6, 32), DType::F32);
        let r = g.input("r", Shape::bhwc(1, 1, 6, 32), DType::F32);
        let sum = g.binary("add", x, r, BinOp::Add).unwrap();
        let normed = g.rms_norm("norm", sum).unwrap();
        let gate = g.fully_connected("gate", normed, 64, DType::F32).unwrap();
        let gate = g.unary("silu", gate, EwOp::Silu).unwrap();
        let up = g.fully_connected("up", normed, 64, DType::F32).unwrap();
        let prod = g.binary("mul", up, gate, BinOp::Mul).unwrap();
        let down = g.fully_connected("down", prod, 32, DType::F32).unwrap();
        let out = g.binary("resid2", sum, down, BinOp::Add).unwrap();
        g.output(out);

        let mut rng = Pcg32::seeded(11);
        let mut feeds = HashMap::new();
        feeds.insert("x".into(), HostTensor::random(Shape::bhwc(1, 1, 6, 32), &mut rng));
        feeds.insert("r".into(), HostTensor::random(Shape::bhwc(1, 1, 6, 32), &mut rng));
        assert_fusion_equivalent(g, feeds);
    }

    #[test]
    fn fusion_preserves_conv_epilogue_semantics() {
        let mut g = Graph::new("conv");
        let x = g.input("x", Shape::bhwc(1, 6, 6, 8), DType::F32);
        let c = g.conv2d("c1", x, 8, 3, 1, 1, DType::F32).unwrap();
        let a = g.unary("relu", c, EwOp::Relu).unwrap();
        let c2 = g.conv2d("c2", a, 8, 3, 1, 1, DType::F32).unwrap();
        let merged = g.binary("skip", c2, a, BinOp::Add).unwrap();
        g.output(merged);
        let mut rng = Pcg32::seeded(21);
        assert_fusion_equivalent(
            g,
            feed("x", HostTensor::random(Shape::bhwc(1, 6, 6, 8), &mut rng)),
        );
    }

    #[test]
    fn fusion_equivalence_property_random_chains() {
        use crate::util::propcheck::{check, Config};
        check("fusion preserves elementwise-chain semantics", Config::cases(20), |rng| {
            let len = 1 + rng.gen_range(4) as usize;
            let mut g = Graph::new("chain");
            let x = g.input("x", Shape::bhwc(1, 1, 4, 16), DType::F32);
            let mut h = g.fully_connected("fc", x, 16, DType::F32).unwrap();
            for i in 0..len {
                let op = *rng.choose(&[
                    EwOp::Relu,
                    EwOp::Silu,
                    EwOp::Tanh,
                    EwOp::Sigmoid,
                    EwOp::Scale(0.5),
                    EwOp::Offset(0.1),
                ]);
                h = g.unary(&format!("ew{i}"), h, op).unwrap();
            }
            g.output(h);
            let xs = HostTensor::random(Shape::bhwc(1, 1, 4, 16), rng);
            let feeds = feed("x", xs);
            let unfused = run(&g, &feeds);
            crate::fusion::passes::fuse_all(&mut g, None);
            let fused = run(&g, &feeds);
            crate::util::propcheck::assert_close(&unfused[0].data, &fused[0].data, 1e-5, 1e-5)
        });
    }

    #[test]
    fn quant_act_roundtrip_semantics() {
        let mut g = Graph::new("q");
        let x = g.input("x", Shape::bhwc(1, 1, 2, 64), DType::F32);
        let q = g.quant_act("q", x).unwrap();
        g.output(q);
        let mut rng = Pcg32::seeded(31);
        let xs = HostTensor::random(Shape::bhwc(1, 1, 2, 64), &mut rng);
        let out = run(&g, &feed("x", xs.clone()));
        // Round-trip error bounded by scale/2 per element.
        for (a, b) in xs.data.iter().zip(&out[0].data) {
            assert!((a - b).abs() <= 1.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut g = Graph::new("s");
        let x = g.input("x", Shape::bhwc(2, 1, 3, 16), DType::F32);
        let s = g.softmax("sm", x).unwrap();
        g.output(s);
        let mut rng = Pcg32::seeded(41);
        let xs = HostTensor::random(Shape::bhwc(2, 1, 3, 16), &mut rng);
        let out = run(&g, &feed("x", xs));
        for row in out[0].data.chunks(16) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row sum {sum}");
        }
    }
}
