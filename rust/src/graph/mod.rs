//! Operator graph: op set, DAG, builder, and shape inference.
//!
//! Neural networks are represented as DAGs of operator nodes over logical
//! BHWDC tensors. The graph is the input to every downstream stage:
//! fusion ([`crate::fusion`]), memory planning ([`crate::memory`]), kernel
//! selection + shader codegen ([`crate::codegen`]), and the roofline
//! simulator ([`crate::sim`]).
//!
//! Convention (paper §3.6): LLM activations are 4D `(B, 1, S, C)` — height
//! is 1, the sequence runs along W, features along C — which lets the same
//! conv/FC kernels serve both CNN and transformer workloads. Attention
//! heads fold into the batch axis, e.g. `(B·h_kv, S·h_q/h_kv, d_h)`.

pub mod op;
pub mod graph;
pub mod infer;
pub mod interp;

pub use graph::{Graph, Node, NodeId};
pub use op::{BinOp, EwOp, OpKind, WeightInfo};
