//! The operator set.

use crate::tensor::{DType, Shape, WeightShape};

/// Unary elementwise operations (fusable epilogues).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EwOp {
    Relu,
    Gelu,
    Silu,
    Tanh,
    Sigmoid,
    Exp,
    Rsqrt,
    Neg,
    /// Multiply by a compile-time scalar.
    Scale(f32),
    /// Add a compile-time scalar.
    Offset(f32),
}

/// Binary elementwise operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// Weight metadata attached to conv / FC / embedding nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightInfo {
    pub shape: WeightShape,
    /// Storage dtype of the weights (F16, I8, I4 …).
    pub dtype: DType,
}

impl WeightInfo {
    pub fn bytes(&self) -> usize {
        self.dtype.bytes_for(self.shape.elements())
    }
}

/// Operator kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Graph input (activations, token ids, KV-cache views …).
    Input,
    /// Compile-time constant tensor (e.g. timestep embedding table).
    Const,
    /// 2D convolution `OHWI`; `same` padding when `pad = k/2`.
    Conv2D { out_c: usize, kh: usize, kw: usize, stride: usize, pad: usize },
    /// Fully connected over the channel axis (1×1 spatial).
    FullyConnected { out_c: usize },
    /// Batched matmul `(B,1,M,K) × (B,1,K,N) → (B,1,M,N)`;
    /// `transpose_b` consumes `(B,1,N,K)` as the second operand.
    MatMul { transpose_b: bool },
    /// Unary elementwise.
    Elementwise(EwOp),
    /// Binary elementwise (broadcast on matching trailing dims unsupported —
    /// shapes must match exactly; residuals always do).
    Binary(BinOp),
    /// RMS normalization over channels.
    RmsNorm { eps: f32 },
    /// Layer normalization over channels.
    LayerNorm { eps: f32 },
    /// Group normalization (UNet blocks).
    GroupNorm { groups: usize, eps: f32 },
    /// Softmax over the channel axis.
    Softmax,
    /// Rotary position embedding over channels (paper §3.6 fuses this with
    /// the QKV layout transform).
    Rope { theta: f32 },
    /// Reshape to an explicit target shape (element count preserved).
    Reshape { out: Shape },
    /// Transpose of the canonical BHWDC axes (permutation of [0..5)).
    Transpose { perm: [usize; 5] },
    /// Concatenate along a canonical axis index (0=B,1=H,2=W,3=D,4=C).
    Concat { axis: usize },
    /// Token embedding lookup: `(B,1,S,1)` i32 → `(B,1,S,dim)`.
    Embedding { vocab: usize, dim: usize },
    /// Nearest-neighbour 2× spatial upsample (UNet decoder).
    Upsample2x,
    /// Average pool with square kernel+stride `k` (UNet encoder).
    AvgPool { k: usize },
    /// Dynamic activation quantization: computes per-tensor scales and
    /// int8 activations (prefill stage, §3.7). Shape-preserving.
    QuantAct,
    /// Fused residual-add + RMSNorm (produced by the fusion pass, Fig. 4
    /// right). Two inputs: residual, x.
    FusedAddRmsNorm { eps: f32 },
    /// Fused QKV layout transform + RoPE custom kernel (§3.6). Input is the
    /// packed QKV projection `(B,1,S,(h_q+2·h_kv)·d_h)`; outputs the
    /// attention-ready Q view `(B·h_kv, 1, S·h_q/h_kv, d_h)`.
    FusedQkvRope { heads_q: usize, heads_kv: usize, head_dim: usize },
}

impl OpKind {
    /// Whether this op is a "compute" op that owns a GPU kernel (as opposed
    /// to inputs/constants which only bind memory).
    pub fn is_compute(&self) -> bool {
        !matches!(self, OpKind::Input | OpKind::Const)
    }

    /// Whether this op is a pure elementwise op (fusable as an epilogue).
    pub fn is_elementwise(&self) -> bool {
        matches!(self, OpKind::Elementwise(_) | OpKind::Binary(_))
    }

    /// Whether this op performs matrix multiplication work (conv / FC /
    /// matmul) — the ops whose weights the quantizer targets and whose
    /// kernels the stage-aware selector specializes.
    pub fn is_matmul_family(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2D { .. } | OpKind::FullyConnected { .. } | OpKind::MatMul { .. }
        )
    }

    /// Short name for reports and generated kernel labels.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Const => "const",
            OpKind::Conv2D { .. } => "conv2d",
            OpKind::FullyConnected { .. } => "fully_connected",
            OpKind::MatMul { .. } => "matmul",
            OpKind::Elementwise(_) => "elementwise",
            OpKind::Binary(_) => "binary",
            OpKind::RmsNorm { .. } => "rms_norm",
            OpKind::LayerNorm { .. } => "layer_norm",
            OpKind::GroupNorm { .. } => "group_norm",
            OpKind::Softmax => "softmax",
            OpKind::Rope { .. } => "rope",
            OpKind::Reshape { .. } => "reshape",
            OpKind::Transpose { .. } => "transpose",
            OpKind::Concat { .. } => "concat",
            OpKind::Embedding { .. } => "embedding",
            OpKind::Upsample2x => "upsample2x",
            OpKind::AvgPool { .. } => "avg_pool",
            OpKind::QuantAct => "quant_act",
            OpKind::FusedAddRmsNorm { .. } => "fused_add_rms_norm",
            OpKind::FusedQkvRope { .. } => "fused_qkv_rope",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification() {
        assert!(OpKind::Conv2D { out_c: 8, kh: 3, kw: 3, stride: 1, pad: 1 }.is_matmul_family());
        assert!(OpKind::FullyConnected { out_c: 8 }.is_matmul_family());
        assert!(!OpKind::Softmax.is_matmul_family());
        assert!(OpKind::Elementwise(EwOp::Gelu).is_elementwise());
        assert!(OpKind::Binary(BinOp::Add).is_elementwise());
        assert!(!OpKind::Input.is_compute());
        assert!(OpKind::Softmax.is_compute());
    }

    #[test]
    fn weight_bytes() {
        let wi = WeightInfo { shape: WeightShape::fc(256, 128), dtype: DType::I8 };
        assert_eq!(wi.bytes(), 256 * 128);
        let wi4 = WeightInfo { shape: WeightShape::fc(256, 128), dtype: DType::I4 };
        assert_eq!(wi4.bytes(), 256 * 128 / 2);
    }
}
