//! DAG container + fluent builder.

use crate::error::{DriftError, Result};
use crate::graph::infer;
use crate::graph::op::{BinOp, EwOp, OpKind, WeightInfo};
use crate::tensor::{DType, Shape, WeightShape};

/// Node identifier (index into `Graph::nodes`).
pub type NodeId = usize;

/// One operator node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<NodeId>,
    /// Inferred output shape.
    pub shape: Shape,
    /// Output activation dtype.
    pub dtype: DType,
    /// Weights consumed by this node (conv / FC / embedding).
    pub weight: Option<WeightInfo>,
    /// Fused elementwise epilogue (populated by the fusion pass).
    pub epilogue: Vec<EwOp>,
    /// Extra fused binary inputs (residual adds merged into this kernel);
    /// each entry is `(node, op)` — the node's output is combined into this
    /// node's output inside the same kernel.
    pub fused_adds: Vec<(NodeId, BinOp)>,
    /// If set, this node's output is produced *inside* the kernel of the
    /// referenced node (secondary output / zero-cost view): it owns no
    /// kernel launch and no compute cost, but may still own a buffer.
    pub absorbed_into: Option<NodeId>,
}

/// An operator DAG in topological insertion order.
///
/// Nodes are appended by the builder methods; each node's inputs must
/// already exist, so insertion order is a valid execution order (verified
/// by [`Graph::validate`]).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub outputs: Vec<NodeId>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Graph { name: name.to_string(), ..Default::default() }
    }

    fn push(&mut self, name: &str, kind: OpKind, inputs: Vec<NodeId>, weight: Option<WeightInfo>) -> Result<NodeId> {
        let id = self.nodes.len();
        for &i in &inputs {
            if i >= id {
                return Err(DriftError::Graph(format!(
                    "node {name}: input {i} does not precede node {id}"
                )));
            }
        }
        let in_shapes: Vec<Shape> = inputs.iter().map(|&i| self.nodes[i].shape).collect();
        let shape = infer::infer_shape(&kind, &in_shapes, weight.as_ref())
            .map_err(|e| DriftError::Shape(format!("node {name}: {e}")))?;
        let dtype = infer::infer_dtype(&kind, &inputs.iter().map(|&i| self.nodes[i].dtype).collect::<Vec<_>>());
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            inputs,
            shape,
            dtype,
            weight,
            epilogue: Vec::new(),
            fused_adds: Vec::new(),
            absorbed_into: None,
        });
        Ok(id)
    }

    // ---- builder methods -------------------------------------------------

    pub fn input(&mut self, name: &str, shape: Shape, dtype: DType) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind: OpKind::Input,
            inputs: vec![],
            shape,
            dtype,
            weight: None,
            epilogue: Vec::new(),
            fused_adds: Vec::new(),
            absorbed_into: None,
        });
        id
    }

    pub fn constant(&mut self, name: &str, shape: Shape, dtype: DType) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind: OpKind::Const,
            inputs: vec![],
            shape,
            dtype,
            weight: None,
            epilogue: Vec::new(),
            fused_adds: Vec::new(),
            absorbed_into: None,
        });
        id
    }

    pub fn conv2d(
        &mut self,
        name: &str,
        x: NodeId,
        out_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        wdtype: DType,
    ) -> Result<NodeId> {
        let in_c = self.nodes[x].shape.c;
        let weight = WeightInfo { shape: WeightShape::ohwi(out_c, k, k, in_c), dtype: wdtype };
        self.push(name, OpKind::Conv2D { out_c, kh: k, kw: k, stride, pad }, vec![x], Some(weight))
    }

    pub fn fully_connected(&mut self, name: &str, x: NodeId, out_c: usize, wdtype: DType) -> Result<NodeId> {
        let in_c = self.nodes[x].shape.c;
        let weight = WeightInfo { shape: WeightShape::fc(out_c, in_c), dtype: wdtype };
        self.push(name, OpKind::FullyConnected { out_c }, vec![x], Some(weight))
    }

    pub fn matmul(&mut self, name: &str, a: NodeId, b: NodeId, transpose_b: bool) -> Result<NodeId> {
        self.push(name, OpKind::MatMul { transpose_b }, vec![a, b], None)
    }

    pub fn unary(&mut self, name: &str, x: NodeId, op: EwOp) -> Result<NodeId> {
        self.push(name, OpKind::Elementwise(op), vec![x], None)
    }

    pub fn binary(&mut self, name: &str, a: NodeId, b: NodeId, op: BinOp) -> Result<NodeId> {
        self.push(name, OpKind::Binary(op), vec![a, b], None)
    }

    pub fn rms_norm(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::RmsNorm { eps: 1e-6 }, vec![x], None)
    }

    pub fn layer_norm(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::LayerNorm { eps: 1e-5 }, vec![x], None)
    }

    pub fn group_norm(&mut self, name: &str, x: NodeId, groups: usize) -> Result<NodeId> {
        self.push(name, OpKind::GroupNorm { groups, eps: 1e-5 }, vec![x], None)
    }

    pub fn softmax(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::Softmax, vec![x], None)
    }

    pub fn rope(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::Rope { theta: 10000.0 }, vec![x], None)
    }

    pub fn reshape(&mut self, name: &str, x: NodeId, out: Shape) -> Result<NodeId> {
        self.push(name, OpKind::Reshape { out }, vec![x], None)
    }

    pub fn transpose(&mut self, name: &str, x: NodeId, perm: [usize; 5]) -> Result<NodeId> {
        self.push(name, OpKind::Transpose { perm }, vec![x], None)
    }

    pub fn concat(&mut self, name: &str, inputs: Vec<NodeId>, axis: usize) -> Result<NodeId> {
        self.push(name, OpKind::Concat { axis }, inputs, None)
    }

    pub fn embedding(&mut self, name: &str, ids: NodeId, vocab: usize, dim: usize, wdtype: DType) -> Result<NodeId> {
        let weight = WeightInfo { shape: WeightShape::fc(vocab, dim), dtype: wdtype };
        self.push(name, OpKind::Embedding { vocab, dim }, vec![ids], Some(weight))
    }

    pub fn upsample2x(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::Upsample2x, vec![x], None)
    }

    pub fn avg_pool(&mut self, name: &str, x: NodeId, k: usize) -> Result<NodeId> {
        self.push(name, OpKind::AvgPool { k }, vec![x], None)
    }

    pub fn quant_act(&mut self, name: &str, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::QuantAct, vec![x], None)
    }

    pub fn fused_add_rms_norm(&mut self, name: &str, residual: NodeId, x: NodeId) -> Result<NodeId> {
        self.push(name, OpKind::FusedAddRmsNorm { eps: 1e-6 }, vec![residual, x], None)
    }

    pub fn fused_qkv_rope(
        &mut self,
        name: &str,
        qkv: NodeId,
        heads_q: usize,
        heads_kv: usize,
        head_dim: usize,
    ) -> Result<NodeId> {
        self.push(name, OpKind::FusedQkvRope { heads_q, heads_kv, head_dim }, vec![qkv], None)
    }

    /// Mark a node as a graph output.
    pub fn output(&mut self, id: NodeId) {
        if !self.outputs.contains(&id) {
            self.outputs.push(id);
        }
    }

    // ---- queries ----------------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Consumers of each node (adjacency reversed).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut cons = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                cons[i].push(n.id);
            }
        }
        cons
    }

    /// Count of compute nodes (kernel launches before fusion).
    pub fn compute_node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind.is_compute()).count()
    }

    /// Total weight bytes across the graph.
    pub fn weight_bytes(&self) -> usize {
        self.nodes.iter().filter_map(|n| n.weight.as_ref()).map(|w| w.bytes()).sum()
    }

    /// Validate DAG invariants: inputs precede nodes, outputs exist, and
    /// every non-input node has the right arity.
    pub fn validate(&self) -> Result<()> {
        for (idx, n) in self.nodes.iter().enumerate() {
            if n.id != idx {
                return Err(DriftError::Graph(format!("node {idx} has id {}", n.id)));
            }
            for &i in &n.inputs {
                if i >= idx {
                    return Err(DriftError::Graph(format!(
                        "node {} ({}) depends on later node {i}",
                        n.name, idx
                    )));
                }
            }
            let arity_ok = match &n.kind {
                OpKind::Input | OpKind::Const => n.inputs.is_empty(),
                OpKind::Binary(_) | OpKind::MatMul { .. } | OpKind::FusedAddRmsNorm { .. } => {
                    n.inputs.len() == 2
                }
                OpKind::Concat { .. } => n.inputs.len() >= 2,
                _ => n.inputs.len() == 1,
            };
            if !arity_ok {
                return Err(DriftError::Graph(format!(
                    "node {} ({}) has wrong arity {}",
                    n.name,
                    n.kind.name(),
                    n.inputs.len()
                )));
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(DriftError::Graph(format!("output {o} out of range")));
            }
        }
        if self.outputs.is_empty() {
            return Err(DriftError::Graph("graph has no outputs".into()));
        }
        Ok(())
    }

    /// One-line-per-node dump for debugging and the `plan` CLI command.
    pub fn dump(&self) -> String {
        let mut s = format!("graph {} ({} nodes, {} outputs)\n", self.name, self.nodes.len(), self.outputs.len());
        for n in &self.nodes {
            let ins: Vec<String> = n.inputs.iter().map(|i| i.to_string()).collect();
            let w = n
                .weight
                .as_ref()
                .map(|w| format!(" w={}x{}x{}x{} {}", w.shape.o, w.shape.h, w.shape.w, w.shape.i, w.dtype))
                .unwrap_or_default();
            let ep = if n.epilogue.is_empty() { String::new() } else { format!(" +{} epilogue", n.epilogue.len()) };
            s.push_str(&format!(
                "  [{:>3}] {:<24} {:<18} in=[{}] out={}{}{}\n",
                n.id,
                n.name,
                n.kind.name(),
                ins.join(","),
                n.shape,
                w,
                ep
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_small_mlp() {
        let mut g = Graph::new("mlp");
        let x = g.input("x", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let h = g.fully_connected("fc1", x, 256, DType::I8).unwrap();
        let h = g.unary("gelu", h, EwOp::Gelu).unwrap();
        let y = g.fully_connected("fc2", h, 64, DType::I8).unwrap();
        g.output(y);
        g.validate().unwrap();
        assert_eq!(g.node(y).shape, Shape::bhwc(1, 1, 8, 64));
        assert_eq!(g.compute_node_count(), 3);
        assert_eq!(g.weight_bytes(), 64 * 256 + 256 * 64);
    }

    #[test]
    fn rejects_missing_outputs() {
        let mut g = Graph::new("empty");
        g.input("x", Shape::linear(4), DType::F32);
        assert!(g.validate().is_err());
    }

    #[test]
    fn consumers_reversed_edges() {
        let mut g = Graph::new("g");
        let x = g.input("x", Shape::bhwc(1, 1, 4, 8), DType::F16);
        let a = g.unary("a", x, EwOp::Relu).unwrap();
        let b = g.unary("b", x, EwOp::Gelu).unwrap();
        let c = g.binary("c", a, b, BinOp::Add).unwrap();
        g.output(c);
        let cons = g.consumers();
        assert_eq!(cons[x], vec![a, b]);
        assert_eq!(cons[a], vec![c]);
        assert!(cons[c].is_empty());
    }

    #[test]
    fn dump_contains_nodes() {
        let mut g = Graph::new("d");
        let x = g.input("x", Shape::bhwc(1, 1, 4, 8), DType::F16);
        let y = g.softmax("sm", x).unwrap();
        g.output(y);
        let d = g.dump();
        assert!(d.contains("softmax"));
        assert!(d.contains("(1,1,4,8)"));
    }
}
