//! Per-channel symmetric int8/int4 quantization with real pack/unpack.
//!
//! These are the host-side reference implementations; the Pallas prefill
//! kernel (`python/compile/kernels/quant_matmul.py`) implements the same
//! per-row dynamic scheme and is validated against `ref.py`.

use crate::error::{DriftError, Result};

/// A quantized 2D weight matrix `(rows = output channels, cols = input
/// features)` with one scale per row.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub rows: usize,
    pub cols: usize,
    /// Packed payload: i8 per element, or two i4 per byte (col-major pairs
    /// within a row; even col in low nibble).
    pub data: Vec<u8>,
    /// Per-row scales.
    pub scales: Vec<f32>,
    /// Bits per element (8 or 4).
    pub bits: u8,
}

impl QuantizedTensor {
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn total_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Per-row symmetric int8: `scale = absmax/127`, `q = round(x/scale)`.
pub fn quantize_i8(rows: usize, cols: usize, w: &[f32]) -> Result<QuantizedTensor> {
    check_dims(rows, cols, w)?;
    let mut data = vec![0u8; rows * cols];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let absmax = row.iter().fold(0f32, |m, x| m.max(x.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        scales[r] = scale;
        for (c, x) in row.iter().enumerate() {
            let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
            data[r * cols + c] = q as u8;
        }
    }
    Ok(QuantizedTensor { rows, cols, data, scales, bits: 8 })
}

/// Dequantize an int8 tensor back to f32.
pub fn dequantize_i8(q: &QuantizedTensor) -> Vec<f32> {
    assert_eq!(q.bits, 8);
    let mut out = vec![0f32; q.rows * q.cols];
    for r in 0..q.rows {
        let scale = q.scales[r];
        for c in 0..q.cols {
            out[r * q.cols + c] = (q.data[r * q.cols + c] as i8) as f32 * scale;
        }
    }
    out
}

/// Per-row symmetric int4: `scale = absmax/7`, two values per byte
/// (even column in the low nibble).
pub fn quantize_i4(rows: usize, cols: usize, w: &[f32]) -> Result<QuantizedTensor> {
    check_dims(rows, cols, w)?;
    let packed_cols = cols.div_ceil(2);
    let mut data = vec![0u8; rows * packed_cols];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let absmax = row.iter().fold(0f32, |m, x| m.max(x.abs()));
        let scale = if absmax > 0.0 { absmax / 7.0 } else { 1.0 };
        scales[r] = scale;
        for c in 0..cols {
            let q = (row[c] / scale).round().clamp(-7.0, 7.0) as i8;
            let nibble = (q as u8) & 0x0F;
            let byte = &mut data[r * packed_cols + c / 2];
            if c % 2 == 0 {
                *byte = (*byte & 0xF0) | nibble;
            } else {
                *byte = (*byte & 0x0F) | (nibble << 4);
            }
        }
    }
    Ok(QuantizedTensor { rows, cols, data, scales, bits: 4 })
}

/// Sign-extend a 4-bit nibble.
fn nibble_to_i8(n: u8) -> i8 {
    let n = n & 0x0F;
    if n & 0x08 != 0 {
        (n | 0xF0) as i8
    } else {
        n as i8
    }
}

/// Dequantize an int4 tensor back to f32.
pub fn dequantize_i4(q: &QuantizedTensor) -> Vec<f32> {
    assert_eq!(q.bits, 4);
    let packed_cols = q.cols.div_ceil(2);
    let mut out = vec![0f32; q.rows * q.cols];
    for r in 0..q.rows {
        let scale = q.scales[r];
        for c in 0..q.cols {
            let byte = q.data[r * packed_cols + c / 2];
            let nib = if c % 2 == 0 { byte } else { byte >> 4 };
            out[r * q.cols + c] = nibble_to_i8(nib) as f32 * scale;
        }
    }
    out
}

/// Dynamic per-row activation quantization (the §3.7 prefill kernel's
/// algorithm): returns (int8 payload, per-row scales).
pub fn quantize_activations(rows: usize, cols: usize, x: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
    check_dims(rows, cols, x)?;
    let mut q = vec![0i8; rows * cols];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let absmax = row.iter().fold(0f32, |m, v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        scales[r] = scale;
        for (c, v) in row.iter().enumerate() {
            q[r * cols + c] = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    Ok((q, scales))
}

/// Int8 GEMM with dequantized output — the reference semantics of the
/// prefill path: `y[m,o] = sum_k a_q[m,k]·w_q[o,k] · a_scale[m]·w_scale[o]`.
pub fn int8_matmul_reference(
    m: usize,
    k: usize,
    o: usize,
    a_q: &[i8],
    a_scales: &[f32],
    w: &QuantizedTensor,
) -> Vec<f32> {
    assert_eq!(w.bits, 8);
    assert_eq!((w.rows, w.cols), (o, k));
    let mut y = vec![0f32; m * o];
    for mi in 0..m {
        for oi in 0..o {
            let mut acc = 0i32;
            for ki in 0..k {
                acc += a_q[mi * k + ki] as i32 * (w.data[oi * k + ki] as i8) as i32;
            }
            y[mi * o + oi] = acc as f32 * a_scales[mi] * w.scales[oi];
        }
    }
    y
}

fn check_dims(rows: usize, cols: usize, w: &[f32]) -> Result<()> {
    if w.len() != rows * cols {
        return Err(DriftError::Quant(format!(
            "expected {rows}×{cols} = {} values, got {}",
            rows * cols,
            w.len()
        )));
    }
    Ok(())
}

/// Max relative error of a quantization round-trip (quality metric).
pub fn roundtrip_rel_error(orig: &[f32], deq: &[f32]) -> f32 {
    let norm = orig.iter().fold(0f32, |m, x| m.max(x.abs())).max(1e-12);
    orig.iter()
        .zip(deq)
        .map(|(a, b)| (a - b).abs() / norm)
        .fold(0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};
    use crate::util::rng::Pcg32;

    fn random_matrix(rng: &mut Pcg32, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| (rng.gen_f32() * 2.0 - 1.0) * 3.0).collect()
    }

    #[test]
    fn i8_roundtrip_within_tolerance() {
        let mut rng = Pcg32::seeded(1);
        let w = random_matrix(&mut rng, 16, 64);
        let q = quantize_i8(16, 64, &w).unwrap();
        let d = dequantize_i8(&q);
        // Symmetric int8: error ≤ scale/2 ≈ absmax/254 per element.
        assert!(roundtrip_rel_error(&w, &d) <= 1.0 / 254.0 + 1e-6);
    }

    #[test]
    fn i4_roundtrip_within_tolerance() {
        let mut rng = Pcg32::seeded(2);
        let w = random_matrix(&mut rng, 8, 33); // odd cols exercise packing
        let q = quantize_i4(8, 33, &w).unwrap();
        assert_eq!(q.data.len(), 8 * 17);
        let d = dequantize_i4(&q);
        assert!(roundtrip_rel_error(&w, &d) <= 1.0 / 14.0 + 1e-6);
    }

    #[test]
    fn i4_payload_is_half_of_i8() {
        let mut rng = Pcg32::seeded(3);
        let w = random_matrix(&mut rng, 32, 128);
        let q8 = quantize_i8(32, 128, &w).unwrap();
        let q4 = quantize_i4(32, 128, &w).unwrap();
        assert_eq!(q4.payload_bytes() * 2, q8.payload_bytes());
    }

    #[test]
    fn nibble_sign_extension() {
        assert_eq!(nibble_to_i8(0x0), 0);
        assert_eq!(nibble_to_i8(0x7), 7);
        assert_eq!(nibble_to_i8(0x8), -8);
        assert_eq!(nibble_to_i8(0xF), -1);
        assert_eq!(nibble_to_i8(0x9), -7);
    }

    #[test]
    fn int8_matmul_close_to_float() {
        let mut rng = Pcg32::seeded(4);
        let (m, k, o) = (4, 64, 8);
        let a = random_matrix(&mut rng, m, k);
        let w = random_matrix(&mut rng, o, k);
        // Float reference.
        let mut y_ref = vec![0f32; m * o];
        for mi in 0..m {
            for oi in 0..o {
                y_ref[mi * o + oi] =
                    (0..k).map(|ki| a[mi * k + ki] * w[oi * k + ki]).sum::<f32>();
            }
        }
        let (aq, ascales) = quantize_activations(m, k, &a).unwrap();
        let wq = quantize_i8(o, k, &w).unwrap();
        let y = int8_matmul_reference(m, k, o, &aq, &ascales, &wq);
        // Error budget: per-term quant noise ~N(0, σ²) with σ ≈ 0.017 for
        // this data scale accumulates to ~0.13·√(k/64); allow 5σ.
        for (got, want) in y.iter().zip(&y_ref) {
            assert!((got - want).abs() < 0.7, "int8 matmul too far: {got} vs {want}");
        }
    }

    #[test]
    fn property_roundtrips_bounded() {
        check("quant roundtrip error bounded", Config::cases(40), |rng| {
            let rows = 1 + rng.gen_range(12) as usize;
            let cols = 1 + rng.gen_range(100) as usize;
            let w = random_matrix(rng, rows, cols);
            let q8 = quantize_i8(rows, cols, &w).map_err(|e| e.to_string())?;
            let e8 = roundtrip_rel_error(&w, &dequantize_i8(&q8));
            if e8 > 1.0 / 200.0 {
                return Err(format!("i8 error {e8}"));
            }
            let q4 = quantize_i4(rows, cols, &w).map_err(|e| e.to_string())?;
            let e4 = roundtrip_rel_error(&w, &dequantize_i4(&q4));
            if e4 > 1.0 / 12.0 {
                return Err(format!("i4 error {e4}"));
            }
            Ok(())
        });
    }
}
