//! GGUF `q4_0` group quantization — the format llama.cpp-family baselines
//! use (paper §4.2: "other open-source solutions often utilize GGUF q4
//! group quantization, which produces a model size that falls between
//! those resulting from ML Drift's q8 and 8/4/4 methods").

use crate::error::{DriftError, Result};

/// One q4_0 block: 32 weights, fp16 scale, 4-bit payload, 18 bytes total.
#[derive(Clone, Debug, PartialEq)]
pub struct Q4_0Block {
    /// Scale stored as f32 here (fp16 on disk; 2 bytes counted in sizes).
    pub scale: f32,
    /// 32 4-bit values packed into 16 bytes (llama.cpp order: element i
    /// low nibble of byte i, element i+16 high nibble of byte i).
    pub packed: [u8; 16],
}

pub const Q4_0_GROUP: usize = 32;
/// Bytes per block on disk: 2 (fp16 scale) + 16 (payload).
pub const Q4_0_BLOCK_BYTES: usize = 18;

/// Quantize a flat weight slice into q4_0 blocks (length must be a
/// multiple of 32, as in GGUF).
pub fn quantize_q4_0(w: &[f32]) -> Result<Vec<Q4_0Block>> {
    if w.len() % Q4_0_GROUP != 0 {
        return Err(DriftError::Quant(format!(
            "q4_0 needs length divisible by {Q4_0_GROUP}, got {}",
            w.len()
        )));
    }
    let mut blocks = Vec::with_capacity(w.len() / Q4_0_GROUP);
    for chunk in w.chunks_exact(Q4_0_GROUP) {
        let absmax = chunk.iter().fold(0f32, |m, x| m.max(x.abs()));
        // q4_0: values mapped to [-8, 7] around zero with scale absmax/8.
        let scale = if absmax > 0.0 { absmax / 8.0 } else { 1.0 };
        let mut packed = [0u8; 16];
        for (i, x) in chunk.iter().enumerate() {
            let q = ((x / scale).round().clamp(-8.0, 7.0) as i8 + 8) as u8; // bias to [0,15]
            if i < 16 {
                packed[i] = (packed[i] & 0xF0) | (q & 0x0F);
            } else {
                packed[i - 16] = (packed[i - 16] & 0x0F) | ((q & 0x0F) << 4);
            }
        }
        blocks.push(Q4_0Block { scale, packed });
    }
    Ok(blocks)
}

/// Dequantize q4_0 blocks back to f32.
pub fn dequantize_q4_0(blocks: &[Q4_0Block]) -> Vec<f32> {
    let mut out = Vec::with_capacity(blocks.len() * Q4_0_GROUP);
    for b in blocks {
        for i in 0..Q4_0_GROUP {
            let nib = if i < 16 { b.packed[i] & 0x0F } else { b.packed[i - 16] >> 4 };
            out.push((nib as i8 - 8) as f32 * b.scale);
        }
    }
    out
}

/// On-disk bytes for `n` weights in q4_0 (4.5 bits/weight).
pub fn gguf_q4_0_bytes(n: usize) -> usize {
    n.div_ceil(Q4_0_GROUP) * Q4_0_BLOCK_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Pcg32::seeded(7);
        let w: Vec<f32> = (0..256).map(|_| rng.gen_f32() * 4.0 - 2.0).collect();
        let blocks = quantize_q4_0(&w).unwrap();
        assert_eq!(blocks.len(), 8);
        let d = dequantize_q4_0(&blocks);
        assert_eq!(d.len(), w.len());
        let absmax = w.iter().fold(0f32, |m, x| m.max(x.abs()));
        for (a, b) in w.iter().zip(&d) {
            assert!((a - b).abs() <= absmax / 8.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn non_multiple_rejected() {
        assert!(quantize_q4_0(&[0.0; 33]).is_err());
    }

    #[test]
    fn size_is_4_5_bits_per_weight() {
        let bytes = gguf_q4_0_bytes(1_000_000_032);
        let bits_per_weight = bytes as f64 * 8.0 / 1_000_000_032.0;
        assert!((bits_per_weight - 4.5).abs() < 0.01, "{bits_per_weight}");
    }

    #[test]
    fn sizes_sit_between_q8_and_844() {
        // For an FFN-heavy 1M-weight tensor.
        let n = 1_000_000 / 32 * 32;
        let q8 = n; // 1 byte each
        let m844 = n / 2; // int4
        let gguf = gguf_q4_0_bytes(n);
        assert!(m844 < gguf && gguf < q8, "{m844} < {gguf} < {q8}");
    }
}
