//! Weight quantization (paper §4.2).
//!
//! ML Drift implements two schemes:
//!
//! * **q8** — per-(output-)channel symmetric int8 for *all* weights.
//! * **8/4/4** — mixed precision: int8 for attention weights, int4 for
//!   embedding and feed-forward weights (per-channel, symmetric).
//!
//! Baseline engines use **GGUF q4_0** group quantization (32-element
//! groups, fp16 scale per group) whose model size lands between q8 and
//! 8/4/4 — exactly the paper's observation.
//!
//! Activation quantization for the prefill path (dynamic per-row absmax
//! int8, §3.7) lives here too; the Pallas kernel implements the same
//! algorithm on-device and is tested against it.

pub mod schemes;
pub mod pack;
pub mod gguf;

pub use pack::{
    dequantize_i4, dequantize_i8, int8_matmul_reference, quantize_activations, quantize_i4,
    quantize_i8, QuantizedTensor,
};
pub use schemes::{effective_bits, scheme_dtype_for, QuantScheme, WeightClass};
pub use gguf::{dequantize_q4_0, gguf_q4_0_bytes, quantize_q4_0, Q4_0Block};
