//! Quantization scheme definitions and weight-class assignment.

use crate::tensor::DType;

/// Which functional class a weight tensor belongs to (drives the mixed
/// 8/4/4 assignment).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightClass {
    /// Q/K/V/O projections.
    Attention,
    /// Gate/up/down feed-forward weights.
    FeedForward,
    /// Token embedding / LM head.
    Embedding,
    /// Convolutions, norms' scales, everything else.
    Other,
}

/// A weight quantization scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantScheme {
    /// FP16 weights (diffusion pipeline default).
    F16,
    /// Per-channel int8 everywhere.
    Q8,
    /// Mixed: int8 attention, int4 embedding + feed-forward (paper 8/4/4).
    Mixed844,
    /// GGUF q4_0 group quantization (baseline engines).
    GgufQ4_0,
}

impl QuantScheme {
    pub fn name(self) -> &'static str {
        match self {
            QuantScheme::F16 => "f16",
            QuantScheme::Q8 => "q8",
            QuantScheme::Mixed844 => "8/4/4",
            QuantScheme::GgufQ4_0 => "gguf-q4_0",
        }
    }

    /// Parse from CLI spelling.
    pub fn parse(s: &str) -> Option<QuantScheme> {
        match s {
            "f16" | "fp16" => Some(QuantScheme::F16),
            "q8" => Some(QuantScheme::Q8),
            "8/4/4" | "844" | "mixed" => Some(QuantScheme::Mixed844),
            "q4" | "gguf" | "q4_0" => Some(QuantScheme::GgufQ4_0),
            _ => None,
        }
    }
}

/// Storage dtype for a weight of `class` under `scheme`.
pub fn scheme_dtype_for(scheme: QuantScheme, class: WeightClass) -> DType {
    match (scheme, class) {
        (QuantScheme::F16, _) => DType::F16,
        (QuantScheme::Q8, _) => DType::I8,
        (QuantScheme::Mixed844, WeightClass::Attention) => DType::I8,
        (QuantScheme::Mixed844, WeightClass::FeedForward | WeightClass::Embedding) => DType::I4,
        (QuantScheme::Mixed844, WeightClass::Other) => DType::I8,
        // GGUF q4_0: 4-bit payload + fp16 scale per 32 → effective
        // 4.5 bits/weight; we model storage as I4 and add the scale
        // overhead in `gguf::gguf_q4_0_bytes`.
        (QuantScheme::GgufQ4_0, WeightClass::Embedding) => DType::I8, // GGUF keeps embeddings ~q8
        (QuantScheme::GgufQ4_0, _) => DType::I4,
    }
}

/// Effective bits per weight including scale overheads (for size reports).
pub fn effective_bits(scheme: QuantScheme, class: WeightClass) -> f64 {
    match scheme_dtype_for(scheme, class) {
        DType::F16 => 16.0,
        DType::I8 => 8.0 + 0.01, // one fp16 scale per output channel: negligible
        DType::I4 if scheme == QuantScheme::GgufQ4_0 => 4.5, // fp16 scale / 32 weights
        DType::I4 => 4.0 + 0.01,
        d => d.bits() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_assignment_matches_paper() {
        assert_eq!(scheme_dtype_for(QuantScheme::Mixed844, WeightClass::Attention), DType::I8);
        assert_eq!(scheme_dtype_for(QuantScheme::Mixed844, WeightClass::FeedForward), DType::I4);
        assert_eq!(scheme_dtype_for(QuantScheme::Mixed844, WeightClass::Embedding), DType::I4);
    }

    #[test]
    fn gguf_sits_between_q8_and_844() {
        // Paper §4.2: GGUF q4 model size falls between ML Drift q8 and 8/4/4.
        // For a FFN-dominated model: q8 = 8 bits, 8/4/4 ≈ 4 bits, gguf = 4.5.
        let q8 = effective_bits(QuantScheme::Q8, WeightClass::FeedForward);
        let m = effective_bits(QuantScheme::Mixed844, WeightClass::FeedForward);
        let g = effective_bits(QuantScheme::GgufQ4_0, WeightClass::FeedForward);
        assert!(m < g && g < q8, "{m} < {g} < {q8}");
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(QuantScheme::parse("8/4/4"), Some(QuantScheme::Mixed844));
        assert_eq!(QuantScheme::parse("q8"), Some(QuantScheme::Q8));
        assert_eq!(QuantScheme::parse("nope"), None);
    }
}
