//! Unified error type for the mldrift crate.

use std::fmt;

use crate::runtime::xla;

/// Errors produced by the ML Drift compiler, simulator, and runtime.
#[derive(Debug)]
pub enum DriftError {
    /// Shape inference or shape compatibility failure.
    Shape(String),
    /// Invalid or unsupported layout request.
    Layout(String),
    /// Graph construction / validation failure (cycles, dangling refs …).
    Graph(String),
    /// Memory planning failure.
    Memory(String),
    /// Code generation failure.
    Codegen(String),
    /// Device capability mismatch (e.g. texture width exceeded).
    Device(String),
    /// Model would not fit in device memory (paper Table 2 OOM entries).
    OutOfMemory { required_bytes: u64, budget_bytes: u64 },
    /// Quantization error.
    Quant(String),
    /// PJRT runtime error (wraps the `xla` crate error).
    Runtime(String),
    /// Serving-layer error (queue closed, bad request …).
    Serving(String),
    /// Configuration / CLI / JSON parse error.
    Config(String),
    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for DriftError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DriftError::Shape(m) => write!(f, "shape error: {m}"),
            DriftError::Layout(m) => write!(f, "layout error: {m}"),
            DriftError::Graph(m) => write!(f, "graph error: {m}"),
            DriftError::Memory(m) => write!(f, "memory planning error: {m}"),
            DriftError::Codegen(m) => write!(f, "codegen error: {m}"),
            DriftError::Device(m) => write!(f, "device error: {m}"),
            DriftError::OutOfMemory { required_bytes, budget_bytes } => write!(
                f,
                "out of device memory: required {:.2} GB > budget {:.2} GB",
                *required_bytes as f64 / 1e9,
                *budget_bytes as f64 / 1e9
            ),
            DriftError::Quant(m) => write!(f, "quantization error: {m}"),
            DriftError::Runtime(m) => write!(f, "runtime error: {m}"),
            DriftError::Serving(m) => write!(f, "serving error: {m}"),
            DriftError::Config(m) => write!(f, "config error: {m}"),
            DriftError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for DriftError {}

impl From<std::io::Error> for DriftError {
    fn from(e: std::io::Error) -> Self {
        DriftError::Io(e)
    }
}

impl From<xla::Error> for DriftError {
    fn from(e: xla::Error) -> Self {
        DriftError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DriftError>;
