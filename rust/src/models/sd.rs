//! Stable Diffusion 1.4 component graphs at real dimensions.
//!
//! The pipeline (paper §4.1): CLIP ViT-L/14 text encoder → UNet (×20
//! denoising iterations) → VAE decoder, FP16 weights and activations.
//! These graphs drive the Fig. 3 memory experiment, the Fig. 5 per-
//! component latency experiment, and Table 3.

use crate::error::Result;
use crate::graph::{BinOp, EwOp, Graph, NodeId};
use crate::tensor::{DType, Shape};

/// Identifies one component of the SD pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SdComponent {
    TextEncoder,
    Unet,
    VaeDecoder,
}

impl SdComponent {
    pub fn name(self) -> &'static str {
        match self {
            SdComponent::TextEncoder => "text_encoder",
            SdComponent::Unet => "unet",
            SdComponent::VaeDecoder => "vae_decoder",
        }
    }
}

const W_DT: DType = DType::F16;

/// CLIP ViT-L/14 text encoder: 12 layers, d=768, 12 heads, seq 77.
pub fn sd_text_encoder() -> Result<Graph> {
    let mut g = Graph::new("sd14_text_encoder");
    let (layers, d, heads, seq, vocab) = (12, 768, 12, 77, 49408);
    let dh = d / heads;
    let tokens = g.input("tokens", Shape::bhwc(1, 1, seq, 1), DType::I32);
    let mut x = g.embedding("embed", tokens, vocab, d, W_DT)?;
    for l in 0..layers {
        let p = |n: &str| format!("l{l}_{n}");
        let normed = g.layer_norm(&p("ln1"), x)?;
        let q = g.fully_connected(&p("wq"), normed, d, W_DT)?;
        let k = g.fully_connected(&p("wk"), normed, d, W_DT)?;
        let v = g.fully_connected(&p("wv"), normed, d, W_DT)?;
        let q_r = g.reshape(&p("q_fold"), q, Shape::bhwc(heads, 1, seq, dh))?;
        let k_r = g.reshape(&p("k_fold"), k, Shape::bhwc(heads, 1, seq, dh))?;
        let v_r = g.reshape(&p("v_fold"), v, Shape::bhwc(heads, 1, seq, dh))?;
        let scores = g.matmul(&p("scores"), q_r, k_r, true)?;
        let scaled = g.unary(&p("scale"), scores, EwOp::Scale(1.0 / (dh as f32).sqrt()))?;
        let probs = g.softmax(&p("probs"), scaled)?;
        let ctx = g.matmul(&p("ctx"), probs, v_r, false)?;
        let ctx_r = g.reshape(&p("ctx_unfold"), ctx, Shape::bhwc(1, 1, seq, d))?;
        let o = g.fully_connected(&p("wo"), ctx_r, d, W_DT)?;
        let x1 = g.binary(&p("res1"), x, o, BinOp::Add)?;
        let normed2 = g.layer_norm(&p("ln2"), x1)?;
        let h = g.fully_connected(&p("fc1"), normed2, 4 * d, W_DT)?;
        let h = g.unary(&p("gelu"), h, EwOp::Gelu)?;
        let h = g.fully_connected(&p("fc2"), h, d, W_DT)?;
        x = g.binary(&p("res2"), x1, h, BinOp::Add)?;
    }
    let out = g.layer_norm("final_ln", x)?;
    g.output(out);
    g.validate()?;
    Ok(g)
}

/// One UNet ResNet block: GN → SiLU → conv3×3 (+time-emb add) → GN → SiLU
/// → conv3×3, with a 1×1 skip conv when channels change.
fn res_block(
    g: &mut Graph,
    prefix: &str,
    x: NodeId,
    out_c: usize,
    temb: NodeId,
) -> Result<NodeId> {
    let in_c = g.node(x).shape.c;
    let p = |n: &str| format!("{prefix}_{n}");
    let h = g.group_norm(&p("gn1"), x, 32)?;
    let h = g.unary(&p("silu1"), h, EwOp::Silu)?;
    let h = g.conv2d(&p("conv1"), h, out_c, 3, 1, 1, W_DT)?;
    // Time embedding projected and broadcast-added: modeled as an FC to
    // out_c followed by a fused add (the broadcast is free in the kernel).
    let t = g.fully_connected(&p("temb_proj"), temb, out_c, W_DT)?;
    let spatial = g.node(h).shape;
    let t_b = g.reshape(&p("temb_cast"), t, Shape::bhwc(1, 1, 1, out_c))?;
    // Broadcast add modeled as elementwise epilogue on conv1: we emulate by
    // a binary add against an upsampled constant-shaped tensor. To keep
    // shapes exact we tile via reshape to (1,1,1,out_c) and rely on the
    // kernel's broadcast; the graph-level shape check requires equality, so
    // we expand through an explicit broadcast-concat-free path: a Const of
    // the spatial shape (zero flops, counted as a read).
    let t_full = g.constant(&p("temb_b"), spatial, DType::F16);
    let _ = t_b;
    let h = g.binary(&p("temb_add"), h, t_full, BinOp::Add)?;
    let h = g.group_norm(&p("gn2"), h, 32)?;
    let h = g.unary(&p("silu2"), h, EwOp::Silu)?;
    let h = g.conv2d(&p("conv2"), h, out_c, 3, 1, 1, W_DT)?;
    let skip = if in_c != out_c {
        g.conv2d(&p("skip"), x, out_c, 1, 1, 0, W_DT)?
    } else {
        x
    };
    g.binary(&p("res_add"), skip, h, BinOp::Add)
}

/// One transformer block over spatial tokens (self-attn + cross-attn to
/// the 77-token text context + GeGLU feed-forward).
fn spatial_transformer(
    g: &mut Graph,
    prefix: &str,
    x: NodeId,
    context: NodeId,
) -> Result<NodeId> {
    let s = g.node(x).shape;
    let (h_sp, w_sp, c) = (s.h, s.w, s.c);
    let tokens = h_sp * w_sp;
    let p = |n: &str| format!("{prefix}_{n}");
    let normed = g.group_norm(&p("gn"), x, 32)?;
    let proj_in = g.conv2d(&p("proj_in"), normed, c, 1, 1, 0, W_DT)?;
    let seq = g.reshape(&p("to_seq"), proj_in, Shape::bhwc(1, 1, tokens, c))?;

    // Self-attention (single folded head batch to bound node count: the
    // FLOP/byte totals match the multi-head computation exactly).
    let ln1 = g.layer_norm(&p("ln1"), seq)?;
    let q = g.fully_connected(&p("sa_q"), ln1, c, W_DT)?;
    let k = g.fully_connected(&p("sa_k"), ln1, c, W_DT)?;
    let v = g.fully_connected(&p("sa_v"), ln1, c, W_DT)?;
    let scores = g.matmul(&p("sa_scores"), q, k, true)?;
    let scaled = g.unary(&p("sa_scale"), scores, EwOp::Scale(1.0 / (c as f32).sqrt()))?;
    let probs = g.softmax(&p("sa_probs"), scaled)?;
    let ctx = g.matmul(&p("sa_ctx"), probs, v, false)?;
    let sa_o = g.fully_connected(&p("sa_o"), ctx, c, W_DT)?;
    let x1 = g.binary(&p("sa_res"), seq, sa_o, BinOp::Add)?;

    // Cross-attention against the text context (77 × 768).
    let ln2 = g.layer_norm(&p("ln2"), x1)?;
    let q = g.fully_connected(&p("ca_q"), ln2, c, W_DT)?;
    let k = g.fully_connected(&p("ca_k"), context, c, W_DT)?;
    let v = g.fully_connected(&p("ca_v"), context, c, W_DT)?;
    let scores = g.matmul(&p("ca_scores"), q, k, true)?;
    let scaled = g.unary(&p("ca_scale"), scores, EwOp::Scale(1.0 / (c as f32).sqrt()))?;
    let probs = g.softmax(&p("ca_probs"), scaled)?;
    let ctx2 = g.matmul(&p("ca_ctx"), probs, v, false)?;
    let ca_o = g.fully_connected(&p("ca_o"), ctx2, c, W_DT)?;
    let x2 = g.binary(&p("ca_res"), x1, ca_o, BinOp::Add)?;

    // GeGLU feed-forward.
    let ln3 = g.layer_norm(&p("ln3"), x2)?;
    let gate = g.fully_connected(&p("ff_gate"), ln3, 4 * c, W_DT)?;
    let gate = g.unary(&p("ff_gelu"), gate, EwOp::Gelu)?;
    let up = g.fully_connected(&p("ff_up"), ln3, 4 * c, W_DT)?;
    let prod = g.binary(&p("ff_mul"), up, gate, BinOp::Mul)?;
    let down = g.fully_connected(&p("ff_down"), prod, c, W_DT)?;
    let x3 = g.binary(&p("ff_res"), x2, down, BinOp::Add)?;

    let back = g.reshape(&p("to_spatial"), x3, Shape::bhwc(1, h_sp, w_sp, c))?;
    let proj_out = g.conv2d(&p("proj_out"), back, c, 1, 1, 0, W_DT)?;
    g.binary(&p("st_res"), x, proj_out, BinOp::Add)
}

/// SD 1.4 UNet (single denoising step): 64×64×4 latent, channel ladder
/// (320, 640, 1280, 1280), attention at the top three resolutions.
pub fn sd_unet() -> Result<Graph> {
    let mut g = Graph::new("sd14_unet");
    let latent = g.input("latent", Shape::bhwc(1, 64, 64, 4), DType::F16);
    let temb = g.input("time_embed", Shape::bhwc(1, 1, 1, 1280), DType::F16);
    let context = g.input("text_context", Shape::bhwc(1, 1, 77, 768), DType::F16);

    let chans = [320usize, 640, 1280, 1280];
    let mut x = g.conv2d("conv_in", latent, chans[0], 3, 1, 1, W_DT)?;
    let mut skips: Vec<NodeId> = vec![x];

    // Down path: 2 res blocks per level (+ attention at levels 0–2),
    // downsample between levels.
    for (lvl, &c) in chans.iter().enumerate() {
        for b in 0..2 {
            x = res_block(&mut g, &format!("down{lvl}_res{b}"), x, c, temb)?;
            if lvl < 3 {
                x = spatial_transformer(&mut g, &format!("down{lvl}_attn{b}"), x, context)?;
            }
            skips.push(x);
        }
        if lvl < 3 {
            x = g.conv2d(&format!("down{lvl}_ds"), x, c, 3, 2, 1, W_DT)?;
            skips.push(x);
        }
    }

    // Middle: res + attn + res.
    x = res_block(&mut g, "mid_res0", x, chans[3], temb)?;
    x = spatial_transformer(&mut g, "mid_attn", x, context)?;
    x = res_block(&mut g, "mid_res1", x, chans[3], temb)?;

    // Up path: 3 res blocks per level with skip concats, upsample.
    for (lvl, &c) in chans.iter().enumerate().rev() {
        for b in 0..3 {
            let skip = skips.pop().expect("skip available");
            let cat = g.concat(&format!("up{lvl}_cat{b}"), vec![x, skip], 4)?;
            x = res_block(&mut g, &format!("up{lvl}_res{b}"), cat, c, temb)?;
            if lvl > 0 {
                x = spatial_transformer(&mut g, &format!("up{lvl}_attn{b}"), x, context)?;
            }
        }
        if lvl > 0 {
            x = g.upsample2x(&format!("up{lvl}_us"), x)?;
            x = g.conv2d(&format!("up{lvl}_usconv"), x, c, 3, 1, 1, W_DT)?;
        }
    }

    let out = g.group_norm("out_gn", x, 32)?;
    let out = g.unary("out_silu", out, EwOp::Silu)?;
    let out = g.conv2d("conv_out", out, 4, 3, 1, 1, W_DT)?;
    g.output(out);
    g.validate()?;
    Ok(g)
}

/// VAE decoder: 64×64×4 latent → 512×512×3 image.
pub fn sd_vae_decoder() -> Result<Graph> {
    let mut g = Graph::new("sd14_vae_decoder");
    let latent = g.input("latent", Shape::bhwc(1, 64, 64, 4), DType::F16);
    let temb = g.constant("no_temb", Shape::bhwc(1, 1, 1, 1280), DType::F16); // unused projection source
    let mut x = g.conv2d("conv_in", latent, 512, 3, 1, 1, W_DT)?;

    // Mid: res + self-attn + res at 64×64×512.
    x = res_block(&mut g, "mid_res0", x, 512, temb)?;
    {
        // VAE self-attention block (single head over 4096 tokens).
        let s = g.node(x).shape;
        let tokens = s.h * s.w;
        let normed = g.group_norm("mid_attn_gn", x, 32)?;
        let seq = g.reshape("mid_attn_seq", normed, Shape::bhwc(1, 1, tokens, s.c))?;
        let q = g.fully_connected("mid_attn_q", seq, s.c, W_DT)?;
        let k = g.fully_connected("mid_attn_k", seq, s.c, W_DT)?;
        let v = g.fully_connected("mid_attn_v", seq, s.c, W_DT)?;
        let scores = g.matmul("mid_attn_scores", q, k, true)?;
        let probs = g.softmax("mid_attn_probs", scores)?;
        let ctx = g.matmul("mid_attn_ctx", probs, v, false)?;
        let o = g.fully_connected("mid_attn_o", ctx, s.c, W_DT)?;
        let back = g.reshape("mid_attn_back", o, s)?;
        x = g.binary("mid_attn_res", x, back, BinOp::Add)?;
    }
    x = res_block(&mut g, "mid_res1", x, 512, temb)?;

    // Up ladder: (512, 512, 256, 128) with 3 res blocks + upsample each
    // (final level no upsample). 64→128→256→512.
    let ladder = [512usize, 512, 256, 128];
    for (lvl, &c) in ladder.iter().enumerate() {
        for b in 0..3 {
            x = res_block(&mut g, &format!("up{lvl}_res{b}"), x, c, temb)?;
        }
        if lvl < 3 {
            x = g.upsample2x(&format!("up{lvl}_us"), x)?;
            x = g.conv2d(&format!("up{lvl}_usconv"), x, c, 3, 1, 1, W_DT)?;
        }
    }

    let out = g.group_norm("out_gn", x, 32)?;
    let out = g.unary("out_silu", out, EwOp::Silu)?;
    let out = g.conv2d("conv_out", out, 3, 3, 1, 1, W_DT)?;
    g.output(out);
    g.validate()?;
    Ok(g)
}

/// Build a component graph by id.
pub fn sd_component(c: SdComponent) -> Result<Graph> {
    match c {
        SdComponent::TextEncoder => sd_text_encoder(),
        SdComponent::Unet => sd_unet(),
        SdComponent::VaeDecoder => sd_vae_decoder(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{lifetimes, naive_bytes};
    use crate::tensor::DType;

    #[test]
    fn text_encoder_builds() {
        let g = sd_text_encoder().unwrap();
        let out = g.node(g.outputs[0]);
        assert_eq!(out.shape, Shape::bhwc(1, 1, 77, 768));
        // ~123M params × 2 bytes ≈ 246 MB.
        let mb = g.weight_bytes() as f64 / 1e6;
        assert!(mb > 150.0 && mb < 350.0, "text encoder weights {mb} MB");
    }

    #[test]
    fn unet_builds_with_right_output() {
        let g = sd_unet().unwrap();
        let out = g.node(g.outputs[0]);
        assert_eq!(out.shape, Shape::bhwc(1, 64, 64, 4));
        // SD 1.4 UNet ≈ 860M params ≈ 1.7 GB fp16 (within 2×: the model
        // here simplifies head splits but keeps all matmul volumes).
        let gb = g.weight_bytes() as f64 / 1e9;
        assert!(gb > 1.0 && gb < 2.6, "unet weights {gb} GB");
    }

    #[test]
    fn vae_decoder_builds_to_512() {
        let g = sd_vae_decoder().unwrap();
        let out = g.node(g.outputs[0]);
        assert_eq!(out.shape, Shape::bhwc(1, 512, 512, 3));
    }

    #[test]
    fn naive_memory_magnitudes_match_fig3() {
        // Fig. 3 naive footprints: text 62 MB, UNet 2075 MB, VAE 2274 MB.
        // Our graphs should land in the same decade (±2×).
        let mb = |g: &crate::graph::Graph| {
            naive_bytes(&lifetimes(g, DType::F16)) as f64 / 1e6
        };
        let te = mb(&sd_text_encoder().unwrap());
        assert!(te > 20.0 && te < 160.0, "text encoder naive {te} MB (paper 62)");
        let vae = mb(&sd_vae_decoder().unwrap());
        assert!(vae > 1100.0 && vae < 4500.0, "vae naive {vae} MB (paper 2274)");
        let unet = mb(&sd_unet().unwrap());
        assert!(unet > 1000.0 && unet < 4200.0, "unet naive {unet} MB (paper 2075)");
    }
}
