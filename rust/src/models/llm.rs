//! LLM architecture configs and the transformer graph builder.

use crate::error::Result;
use crate::graph::{BinOp, EwOp, Graph};
use crate::quant::{scheme_dtype_for, QuantScheme, WeightClass};
use crate::tensor::{DType, Shape};

/// Transformer architecture description (decoder-only).
#[derive(Clone, Copy, Debug)]
pub struct LlmConfig {
    pub name: &'static str,
    pub layers: usize,
    pub d_model: usize,
    pub heads_q: usize,
    pub heads_kv: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    /// Gated FFN (SiLU/GeLU-gated: 3 matrices) vs plain 2-matrix MLP.
    pub gated_ffn: bool,
    /// Gate activation.
    pub act: EwOp,
    /// LM head shares the embedding matrix.
    pub tied_embeddings: bool,
}

impl LlmConfig {
    /// Parameter count (weights only, no biases — these models are
    /// bias-free).
    pub fn params(&self) -> usize {
        let embed = self.vocab * self.d_model;
        let qkv = self.d_model * (self.heads_q + 2 * self.heads_kv) * self.head_dim;
        let o = self.heads_q * self.head_dim * self.d_model;
        let ffn = if self.gated_ffn {
            3 * self.d_model * self.ffn_hidden
        } else {
            2 * self.d_model * self.ffn_hidden
        };
        let norms = 2 * self.d_model;
        let lm_head = if self.tied_embeddings { 0 } else { embed };
        embed + self.layers * (qkv + o + ffn + norms) + self.d_model + lm_head
    }

    /// Model weight bytes under a quantization scheme (scale overheads
    /// folded in via effective bit widths).
    pub fn weight_bytes(&self, scheme: QuantScheme) -> u64 {
        use crate::quant::schemes::effective_bits;
        let embed_copies = if self.tied_embeddings { 1.0 } else { 2.0 };
        let embed = embed_copies
            * (self.vocab * self.d_model) as f64
            * effective_bits(scheme, WeightClass::Embedding)
            / 8.0;
        let qkv_o = (self.d_model * (self.heads_q + 2 * self.heads_kv) * self.head_dim
            + self.heads_q * self.head_dim * self.d_model) as f64
            * effective_bits(scheme, WeightClass::Attention)
            / 8.0;
        let ffn_n = if self.gated_ffn { 3 } else { 2 } * self.d_model * self.ffn_hidden;
        let ffn = ffn_n as f64 * effective_bits(scheme, WeightClass::FeedForward) / 8.0;
        (embed + self.layers as f64 * (qkv_o + ffn)) as u64
    }

    /// Bytes of KV cache per token (fp16 K and V across all layers).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * self.heads_kv * self.head_dim * 2 // 2 bytes fp16
    }
}

/// The paper's evaluation models (public architecture parameters) plus
/// TinyLM (the model served for real through the PJRT runtime).
pub fn llm_configs() -> Vec<LlmConfig> {
    vec![
        LlmConfig {
            name: "gemma_2b",
            layers: 18,
            d_model: 2048,
            heads_q: 8,
            heads_kv: 1, // MQA
            head_dim: 256,
            ffn_hidden: 16384,
            vocab: 256128,
            gated_ffn: true,
            act: EwOp::Gelu,
            tied_embeddings: true,
        },
        LlmConfig {
            name: "gemma2_2b",
            layers: 26,
            d_model: 2304,
            heads_q: 8,
            heads_kv: 4, // GQA
            head_dim: 256,
            ffn_hidden: 9216,
            vocab: 256128,
            gated_ffn: true,
            act: EwOp::Gelu,
            tied_embeddings: true,
        },
        LlmConfig {
            name: "llama3.2_3b",
            layers: 28,
            d_model: 3072,
            heads_q: 24,
            heads_kv: 8,
            head_dim: 128,
            ffn_hidden: 8192,
            vocab: 128256,
            gated_ffn: true,
            act: EwOp::Silu,
            tied_embeddings: true,
        },
        LlmConfig {
            name: "llama3.1_8b",
            layers: 32,
            d_model: 4096,
            heads_q: 32,
            heads_kv: 8,
            head_dim: 128,
            ffn_hidden: 14336,
            vocab: 128256,
            gated_ffn: true,
            act: EwOp::Silu,
            tied_embeddings: false,
        },
        LlmConfig {
            name: "tinylm",
            layers: 4,
            d_model: 256,
            heads_q: 4,
            heads_kv: 2,
            head_dim: 64,
            ffn_hidden: 1024,
            vocab: 2048,
            gated_ffn: true,
            act: EwOp::Silu,
            tied_embeddings: true,
        },
    ]
}

/// Look up a config by name.
pub fn llm_config(name: &str) -> Option<LlmConfig> {
    llm_configs().into_iter().find(|c| c.name == name)
}

/// Which stage graph to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LlmStageGraph {
    /// Process `seq` prompt tokens; K/V for the whole prompt are produced
    /// by the layer itself.
    Prefill { seq: usize },
    /// Generate one token against a KV cache holding `cache_len` entries
    /// (including the current token's slot — the fused QKV kernel writes
    /// it in place, §3.8).
    Decode { cache_len: usize },
}

/// Build the *unfused* transformer graph for one stage. The fusion passes
/// ([`crate::fusion::fuse_all`]) then produce the deployed form; keeping
/// construction unfused lets the ablation bench measure each fusion.
pub fn build_llm_graph(
    cfg: &LlmConfig,
    batch: usize,
    stage: LlmStageGraph,
    scheme: QuantScheme,
) -> Result<Graph> {
    let attn_dt = scheme_dtype_for(scheme, WeightClass::Attention);
    let ffn_dt = scheme_dtype_for(scheme, WeightClass::FeedForward);
    let embed_dt = scheme_dtype_for(scheme, WeightClass::Embedding);

    let (seq, stage_tag) = match stage {
        LlmStageGraph::Prefill { seq } => (seq, "prefill"),
        LlmStageGraph::Decode { .. } => (1, "decode"),
    };
    let mut g = Graph::new(&format!("{}_{stage_tag}_{}", cfg.name, scheme.name()));
    let d = cfg.d_model;
    let (hq, hkv, dh) = (cfg.heads_q, cfg.heads_kv, cfg.head_dim);
    let group = hq / hkv;

    let tokens = g.input("tokens", Shape::bhwc(batch, 1, seq, 1), DType::I32);
    let mut x = g.embedding("embed", tokens, cfg.vocab, d, embed_dt)?;

    for l in 0..cfg.layers {
        let p = |n: &str| format!("l{l}_{n}");
        // ---- attention block (pre-norm) --------------------------------
        let normed = g.rms_norm(&p("attn_norm"), x)?;
        let q = g.fully_connected(&p("wq"), normed, hq * dh, attn_dt)?;
        let k = g.fully_connected(&p("wk"), normed, hkv * dh, attn_dt)?;
        let v = g.fully_connected(&p("wv"), normed, hkv * dh, attn_dt)?;
        let q = g.rope(&p("rope_q"), q)?;
        let k_roped = g.rope(&p("rope_k"), k)?;
        // Head-folded attention layouts (§3.6).
        let q_r = g.reshape(&p("q_fold"), q, Shape::bhwc(batch * hkv, 1, seq * group, dh))?;
        let (scores_k, ctx_v) = match stage {
            LlmStageGraph::Prefill { seq } => {
                let k_r = g.reshape(&p("k_fold"), k_roped, Shape::bhwc(batch * hkv, 1, seq, dh))?;
                let v_r = g.reshape(&p("v_fold"), v, Shape::bhwc(batch * hkv, 1, seq, dh))?;
                (k_r, v_r)
            }
            LlmStageGraph::Decode { cache_len } => {
                // K cache in OHWI (O=cache, I=d_h); V reversed (§3.8). The
                // current token's K/V are written in place by the QKV
                // kernel; `k_roped`/`v` above model those cache writes.
                let kc = g.input(
                    &p("kv_k"),
                    Shape::bhwc(batch * hkv, 1, cache_len, dh),
                    DType::F16,
                );
                let vc = g.input(
                    &p("kv_v"),
                    Shape::bhwc(batch * hkv, 1, cache_len, dh),
                    DType::F16,
                );
                let _ = k_roped; // cache write, no further reader in-graph
                (kc, vc)
            }
        };
        let scores = g.matmul(&p("scores"), q_r, scores_k, true)?;
        let scaled = g.unary(&p("scale"), scores, EwOp::Scale(1.0 / (dh as f32).sqrt()))?;
        let probs = g.softmax(&p("probs"), scaled)?;
        let ctx = g.matmul(&p("ctx"), probs, ctx_v, false)?;
        let ctx_r = g.reshape(&p("ctx_unfold"), ctx, Shape::bhwc(batch, 1, seq, hq * dh))?;
        let attn_out = g.fully_connected(&p("wo"), ctx_r, d, attn_dt)?;
        let x_attn = g.binary(&p("attn_residual"), x, attn_out, BinOp::Add)?;

        // ---- feed-forward block (pre-norm) ------------------------------
        let normed = g.rms_norm(&p("ffn_norm"), x_attn)?;
        let ffn_out = if cfg.gated_ffn {
            let gate = g.fully_connected(&p("ffn_gate"), normed, cfg.ffn_hidden, ffn_dt)?;
            let gate = g.unary(&p("ffn_act"), gate, cfg.act)?;
            let up = g.fully_connected(&p("ffn_up"), normed, cfg.ffn_hidden, ffn_dt)?;
            let prod = g.binary(&p("ffn_mul"), up, gate, BinOp::Mul)?;
            g.fully_connected(&p("ffn_down"), prod, d, ffn_dt)?
        } else {
            let h = g.fully_connected(&p("ffn_up"), normed, cfg.ffn_hidden, ffn_dt)?;
            let h = g.unary(&p("ffn_act"), h, cfg.act)?;
            g.fully_connected(&p("ffn_down"), h, d, ffn_dt)?
        };
        x = g.binary(&p("ffn_residual"), x_attn, ffn_out, BinOp::Add)?;
    }

    let normed = g.rms_norm("final_norm", x)?;
    let logits = g.fully_connected("lm_head", normed, cfg.vocab, embed_dt)?;
    g.output(logits);
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_public_numbers() {
        // Published totals: Gemma 2B ≈ 2.5B, Gemma2 2B ≈ 2.6B,
        // Llama 3.2 3B ≈ 3.2B, Llama 3.1 8B ≈ 8.0B.
        let check = |name: &str, want_b: f64| {
            let p = llm_config(name).unwrap().params() as f64 / 1e9;
            assert!(
                (p - want_b).abs() / want_b < 0.08,
                "{name}: {p:.2}B vs published {want_b}B"
            );
        };
        check("gemma_2b", 2.51);
        check("gemma2_2b", 2.61);
        check("llama3.2_3b", 3.21);
        check("llama3.1_8b", 8.03);
    }

    #[test]
    fn weight_bytes_ordering_by_scheme() {
        let cfg = llm_config("gemma2_2b").unwrap();
        let q8 = cfg.weight_bytes(QuantScheme::Q8);
        let m844 = cfg.weight_bytes(QuantScheme::Mixed844);
        let gguf = cfg.weight_bytes(QuantScheme::GgufQ4_0);
        let f16 = cfg.weight_bytes(QuantScheme::F16);
        assert!(m844 < gguf && gguf < q8 && q8 < f16, "{m844} {gguf} {q8} {f16}");
        // Llama 3.1 8B q8 ≈ 8.0–8.6 GB (the Table 2 OOM threshold).
        let l8 = llm_config("llama3.1_8b").unwrap().weight_bytes(QuantScheme::Q8);
        assert!(l8 > 7_800_000_000 && l8 < 9_000_000_000, "{l8}");
    }

    #[test]
    fn prefill_graph_builds_and_validates() {
        let cfg = llm_config("tinylm").unwrap();
        let g = build_llm_graph(&cfg, 1, LlmStageGraph::Prefill { seq: 64 }, QuantScheme::Mixed844)
            .unwrap();
        assert_eq!(g.outputs.len(), 1);
        let logits = g.node(g.outputs[0]);
        assert_eq!(logits.shape, Shape::bhwc(1, 1, 64, cfg.vocab));
    }

    #[test]
    fn decode_graph_has_kv_inputs() {
        let cfg = llm_config("tinylm").unwrap();
        let g = build_llm_graph(&cfg, 1, LlmStageGraph::Decode { cache_len: 128 }, QuantScheme::Q8)
            .unwrap();
        let kv_inputs = g
            .nodes
            .iter()
            .filter(|n| n.name.contains("kv_"))
            .count();
        assert_eq!(kv_inputs, 2 * cfg.layers);
        let logits = g.node(g.outputs[0]);
        assert_eq!(logits.shape.w, 1, "decode emits one position");
    }

    #[test]
    fn fusion_applies_to_built_graph() {
        let cfg = llm_config("tinylm").unwrap();
        let mut g =
            build_llm_graph(&cfg, 1, LlmStageGraph::Prefill { seq: 32 }, QuantScheme::Mixed844)
                .unwrap();
        let before = crate::fusion::live_kernel_count(&g);
        let rep = crate::fusion::fuse_all(&mut g, Some((cfg.heads_q, cfg.heads_kv, cfg.head_dim)));
        assert!(rep.qkv_rope_fused >= cfg.layers, "{rep:?}");
        assert!(rep.add_rmsnorm_fused >= 1, "{rep:?}");
        assert!(crate::fusion::live_kernel_count(&g) < before);
        g.validate().unwrap();
    }

    #[test]
    fn kv_bytes_per_token() {
        let cfg = llm_config("gemma2_2b").unwrap();
        // 26 layers × 4 kv heads × 256 dim × 2 (K+V) × 2 bytes = 212992.
        assert_eq!(cfg.kv_bytes_per_token(), 26 * 4 * 256 * 2 * 2);
    }
}
