//! Model zoo: the paper's evaluation models as graph builders.
//!
//! * [`llm`] — Gemma 2B, Gemma2 2B, Llama 3.2 3B, Llama 3.1 8B (public
//!   architecture dimensions) plus `TinyLM`, the small model actually
//!   served end-to-end through the PJRT runtime. Builders emit *unfused*
//!   transformer graphs; [`crate::fusion`] then applies the paper's
//!   fusions (so ablations can toggle them).
//! * [`sd`] — Stable Diffusion 1.4's three components (CLIP text encoder,
//!   UNet, VAE decoder) at their real dimensions for the memory-planning
//!   (Fig. 3) and latency (Fig. 5, Table 3) experiments.

pub mod llm;
pub mod sd;

pub use llm::{llm_config, llm_configs, LlmConfig};
pub use sd::{sd_text_encoder, sd_unet, sd_vae_decoder, SdComponent};
