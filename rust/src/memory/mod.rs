//! Intermediate-tensor memory planning (paper §3.5, Fig. 3).
//!
//! Neural networks execute sequentially over a DAG, so intermediate
//! tensors need not occupy memory simultaneously: buffers can be reused
//! across tensors with non-overlapping lifetimes. Following Pisarchyk &
//! Lee [43], two families of strategies are provided:
//!
//! * **Offset calculation** — pre-allocate one arena and assign each
//!   tensor an offset inside it (`GREEDY BY SIZE` is the paper's choice
//!   for Stable Diffusion: 4.31 GB → 387 MB, 93 % savings).
//! * **Shared objects** — maintain a pool of reusable buffers and assign
//!   tensors to the best free one (`GREEDY BY BREADTH`).
//!
//! [`lifetime`] extracts tensor usage records from a (possibly fused)
//! graph; [`plan`] implements the strategies and validates plans.

pub mod lifetime;
pub mod plan;

pub use lifetime::{lifetimes, liveness_lower_bound, naive_bytes, TensorUsage};
pub use plan::{plan, validate_plan, Assignment, MemoryPlan, Strategy};
