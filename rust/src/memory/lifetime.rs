//! Tensor lifetime extraction from a (fused) graph.

use crate::graph::{Graph, NodeId, OpKind};
use crate::tensor::DType;

/// One intermediate tensor's memory requirement and lifetime, in units of
/// *execution steps* (live-kernel order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorUsage {
    /// Graph node whose output this buffer holds.
    pub node: NodeId,
    pub name: String,
    /// Buffer size in bytes (slice-padded storage footprint).
    pub bytes: usize,
    /// Step of the kernel that writes the buffer.
    pub first: usize,
    /// Last step of any kernel that reads it (≥ first).
    pub last: usize,
}

/// Extract intermediate-tensor usages from a graph.
///
/// * Steps are indices into the live-kernel execution order (absorbed
///   nodes execute inside their absorber's kernel).
/// * Graph inputs and constants are externally owned — not planned.
/// * Graph outputs stay live until the final step.
/// * Absorbed nodes own a buffer only if someone still reads it (the
///   secondary-output case of the fused residual+RMSNorm kernel);
///   rewired elementwise nodes own nothing.
/// * Buffer sizes use the slice-padded footprint (`⌈C/4⌉·4` channels) at
///   the node's activation dtype — matching what the GPU actually
///   allocates for PHWC4-family layouts.
pub fn lifetimes(g: &Graph, activation_dtype: DType) -> Vec<TensorUsage> {
    // Map node -> execution step of the kernel that materializes it.
    let mut step_of = vec![usize::MAX; g.nodes.len()];
    let mut step = 0usize;
    for n in &g.nodes {
        if n.kind.is_compute() && n.absorbed_into.is_none() {
            step_of[n.id] = step;
            step += 1;
        }
    }
    let last_step = step.saturating_sub(1);
    // Absorbed nodes materialize at their absorber's step (transitively).
    for n in &g.nodes {
        if let Some(mut a) = n.absorbed_into {
            while let Some(next) = g.nodes[a].absorbed_into {
                a = next;
            }
            step_of[n.id] = step_of[a];
        }
    }

    // Which nodes are read by live kernels?
    let mut usages = Vec::new();
    for n in &g.nodes {
        if matches!(n.kind, OpKind::Input | OpKind::Const) {
            continue;
        }
        let def = step_of[n.id];
        if def == usize::MAX {
            continue; // dead node
        }
        // Readers: any live kernel consuming this node (directly or as a
        // fused add operand).
        let mut last = def;
        let mut referenced = g.outputs.contains(&n.id);
        for m in &g.nodes {
            if m.id == n.id || step_of[m.id] == usize::MAX {
                continue;
            }
            let reads = m.inputs.contains(&n.id) || m.fused_adds.iter().any(|(i, _)| *i == n.id);
            if reads && m.absorbed_into.is_none() {
                referenced = true;
                last = last.max(step_of[m.id]);
            } else if reads {
                // Reader absorbed into another kernel: charge that kernel's step.
                referenced = true;
                last = last.max(step_of[m.id]);
            }
        }
        if n.absorbed_into.is_some() && !referenced {
            continue; // rewired away: owns no buffer
        }
        if g.outputs.contains(&n.id) {
            last = last_step;
        }
        let bytes = activation_dtype.bytes_for(n.shape.padded_elements());
        usages.push(TensorUsage { node: n.id, name: n.name.clone(), bytes, first: def, last });
    }
    usages
}

/// Sum of all usage sizes — the naive (no reuse) footprint.
pub fn naive_bytes(usages: &[TensorUsage]) -> usize {
    usages.iter().map(|u| u.bytes).sum()
}

/// Peak of the liveness profile — a lower bound for any planner.
pub fn liveness_lower_bound(usages: &[TensorUsage]) -> usize {
    let max_step = usages.iter().map(|u| u.last).max().unwrap_or(0);
    let mut profile = vec![0usize; max_step + 1];
    for u in usages {
        for s in u.first..=u.last {
            profile[s] += u.bytes;
        }
    }
    profile.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EwOp, Graph};
    use crate::tensor::{DType, Shape};

    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain");
        let x = g.input("x", Shape::bhwc(1, 8, 8, 16), DType::F16);
        let a = g.conv2d("a", x, 32, 3, 1, 1, DType::F16).unwrap();
        let b = g.conv2d("b", a, 32, 3, 1, 1, DType::F16).unwrap();
        let c = g.conv2d("c", b, 16, 3, 1, 1, DType::F16).unwrap();
        g.output(c);
        g
    }

    #[test]
    fn chain_lifetimes_are_tight() {
        let g = chain_graph();
        let us = lifetimes(&g, DType::F16);
        assert_eq!(us.len(), 3);
        // a: defined step 0, read by b at step 1.
        assert_eq!((us[0].first, us[0].last), (0, 1));
        assert_eq!((us[1].first, us[1].last), (1, 2));
        // c is the output: lives to the end.
        assert_eq!((us[2].first, us[2].last), (2, 2));
        assert_eq!(us[0].bytes, 8 * 8 * 32 * 2);
    }

    #[test]
    fn inputs_not_planned() {
        let g = chain_graph();
        let us = lifetimes(&g, DType::F16);
        assert!(us.iter().all(|u| g.node(u.node).kind.is_compute()));
    }

    #[test]
    fn absorbed_elementwise_owns_no_buffer() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let fc = g.fully_connected("fc", x, 64, DType::I8).unwrap();
        let act = g.unary("gelu", fc, EwOp::Gelu).unwrap();
        g.output(act);
        crate::fusion::passes::fuse_elementwise(&mut g);
        let us = lifetimes(&g, DType::F16);
        assert_eq!(us.len(), 1, "only the fc buffer remains: {us:?}");
        assert_eq!(us[0].node, fc);
    }

    #[test]
    fn fused_secondary_output_keeps_buffer() {
        // residual add absorbed into FusedAddRmsNorm but still read later.
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let y = g.input("y", Shape::bhwc(1, 1, 8, 64), DType::F16);
        let sum = g.binary("residual", x, y, crate::graph::BinOp::Add).unwrap();
        let norm = g.rms_norm("norm", sum).unwrap();
        let ffn = g.fully_connected("ffn", norm, 64, DType::I8).unwrap();
        let out = g.binary("residual2", sum, ffn, crate::graph::BinOp::Add).unwrap();
        g.output(out);
        crate::fusion::passes::fuse_add_rmsnorm(&mut g);
        let us = lifetimes(&g, DType::F16);
        let sum_usage = us.iter().find(|u| u.node == sum).expect("sum buffer still planned");
        // Defined at the fused kernel's step (0), read by residual2 (2).
        assert_eq!((sum_usage.first, sum_usage.last), (0, 2));
    }

    #[test]
    fn lower_bound_le_naive() {
        let g = chain_graph();
        let us = lifetimes(&g, DType::F16);
        assert!(liveness_lower_bound(&us) <= naive_bytes(&us));
        assert!(liveness_lower_bound(&us) > 0);
    }
}
