//! Memory planning strategies.

use crate::error::{DriftError, Result};
use crate::graph::NodeId;
use crate::memory::lifetime::TensorUsage;
use crate::util::align_up;

/// Buffer alignment (bytes). GPU APIs typically require 64–256; 64 keeps
/// the Fig. 3 numbers comparable to the paper's MB-granular reporting.
pub const ALIGN: usize = 64;

/// Planning strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Every tensor gets its own allocation (no reuse).
    Naive,
    /// Offset calculation, tensors placed in descending size order
    /// (Pisarchyk & Lee's GREEDY BY SIZE — the paper's Fig. 3 policy).
    GreedyBySize,
    /// Shared objects, tensors assigned in descending size order to the
    /// largest free object (GREEDY BY BREADTH).
    GreedyByBreadth,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Naive => "NAIVE",
            Strategy::GreedyBySize => "GREEDY_BY_SIZE",
            Strategy::GreedyByBreadth => "GREEDY_BY_BREADTH",
        }
    }
}

/// One tensor's placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub node: NodeId,
    /// Arena object this tensor lives in (0 for offset strategies).
    pub object: usize,
    /// Byte offset within the object.
    pub offset: usize,
    pub bytes: usize,
}

/// A complete plan: placements + total footprint.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    pub strategy: Strategy,
    pub assignments: Vec<Assignment>,
    /// Total bytes across all objects.
    pub total_bytes: usize,
    /// Per-object sizes.
    pub object_bytes: Vec<usize>,
}

impl MemoryPlan {
    /// Savings relative to the naive footprint, in [0, 1].
    pub fn savings_vs(&self, naive_total: usize) -> f64 {
        if naive_total == 0 {
            return 0.0;
        }
        1.0 - self.total_bytes as f64 / naive_total as f64
    }
}

fn overlap(a: &TensorUsage, b: &TensorUsage) -> bool {
    a.first <= b.last && b.first <= a.last
}

/// Plan memory for `usages` with the given strategy.
pub fn plan(usages: &[TensorUsage], strategy: Strategy) -> MemoryPlan {
    match strategy {
        Strategy::Naive => plan_naive(usages),
        Strategy::GreedyBySize => plan_greedy_by_size(usages),
        Strategy::GreedyByBreadth => plan_greedy_by_breadth(usages),
    }
}

fn plan_naive(usages: &[TensorUsage]) -> MemoryPlan {
    let mut offset = 0usize;
    let mut assignments = Vec::with_capacity(usages.len());
    for u in usages {
        assignments.push(Assignment { node: u.node, object: 0, offset, bytes: u.bytes });
        offset += align_up(u.bytes, ALIGN);
    }
    MemoryPlan {
        strategy: Strategy::Naive,
        assignments,
        total_bytes: offset,
        object_bytes: vec![offset],
    }
}

/// GREEDY BY SIZE offset calculation: place tensors in descending size
/// order; each goes to the lowest offset where it fits without byte-range
/// overlap against already-placed tensors with overlapping lifetimes.
fn plan_greedy_by_size(usages: &[TensorUsage]) -> MemoryPlan {
    let mut order: Vec<usize> = (0..usages.len()).collect();
    order.sort_by(|&a, &b| {
        usages[b]
            .bytes
            .cmp(&usages[a].bytes)
            .then(usages[a].first.cmp(&usages[b].first))
            .then(usages[a].node.cmp(&usages[b].node))
    });

    let mut placed: Vec<(usize, Assignment)> = Vec::new(); // (usage idx, placement)
    let mut total = 0usize;
    // §Perf: the conflict buffer and aligned end offsets are reused across
    // placements (one allocation for the whole plan instead of one per
    // tensor), and lifetimes are pre-fetched to a flat array to keep the
    // O(n²) overlap scan cache-friendly.
    let mut conflicts: Vec<(usize, usize)> = Vec::with_capacity(usages.len());
    let spans: Vec<(usize, usize)> = usages.iter().map(|u| (u.first, u.last)).collect();
    for &ui in &order {
        let u = &usages[ui];
        let (uf, ul) = spans[ui];
        let size = align_up(u.bytes.max(1), ALIGN);
        // Conflicting placements sorted by offset.
        conflicts.clear();
        for (pi, a) in &placed {
            let (pf, pl) = spans[*pi];
            if pf <= ul && uf <= pl {
                conflicts.push((a.offset, a.offset + align_up(a.bytes.max(1), ALIGN)));
            }
        }
        conflicts.sort_unstable();
        // First-fit gap scan.
        let mut offset = 0usize;
        for &(start, end) in conflicts.iter() {
            if offset + size <= start {
                break;
            }
            offset = offset.max(end);
        }
        total = total.max(offset + size);
        placed.push((ui, Assignment { node: u.node, object: 0, offset, bytes: u.bytes }));
    }
    // Restore usage order for readability.
    placed.sort_by_key(|(ui, _)| *ui);
    MemoryPlan {
        strategy: Strategy::GreedyBySize,
        assignments: placed.into_iter().map(|(_, a)| a).collect(),
        total_bytes: total,
        object_bytes: vec![total],
    }
}

/// GREEDY BY BREADTH shared objects: tensors in descending size order are
/// assigned to the largest existing object that is free throughout their
/// lifetime; if none fits, a new object of exactly their size is created
/// (growing an existing smaller free object is allowed when it is the
/// largest free one — matching [43]'s formulation).
fn plan_greedy_by_breadth(usages: &[TensorUsage]) -> MemoryPlan {
    let mut order: Vec<usize> = (0..usages.len()).collect();
    order.sort_by(|&a, &b| {
        usages[b]
            .bytes
            .cmp(&usages[a].bytes)
            .then(usages[a].first.cmp(&usages[b].first))
            .then(usages[a].node.cmp(&usages[b].node))
    });

    struct Obj {
        bytes: usize,
        users: Vec<usize>, // usage indices
    }
    let mut objects: Vec<Obj> = Vec::new();
    let mut assign: Vec<(usize, usize)> = Vec::new(); // (usage idx, object)
    for &ui in &order {
        let u = &usages[ui];
        // Free objects (no lifetime conflict), prefer the largest.
        let mut best: Option<usize> = None;
        for (oi, o) in objects.iter().enumerate() {
            let free = o.users.iter().all(|&other| !overlap(&usages[other], u));
            if free {
                best = match best {
                    Some(b) if objects[b].bytes >= o.bytes => Some(b),
                    _ => Some(oi),
                };
            }
        }
        match best {
            Some(oi) => {
                objects[oi].bytes = objects[oi].bytes.max(align_up(u.bytes, ALIGN));
                objects[oi].users.push(ui);
                assign.push((ui, oi));
            }
            None => {
                objects.push(Obj { bytes: align_up(u.bytes, ALIGN), users: vec![ui] });
                assign.push((ui, objects.len() - 1));
            }
        }
    }
    assign.sort_by_key(|(ui, _)| *ui);
    let object_bytes: Vec<usize> = objects.iter().map(|o| o.bytes).collect();
    MemoryPlan {
        strategy: Strategy::GreedyByBreadth,
        assignments: assign
            .into_iter()
            .map(|(ui, oi)| Assignment {
                node: usages[ui].node,
                object: oi,
                offset: 0,
                bytes: usages[ui].bytes,
            })
            .collect(),
        total_bytes: object_bytes.iter().sum(),
        object_bytes,
    }
}

/// Verify a plan: every pair of assignments with overlapping lifetimes in
/// the same object must not overlap in byte ranges.
pub fn validate_plan(usages: &[TensorUsage], plan: &MemoryPlan) -> Result<()> {
    if usages.len() != plan.assignments.len() {
        return Err(DriftError::Memory(format!(
            "plan covers {} tensors, expected {}",
            plan.assignments.len(),
            usages.len()
        )));
    }
    for (i, (ua, aa)) in usages.iter().zip(&plan.assignments).enumerate() {
        if ua.node != aa.node {
            return Err(DriftError::Memory(format!("assignment {i} node mismatch")));
        }
        for (ub, ab) in usages.iter().zip(&plan.assignments).skip(i + 1) {
            if aa.object != ab.object || !overlap(ua, ub) {
                continue;
            }
            let a_end = aa.offset + aa.bytes;
            let b_end = ab.offset + ab.bytes;
            let byte_overlap = aa.offset < b_end && ab.offset < a_end;
            if byte_overlap {
                return Err(DriftError::Memory(format!(
                    "tensors {} and {} overlap in object {} (lifetimes [{},{}] vs [{},{}])",
                    ua.name, ub.name, aa.object, ua.first, ua.last, ub.first, ub.last
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::lifetime::{liveness_lower_bound, naive_bytes};
    use crate::util::propcheck::{check, Config};
    use crate::util::rng::Pcg32;

    fn usage(node: usize, bytes: usize, first: usize, last: usize) -> TensorUsage {
        TensorUsage { node, name: format!("t{node}"), bytes, first, last }
    }

    #[test]
    fn chain_reuses_two_buffers() {
        // A classic chain a→b→c: disjoint lifetimes alternate, so GREEDY BY
        // SIZE needs only the two largest concurrent tensors.
        let us = vec![usage(0, 1000, 0, 1), usage(1, 1000, 1, 2), usage(2, 1000, 2, 3)];
        let p = plan(&us, Strategy::GreedyBySize);
        validate_plan(&us, &p).unwrap();
        assert_eq!(p.total_bytes, 2 * align_up(1000, ALIGN));
        let naive = plan(&us, Strategy::Naive);
        assert_eq!(naive.total_bytes, 3 * align_up(1000, ALIGN));
    }

    #[test]
    fn greedy_by_size_packs_around_big_tensor() {
        // Big long-lived tensor + small short ones with pairwise-disjoint
        // lifetimes: smalls pack into one slot above the big tensor.
        let us = vec![
            usage(0, 10_000, 0, 5),
            usage(1, 100, 1, 1),
            usage(2, 100, 2, 2),
            usage(3, 100, 3, 3),
        ];
        let p = plan(&us, Strategy::GreedyBySize);
        validate_plan(&us, &p).unwrap();
        // Smalls share one slot above the big tensor.
        assert_eq!(p.total_bytes, align_up(10_000, ALIGN) + align_up(100, ALIGN));
    }

    #[test]
    fn breadth_creates_objects() {
        let us = vec![usage(0, 1000, 0, 1), usage(1, 500, 0, 1), usage(2, 900, 2, 3)];
        let p = plan(&us, Strategy::GreedyByBreadth);
        validate_plan(&us, &p).unwrap();
        // t0 and t1 overlap → 2 objects; t2 reuses the 1000-byte object.
        assert_eq!(p.object_bytes.len(), 2);
        assert_eq!(p.total_bytes, align_up(1000, ALIGN) + align_up(500, ALIGN));
    }

    #[test]
    fn planners_never_beat_liveness_bound() {
        let us = vec![
            usage(0, 3000, 0, 2),
            usage(1, 2000, 1, 3),
            usage(2, 1500, 2, 4),
            usage(3, 800, 3, 5),
        ];
        let lb = liveness_lower_bound(&us);
        for s in [Strategy::GreedyBySize, Strategy::GreedyByBreadth, Strategy::Naive] {
            let p = plan(&us, s);
            validate_plan(&us, &p).unwrap();
            assert!(p.total_bytes >= lb, "{s:?} beat the liveness bound");
            assert!(p.total_bytes <= naive_bytes(&us) + us.len() * ALIGN);
        }
    }

    #[test]
    fn property_random_lifetimes_valid_plans() {
        check("memory plans are overlap-free", Config::cases(60), |rng: &mut Pcg32| {
            let n = 2 + rng.gen_range(40) as usize;
            let steps = 3 + rng.gen_range(30) as usize;
            let us: Vec<TensorUsage> = (0..n)
                .map(|i| {
                    let first = rng.gen_range(steps as u64) as usize;
                    let last = first + rng.gen_range((steps - first) as u64 + 1) as usize;
                    usage(i, 1 + rng.gen_range(5000) as usize, first, last.min(steps))
                })
                .collect();
            for s in [Strategy::Naive, Strategy::GreedyBySize, Strategy::GreedyByBreadth] {
                let p = plan(&us, s);
                validate_plan(&us, &p).map_err(|e| format!("{s:?}: {e}"))?;
                let lb = liveness_lower_bound(&us);
                if p.total_bytes < lb {
                    return Err(format!("{s:?} beat lower bound: {} < {lb}", p.total_bytes));
                }
            }
            // Greedy-by-size should never exceed naive.
            let gs = plan(&us, Strategy::GreedyBySize).total_bytes;
            let nv = plan(&us, Strategy::Naive).total_bytes;
            if gs > nv {
                return Err(format!("greedy {gs} worse than naive {nv}"));
            }
            Ok(())
        });
    }
}
