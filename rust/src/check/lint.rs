//! Repo invariant linter (`mldrift lint`): text/token-level enforcement
//! of the cross-layer contracts every PR so far has maintained by hand.
//! Zero dependencies — files are read with `std::fs`, comments and
//! string literals are stripped by a small character state machine so
//! rules match *code* tokens only, and every rule is scoped by path so
//! the layer that owns a privileged API keeps using it.
//!
//! Rules (each with a violating + clean fixture test below):
//!
//! | rule | scope | contract |
//! |------|-------|----------|
//! | `sim-wall-clock` | `src/sim/` | the simulator runs on virtual time only — `Instant`/`SystemTime` reads are banned |
//! | `kv-pool-discipline` | everywhere except `src/kv/`, `src/check/` | allocation/eviction policy goes through the [`crate::kv::KvPool`] seam; privileged arena mutators are kv-internal |
//! | `bench-gate-order` | `benches/` | a bench gate `.check()` runs only after the trajectory write (or in a marked `--only-` early-exit block that skips the write entirely) |
//! | `undocumented-invariant` | `src/kv/`, `src/serving/` | every `pub` item whose declaration mentions `window`/`provisional`/`unsafe` carries a doc comment that states its invariant |
//! | `unsafe-pin` | whole crate | the `unsafe` token count stays pinned at zero and `lib.rs` keeps `#![forbid(unsafe_code)]` |
//! | `spec-commit-discipline` | everywhere except `src/kv/`, `src/runtime/`, `src/check/` | the speculative KV commit/rollback seam (`commit_provisional`/`scrub_uncommitted`) is driven only by the runtime step functions — serving code sees committed state only |
//! | `device-actor-confinement` | `src/serving/` except `device.rs` | the concrete `TinyLmRuntime` (PJRT handles, not `Send`) is named only by the device actor — policy code dispatches through `LmBackend` and round descriptors |

use std::fmt;
use std::path::Path;

/// One finding. Ordering is (file, line) within the sorted file list,
/// so output is deterministic and diffable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LintDiagnostic {
    /// Stable rule slug (see module table).
    pub rule: &'static str,
    /// Repo-relative path, forward slashes.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for LintDiagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Process exit code for a lint run: 0 clean, 1 when anything fired
/// (the CLI maps this through `main`'s `Result`).
pub fn exit_code(diags: &[LintDiagnostic]) -> i32 {
    i32::from(!diags.is_empty())
}

/// Strip comments and string/char-literal *contents* from Rust source,
/// preserving every newline and the column of every surviving token
/// (stripped characters become spaces), so diagnostics computed on the
/// output carry real line numbers. Handles line comments, nested block
/// comments, string/byte-string escapes, raw strings with `#` fences,
/// and the lifetime-vs-char-literal ambiguity.
pub fn strip_code(src: &str) -> String {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    // Emit a stripped placeholder: newlines survive, all else blanks.
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut prev_ident = false;
    while i < n {
        let c = chars[i];
        // Line comment.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            prev_ident = false;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(chars[i]));
                    i += 1;
                }
            }
            prev_ident = false;
            continue;
        }
        // Raw (byte) string: r"…", r#"…"#, br#"…"#… — only when the
        // `r`/`b` starts a token (not the tail of an identifier).
        if (c == 'r' || c == 'b') && !prev_ident {
            let mut j = i;
            if chars[j] == 'b' && j + 1 < n && chars[j + 1] == 'r' {
                j += 1;
            }
            if chars[j] == 'r' {
                let mut k = j + 1;
                while k < n && chars[k] == '#' {
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let hashes = k - (j + 1);
                    for _ in i..=k {
                        out.push(' ');
                    }
                    i = k + 1;
                    // Scan for `"` followed by `hashes` `#`s.
                    'raw: while i < n {
                        if chars[i] == '"' {
                            let mut h = 0;
                            while h < hashes && i + 1 + h < n && chars[i + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        out.push(blank(chars[i]));
                        i += 1;
                    }
                    prev_ident = false;
                    continue;
                }
            }
        }
        // Plain (byte) string.
        if c == '"' || (c == 'b' && !prev_ident && i + 1 < n && chars[i + 1] == '"') {
            if c == 'b' {
                out.push(' ');
                i += 1;
            }
            out.push(' ');
            i += 1; // past the opening quote
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(blank(chars[i + 1]));
                    i += 2;
                } else if chars[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(chars[i]));
                    i += 1;
                }
            }
            prev_ident = false;
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` are literals,
        // `'static` / `'a` in `&'a` are lifetimes (kept as code).
        if c == '\'' {
            let is_char_literal = if i + 1 < n && chars[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\''
            };
            if is_char_literal {
                out.push(' ');
                i += 1;
                if i < n && chars[i] == '\\' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(' ');
                    i += 1;
                }
                if i < n && chars[i] == '\'' {
                    out.push(' ');
                    i += 1;
                }
                prev_ident = false;
                continue;
            }
        }
        out.push(c);
        prev_ident = is_ident(c);
        i += 1;
    }
    out
}

/// Find word-boundary occurrences of `word` in `line`, returning byte
/// offsets (an occurrence flanked by identifier characters is part of a
/// longer token and does not count).
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            hits.push(at);
        }
        from = at + word.len().max(1);
    }
    hits
}

const WALL_CLOCK_TOKENS: [&str; 2] = ["Instant", "SystemTime"];

/// Privileged [`crate::kv::KvArena`] mutators: growth, copy-on-write
/// privatization, window pinning, retention internals, and the checker
/// fault seam. Everything an admission/eviction policy legitimately
/// needs is on the `KvPool` trait (`can_claim`, `claim`, `ensure`,
/// `release`, `can_claim_prefixed`, `claim_prefixed`) or the arena's
/// read-only/commit surface (`len`, `append`, `publish_prefix`,
/// `stats`, `verify`, …) — those stay callable anywhere.
const PRIVILEGED_KV_CALLS: [&str; 11] = [
    ".grow(",
    ".ensure_detailed(",
    ".make_private(",
    ".claim_prefixed_detailed(",
    ".truncate_reservation(",
    ".pin_window(",
    ".unpin_window(",
    ".unpin_window_raw(",
    ".take_retention_evictions(",
    ".fault_free_deferred_ignoring_pins(",
    ".fault_forget_cow_extensions(",
];

/// The speculative commit/rollback seam: provisional rows become real
/// only via `commit_provisional`, and a failed speculative step must
/// `scrub_uncommitted` before anyone reads the store. Both transitions
/// belong to the runtime step functions (`spec_round_paged*`) and the
/// kv layer itself — a serving-layer caller would split the rollback
/// contract across layers, exactly the drift the fleet engine's
/// "committed state only" view is built on.
const SPEC_COMMIT_CALLS: [&str; 2] = [".commit_provisional(", ".scrub_uncommitted("];

const DECL_NEEDLES: [&str; 3] = ["window", "provisional", "unsafe"];
const DECL_PREFIXES: [&str; 6] =
    ["pub fn ", "pub struct ", "pub enum ", "pub trait ", "pub type ", "pub const "];
const INVARIANT_KEYWORDS: [&str; 10] = [
    "invariant", "never", "must", "cannot", "defer", "pin", "in-flight", "only", "contract",
    "exactly",
];

fn in_dir(file: &str, dir: &str) -> bool {
    file.contains(dir)
}

/// R1: simulated time only — `src/sim/` may not read wall clocks; the
/// virtual clock comes from the roofline model, and a single
/// `Instant::now` makes every simulated latency nondeterministic.
fn rule_sim_wall_clock(file: &str, stripped: &str, diags: &mut Vec<LintDiagnostic>) {
    if !in_dir(file, "src/sim/") {
        return;
    }
    for (ln, line) in stripped.lines().enumerate() {
        for tok in WALL_CLOCK_TOKENS {
            if !word_positions(line, tok).is_empty() {
                diags.push(LintDiagnostic {
                    rule: "sim-wall-clock",
                    file: file.to_string(),
                    line: ln + 1,
                    message: format!(
                        "wall-clock type `{tok}` in sim code: the simulator runs on virtual \
                         time only"
                    ),
                });
            }
        }
    }
}

/// R2: KV allocation policy goes through the `KvPool` trait seam.
/// Privileged arena mutators called outside `src/kv/` would let the
/// engine and the simulator drift onto different policy code — the
/// whole point of the seam (PR 5) is that both sides share it.
/// `src/check/` is exempt: the model checker deliberately drives the
/// raw transition system.
fn rule_kv_pool_discipline(file: &str, stripped: &str, diags: &mut Vec<LintDiagnostic>) {
    if in_dir(file, "src/kv/") || in_dir(file, "src/check/") {
        return;
    }
    for (ln, line) in stripped.lines().enumerate() {
        for call in PRIVILEGED_KV_CALLS {
            if line.contains(call) {
                let name = &call[1..call.len() - 1];
                diags.push(LintDiagnostic {
                    rule: "kv-pool-discipline",
                    file: file.to_string(),
                    line: ln + 1,
                    message: format!(
                        "privileged KvArena call `{name}` outside src/kv/: allocation policy \
                         must go through the KvPool trait"
                    ),
                });
            }
        }
    }
}

/// How many original lines above a `.check()` call to scan for an
/// `--only-` marker (the flag test plus its comment block).
const ONLY_MARKER_WINDOW: usize = 6;

/// R3: bench gates assert only after their trajectory write. A gate
/// that panics before `fs::write` lands takes the whole trajectory with
/// it — CI then has gate failures *and* no artifact to diff, and
/// `bench-check` regression tracking silently loses a data point. The
/// one sanctioned exception: `--only-…` early-exit blocks, which run a
/// single part's gates and deliberately skip the write (marker must
/// appear within the preceding few lines).
fn rule_bench_gate_order(
    file: &str,
    original: &str,
    stripped: &str,
    diags: &mut Vec<LintDiagnostic>,
) {
    if !in_dir(file, "benches/") {
        return;
    }
    let orig_lines: Vec<&str> = original.lines().collect();
    let mut write_seen = false;
    for (ln, line) in stripped.lines().enumerate() {
        if line.contains("fs::write(") {
            write_seen = true;
        }
        if line.contains(".check()") && !write_seen {
            let lo = ln.saturating_sub(ONLY_MARKER_WINDOW);
            let marked = orig_lines[lo..=ln.min(orig_lines.len().saturating_sub(1))]
                .iter()
                .any(|l| l.contains("--only-"));
            if !marked {
                diags.push(LintDiagnostic {
                    rule: "bench-gate-order",
                    file: file.to_string(),
                    line: ln + 1,
                    message: "bench gate `.check()` before the trajectory write: assert gates \
                              after `fs::write`, or mark an `--only-` early-exit block"
                        .to_string(),
                });
            }
        }
    }
}

/// R4: every `pub` item in `src/kv/` and `src/serving/` whose
/// declaration mentions a dangerous concept (`window`, `provisional`,
/// `unsafe`) must carry a doc comment that actually states its
/// invariant — one of [`INVARIANT_KEYWORDS`]. The reservation-window
/// and provisional-scatter APIs are exactly the ones whose misuse is a
/// memory-safety bug at the device layer; their contracts live in doc
/// comments, and this rule keeps those contracts from silently rotting
/// into "TODO".
fn rule_undocumented_invariant(file: &str, original: &str, diags: &mut Vec<LintDiagnostic>) {
    if !(in_dir(file, "src/kv/") || in_dir(file, "src/serving/")) {
        return;
    }
    let lines: Vec<&str> = original.lines().collect();
    for (ln, raw) in lines.iter().enumerate() {
        let line = raw.trim_start();
        if !DECL_PREFIXES.iter().any(|p| line.starts_with(p)) {
            continue;
        }
        let lower = line.to_lowercase();
        let Some(needle) = DECL_NEEDLES.iter().find(|n| lower.contains(**n)) else {
            continue;
        };
        // Walk upward: skip attributes, then collect the contiguous
        // `///` block.
        let mut k = ln;
        let mut doc = String::new();
        while k > 0 {
            k -= 1;
            let above = lines[k].trim_start();
            if above.starts_with("#[") || above.starts_with("#!") {
                continue;
            }
            if above.starts_with("///") {
                doc.push_str(&above.to_lowercase());
                doc.push('\n');
            } else {
                break;
            }
        }
        let documented = !doc.is_empty()
            && INVARIANT_KEYWORDS.iter().any(|kw| doc.contains(kw));
        if !documented {
            let name = line
                .split_whitespace()
                .nth(2)
                .unwrap_or("<unnamed>")
                .trim_end_matches(|c: char| !c.is_alphanumeric() && c != '_')
                .split(['(', '<', ':'])
                .next()
                .unwrap_or("<unnamed>");
            diags.push(LintDiagnostic {
                rule: "undocumented-invariant",
                file: file.to_string(),
                line: ln + 1,
                message: format!(
                    "pub item `{name}` mentions `{needle}` but its doc comment states no \
                     invariant (expected one of: {})",
                    INVARIANT_KEYWORDS.join(", ")
                ),
            });
        }
    }
}

/// R5: the crate's `unsafe` count is pinned at zero. Every cross-thread
/// seam is built on std's safe primitives; an `unsafe` block would be a
/// latent race surface exactly where the pipelined executor can least
/// afford one. `lib.rs` must also keep the crate-level
/// `#![forbid(unsafe_code)]` so the compiler enforces what this rule
/// reports.
fn rule_unsafe_pin(file: &str, stripped: &str, diags: &mut Vec<LintDiagnostic>) {
    for (ln, line) in stripped.lines().enumerate() {
        for at in word_positions(line, "unsafe") {
            // `unsafe_code` inside the forbid attribute is the pin
            // itself, not a use — word boundaries already exclude it,
            // so any surviving hit is a real token.
            let _ = at;
            diags.push(LintDiagnostic {
                rule: "unsafe-pin",
                file: file.to_string(),
                line: ln + 1,
                message: "`unsafe` token: this crate pins its unsafe count at zero \
                          (#![forbid(unsafe_code)])"
                    .to_string(),
            });
        }
    }
    if file.ends_with("src/lib.rs") && !stripped.contains("#![forbid(unsafe_code)]") {
        diags.push(LintDiagnostic {
            rule: "unsafe-pin",
            file: file.to_string(),
            line: 1,
            message: "missing `#![forbid(unsafe_code)]`: lib.rs must keep the crate-level \
                      forbid that backs the unsafe-pin rule"
                .to_string(),
        });
    }
}

/// R6: the speculative commit/rollback seam stays confined. Only the
/// kv layer (implementation), the runtime step functions (the one
/// legitimate driver — commit on accept, scrub on error), and the
/// model checker (which explores the raw transitions) may call
/// `commit_provisional`/`scrub_uncommitted`. Serving code operating the
/// seam directly would mean a second, divergent copy of the rollback
/// contract — the engine must only ever observe committed KV state.
fn rule_spec_commit_discipline(file: &str, stripped: &str, diags: &mut Vec<LintDiagnostic>) {
    if in_dir(file, "src/kv/") || in_dir(file, "src/runtime/") || in_dir(file, "src/check/") {
        return;
    }
    for (ln, line) in stripped.lines().enumerate() {
        for call in SPEC_COMMIT_CALLS {
            if line.contains(call) {
                let name = &call[1..call.len() - 1];
                diags.push(LintDiagnostic {
                    rule: "spec-commit-discipline",
                    file: file.to_string(),
                    line: ln + 1,
                    message: format!(
                        "speculative KV seam call `{name}` outside src/kv//src/runtime/: \
                         commit/rollback is driven by the runtime step functions only"
                    ),
                });
            }
        }
    }
}

/// R7: the device actor owns the model runtime. Within `src/serving/`
/// the concrete `TinyLmRuntime` type — PJRT handles, not `Send`, born
/// on and owned by the device thread — may be named only by
/// `src/serving/device.rs`. Policy code (scheduler, admission, the
/// server loops) dispatches through the `LmBackend` trait and
/// fully-bound round descriptors; a policy-side `TinyLmRuntime` call
/// would re-couple the two actors the async split exists to separate,
/// and the compiler would not catch it until someone tried a `Send`
/// bound.
fn rule_device_actor_confinement(file: &str, stripped: &str, diags: &mut Vec<LintDiagnostic>) {
    if !in_dir(file, "src/serving/") || file.ends_with("src/serving/device.rs") {
        return;
    }
    for (ln, line) in stripped.lines().enumerate() {
        if !word_positions(line, "TinyLmRuntime").is_empty() {
            diags.push(LintDiagnostic {
                rule: "device-actor-confinement",
                file: file.to_string(),
                line: ln + 1,
                message: "`TinyLmRuntime` named outside src/serving/device.rs: the device \
                          actor owns the runtime; policy code dispatches through LmBackend"
                    .to_string(),
            });
        }
    }
}

/// Lint in-memory files (`(path, content)` pairs). Paths are matched
/// textually against rule scopes (`src/sim/`, `src/kv/`, `benches/`,
/// …), so callers should pass repo-relative paths with forward slashes.
/// Diagnostics come back sorted by (file, line, rule).
pub fn lint_files(files: &[(String, String)]) -> Vec<LintDiagnostic> {
    let mut diags = Vec::new();
    let mut sorted: Vec<&(String, String)> = files.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    for (path, content) in sorted {
        let stripped = strip_code(content);
        rule_sim_wall_clock(path, &stripped, &mut diags);
        rule_kv_pool_discipline(path, &stripped, &mut diags);
        rule_bench_gate_order(path, content, &stripped, &mut diags);
        rule_undocumented_invariant(path, content, &mut diags);
        rule_unsafe_pin(path, &stripped, &mut diags);
        rule_spec_commit_discipline(path, &stripped, &mut diags);
        rule_device_actor_confinement(path, &stripped, &mut diags);
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    diags
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("lint: cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("lint: walk error under {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the repository at `root` (the directory containing `rust/`):
/// walks `rust/src`, `rust/benches`, and `rust/tests`, and returns the
/// diagnostics. `Err` is an I/O problem, not a lint finding.
pub fn lint_repo(root: &Path) -> Result<Vec<LintDiagnostic>, String> {
    let rust = root.join("rust");
    let mut paths = Vec::new();
    for sub in ["src", "benches", "tests"] {
        let dir = rust.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut paths)?;
        }
    }
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let content = std::fs::read_to_string(&p)
            .map_err(|e| format!("lint: cannot read {}: {e}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push((rel, content));
    }
    Ok(lint_files(&files))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, content: &str) -> Vec<LintDiagnostic> {
        lint_files(&[(path.to_string(), content.to_string())])
    }

    #[test]
    fn stripper_removes_comments_strings_and_keeps_lines() {
        let src = "let a = 1; // Instant::now()\nlet s = \"unsafe .pin_window(\"; /* multi\nline SystemTime */ let b = 2;\n";
        let out = strip_code(src);
        assert_eq!(out.lines().count(), src.lines().count());
        assert!(!out.contains("Instant"));
        assert!(!out.contains("unsafe"));
        assert!(!out.contains("pin_window"));
        assert!(out.contains("let a = 1;"));
        assert!(out.contains("let b = 2;"));
    }

    #[test]
    fn stripper_handles_raw_strings_nesting_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { let r = r#\"unsafe \"quoted\" \"#; /* a /* nested */ unsafe */ let c = 'u'; 'x' }";
        let out = strip_code(src);
        assert!(!out.contains("unsafe"), "stripped: {out}");
        assert!(out.contains("<'a>"), "lifetimes survive: {out}");
        assert!(out.contains("fn f"));
    }

    #[test]
    fn sim_wall_clock_fires_in_sim_only() {
        let bad = "use std::time::Instant;\nfn t() { let s = Instant::now(); }\n";
        let d = lint_one("rust/src/sim/timing.rs", bad);
        assert_eq!(d.len(), 2, "{d:?}");
        assert_eq!(d[0].rule, "sim-wall-clock");
        assert_eq!(d[0].line, 1);
        assert_eq!(
            d[0].message,
            "wall-clock type `Instant` in sim code: the simulator runs on virtual time only"
        );
        // Same content outside sim/ is fine.
        assert!(lint_one("rust/src/serving/request.rs", bad).is_empty());
        // Comments mentioning Instant are fine even in sim/.
        assert!(lint_one("rust/src/sim/timing.rs", "// Instant::now() is banned here\n")
            .is_empty());
    }

    #[test]
    fn kv_pool_discipline_bans_privileged_calls_outside_kv() {
        let bad = "fn f(a: &mut KvArena, h: KvSeqHandle) { a.pin_window(&[1]); a.grow(h, 4).unwrap(); }\n";
        let d = lint_one("rust/src/serving/scheduler.rs", bad);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "kv-pool-discipline"));
        // Same line, two calls: diagnostics follow the banned-list
        // order, so `grow` is reported first.
        assert_eq!(
            d[0].message,
            "privileged KvArena call `grow` outside src/kv/: allocation policy must go \
             through the KvPool trait"
        );
        assert!(d[1].message.contains("`pin_window`"), "{}", d[1].message);
        // The same calls inside kv/ and check/ are the implementation.
        assert!(lint_one("rust/src/kv/region.rs", bad).is_empty());
        assert!(lint_one("rust/src/check/model.rs", bad).is_empty());
        // Trait-surface calls are fine anywhere.
        let clean = "fn f(p: &mut dyn KvPool, h: KvSeqHandle) { p.ensure(h, 1).unwrap(); p.release(h); }\n";
        assert!(lint_one("rust/src/serving/scheduler.rs", clean).is_empty());
    }

    #[test]
    fn bench_gate_order_requires_write_before_check() {
        let bad = "fn main() {\n    gates.check();\n    std::fs::write(OUT, text).unwrap();\n}\n";
        let d = lint_one("rust/benches/bench_x.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "bench-gate-order");
        assert_eq!(d[0].line, 2);
        let clean = "fn main() {\n    std::fs::write(OUT, text).unwrap();\n    gates.check();\n}\n";
        assert!(lint_one("rust/benches/bench_x.rs", clean).is_empty());
        // `--only-` early-exit blocks are the sanctioned exception.
        let only = "fn main() {\n    if std::env::args().any(|a| a == \"--only-ttft\") {\n        gates.check();\n        return;\n    }\n    std::fs::write(OUT, text).unwrap();\n    gates.check();\n}\n";
        assert!(lint_one("rust/benches/bench_x.rs", only).is_empty(), "{:?}", lint_one("rust/benches/bench_x.rs", only));
        // Outside benches/ the rule does not apply.
        assert!(lint_one("rust/src/bench/gates.rs", bad).is_empty());
    }

    #[test]
    fn undocumented_invariant_requires_contract_doc() {
        let bad = "/// Opens a thing.\npub fn begin_window(&mut self) {}\n";
        let d = lint_one("rust/src/kv/region.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "undocumented-invariant");
        assert_eq!(d[0].line, 2);
        assert!(d[0].message.starts_with("pub item `begin_window` mentions `window`"));
        // Undocumented entirely is also a violation.
        let bare = "pub struct SlotWindow { id: u64 }\n";
        assert_eq!(lint_one("rust/src/kv/mod.rs", bare).len(), 1);
        // A doc comment stating the invariant passes (attributes between
        // doc and decl are fine).
        let clean = "/// Blocks pinned here can never be freed while the\n/// window is open.\n#[doc(hidden)]\npub fn begin_window(&mut self) {}\n";
        assert!(lint_one("rust/src/kv/region.rs", clean).is_empty());
        // Non-pub and needle-free items are out of scope.
        assert!(lint_one("rust/src/kv/region.rs", "fn begin_window() {}\npub fn append() {}\n")
            .is_empty());
        // Outside kv/ and serving/ the rule does not apply.
        assert!(lint_one("rust/src/sim/serving.rs", bad).is_empty());
    }

    #[test]
    fn unsafe_pin_counts_tokens_and_requires_forbid() {
        let bad = "pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let d = lint_one("rust/src/vgpu/pool.rs", bad);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "unsafe-pin");
        assert_eq!(d[0].line, 1);
        // lib.rs without the forbid attribute is itself a violation…
        let d = lint_one("rust/src/lib.rs", "pub mod kv;\n");
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("#![forbid(unsafe_code)]"));
        // …and with it, clean: the attribute's `unsafe_code` is not an
        // `unsafe` token (word boundary).
        let d = lint_one("rust/src/lib.rs", "#![forbid(unsafe_code)]\npub mod kv;\n");
        assert!(d.is_empty(), "{d:?}");
        // Mentions in comments and strings don't count.
        assert!(lint_one("rust/src/vgpu/pool.rs", "// unsafe is banned\nlet s = \"unsafe\";\n")
            .is_empty());
    }

    #[test]
    fn spec_commit_discipline_confines_the_rollback_seam() {
        let bad = "fn reap(store: &mut PagedKvStore, h: KvSeqHandle) {\n    store.scrub_uncommitted(h);\n    store.commit_provisional(h, 3);\n}\n";
        let d = lint_one("rust/src/serving/server.rs", bad);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "spec-commit-discipline"));
        assert!(d[0].message.contains("`scrub_uncommitted`"), "{}", d[0].message);
        assert!(d[1].message.contains("`commit_provisional`"), "{}", d[1].message);
        // The seam's owners are exempt: kv implements it, the runtime
        // step functions drive it, the checker explores it raw.
        assert!(lint_one("rust/src/kv/region.rs", bad).is_empty());
        assert!(lint_one("rust/src/runtime/tinylm.rs", bad).is_empty());
        assert!(lint_one("rust/src/check/model.rs", bad).is_empty());
        // Mentions in comments don't count.
        let comment = "// the step scrub_uncommitted()s on error\nfn f() {}\n";
        assert!(lint_one("rust/src/serving/server.rs", comment).is_empty());
    }

    #[test]
    fn device_actor_confinement_keeps_the_runtime_on_the_device_thread() {
        let bad = "fn plan(rt: &mut TinyLmRuntime) {\n    let _ = TinyLmRuntime::load(rt, \"dir\");\n}\n";
        let d = lint_one("rust/src/serving/server.rs", bad);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "device-actor-confinement"));
        assert_eq!(d[0].line, 1);
        assert!(d[0].message.contains("device actor owns the runtime"), "{}", d[0].message);
        // The device actor itself is the one legitimate home…
        assert!(lint_one("rust/src/serving/device.rs", bad).is_empty());
        // …and outside src/serving/ the rule does not apply (the runtime
        // layer defines the type; tests drive it directly).
        assert!(lint_one("rust/src/runtime/tinylm.rs", bad).is_empty());
        assert!(lint_one("rust/tests/serving_e2e.rs", bad).is_empty());
        // Doc comments naming the type are prose, not a coupling.
        let comment = "//! [`TinyLmRuntime::prefill_pack`] packs chunks.\nfn f() {}\n";
        assert!(lint_one("rust/src/serving/server.rs", comment).is_empty());
        // Longer identifiers containing the name don't count (word
        // boundary), but a generic parameter naming the type does.
        assert!(lint_one("rust/src/serving/server.rs", "fn f(x: TinyLmRuntimeExt) {}\n")
            .is_empty());
        assert_eq!(
            lint_one("rust/src/serving/registry.rs", "type R = FleetRuntime<TinyLmRuntime>;\n")
                .len(),
            1
        );
    }

    #[test]
    fn diagnostics_are_sorted_and_displayed_stably() {
        let files = vec![
            (
                "rust/src/sim/b.rs".to_string(),
                "fn f() { let t = Instant::now(); }\n".to_string(),
            ),
            (
                "rust/src/sim/a.rs".to_string(),
                "fn g() { let t = SystemTime::now(); }\n".to_string(),
            ),
        ];
        let d = lint_files(&files);
        assert_eq!(d.len(), 2);
        assert!(d[0].file.ends_with("a.rs"));
        assert_eq!(
            d[0].to_string(),
            "rust/src/sim/a.rs:1: [sim-wall-clock] wall-clock type `SystemTime` in sim code: \
             the simulator runs on virtual time only"
        );
        assert_eq!(exit_code(&d), 1);
        assert_eq!(exit_code(&[]), 0);
    }

    /// The linter's own acceptance bar: the repo at HEAD is clean. This
    /// runs in tier-1 (`cargo test`), so a PR that violates a contract
    /// fails CI even if it forgets to run `make check`.
    #[test]
    fn linter_is_clean_on_head() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
        let diags = lint_repo(&root).expect("lint walk succeeds");
        assert!(
            diags.is_empty(),
            "repo must be lint-clean, got {} diagnostics:\n{}",
            diags.len(),
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
