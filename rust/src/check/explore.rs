//! Bounded interleaving explorer: DFS over [`World`] schedules with a
//! CHESS-style context-switch bound and DPOR-lite pruning of commuting
//! steps. Deterministic by construction — no randomness, no clocks —
//! so every run over the same config and budget visits the same
//! schedules in the same order, and any [`Violation`] carries the exact
//! [`Schedule`] that [`replay`] reproduces step for step.
//!
//! **Switch bound.** A context switch is charged only when the schedule
//! moves to a different [`Actor`] *while the previous actor still had
//! enabled steps* — i.e. a preemption. Handing off from a blocked actor
//! is free, so the engine worker's normal plan→bind→reap round-robin
//! (one actor) and waiting on the device cost nothing; the bound limits
//! how adversarially arrivals and device completions may preempt the
//! worker. Empirically (CHESS) almost all concurrency bugs need very
//! few preemptions; the default bound of 8 is generous for this model.
//!
//! **DPOR-lite (sleep sets).** The only independent step pairs are the
//! device thread's `Submit`/`Exec` against a co-enabled step of another
//! actor: dequeue and completion each flip their own slot's stage flag
//! (plus the device-queue FIFO counters, which no co-enabled step of
//! another actor reads) and touch nothing any co-enabled step reads
//! (arena state changes only at plan/bind/reap). Two schedules
//! differing only in adjacent swaps of such pairs are the same
//! Mazurkiewicz trace, so after a branch is explored its first step
//! goes to *sleep* for the later sibling branches: a sleeping step is
//! pruned wherever it reappears, and the sleep set survives a step
//! only if the two commute (a dependent step wakes everything it
//! conflicts with). This keeps genuinely new orderings — e.g.
//! `exec·reap·plan`, where the reap *depends* on the exec — while
//! collapsing the exponential shuffle of where independent dequeues
//! and completions land. Nothing else commutes: arrivals reorder the
//! FIFO admission queue and every worker stage touches the arena.

use super::model::{Actor, CheckConfig, Fault, Step, TraceEvent, World};

/// A replayable schedule: at step `k`, the index picked from the
/// `enabled_steps()` vector of the state reached after `k` steps.
/// Displayed (and parsed) as dot-separated indices, e.g. `0.0.2.1`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Schedule(pub Vec<u16>);

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0.is_empty() {
            return write!(f, "(empty)");
        }
        for (i, c) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ".")?;
            }
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

impl std::str::FromStr for Schedule {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() || s == "(empty)" {
            return Ok(Schedule(Vec::new()));
        }
        let mut choices = Vec::new();
        for part in s.split('.') {
            choices.push(
                part.trim()
                    .parse::<u16>()
                    .map_err(|_| format!("schedule: bad choice {part:?} in {s:?}"))?,
            );
        }
        Ok(Schedule(choices))
    }
}

/// An invariant (or model) violation, with everything needed to
/// reproduce it deterministically.
#[derive(Clone, Debug)]
pub struct Violation {
    /// The schedule up to and including the offending step.
    pub schedule: Schedule,
    /// Index of the offending step within the schedule.
    pub step_index: usize,
    /// The step that was applied (None for setup/terminal failures).
    pub step: Option<Step>,
    /// Which invariant broke, from the catalog in DESIGN.md §6.
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.step {
            Some(s) => writeln!(
                f,
                "drift-check violation at step {} ({s}): {}",
                self.step_index, self.message
            )?,
            None => writeln!(f, "drift-check violation: {}", self.message)?,
        }
        writeln!(f, "  schedule: {}", self.schedule)?;
        write!(
            f,
            "  replay:   mldrift drift-check --replay {} (same --config/--fault flags)",
            self.schedule
        )
    }
}

impl std::error::Error for Violation {}

/// Exploration limits. All three are hard caps; hitting `max_schedules`
/// sets [`ExploreReport::truncated`] rather than failing.
#[derive(Clone, Copy, Debug)]
pub struct ExploreBudget {
    /// Maximum complete schedules (DFS leaves) to visit.
    pub max_schedules: u64,
    /// Maximum steps per schedule (guards preemption-churn livelock —
    /// schedules that exceed it are counted in `bounded_out`, not
    /// treated as violations, because readmission ping-pong is a real
    /// unbounded execution, not a safety bug).
    pub max_steps: usize,
    /// Maximum preemptive context switches per schedule.
    pub switch_bound: usize,
}

impl Default for ExploreBudget {
    fn default() -> Self {
        ExploreBudget { max_schedules: 20_000, max_steps: 96, switch_bound: 8 }
    }
}

/// What an exploration covered — printed by `mldrift drift-check`.
#[derive(Clone, Debug, Default)]
pub struct ExploreReport {
    /// Complete schedules visited (DFS leaves reaching terminal).
    pub schedules_explored: u64,
    /// States visited (including interior nodes).
    pub nodes: u64,
    /// Choices pruned as commuting with an earlier explored choice.
    pub pruned_commuting: u64,
    /// Choices skipped by the context-switch bound.
    pub switch_bound_skips: u64,
    /// Schedules cut at `max_steps` before reaching terminal.
    pub bounded_out: u64,
    /// Longest schedule seen.
    pub max_depth: usize,
    /// Schedules in which at least one preemption happened.
    pub preempting_schedules: u64,
    /// Schedules in which at least one free was deferred behind a window.
    pub deferring_schedules: u64,
    /// Schedules in which a copy-on-write privatization happened.
    pub cow_schedules: u64,
    /// Budget exhausted before the DFS finished.
    pub truncated: bool,
    /// The explored schedule with the most contention events
    /// (preemptions and deferred frees) — the one worth pinning as a
    /// regression, plus its score.
    pub trickiest: Option<(Schedule, u32)>,
}

impl std::fmt::Display for ExploreReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "schedules {} (nodes {}, max depth {}, truncated {})",
            self.schedules_explored, self.nodes, self.max_depth, self.truncated
        )?;
        writeln!(
            f,
            "pruned: {} commuting, {} switch-bounded, {} step-bounded",
            self.pruned_commuting, self.switch_bound_skips, self.bounded_out
        )?;
        writeln!(
            f,
            "coverage: {} preempting, {} deferring, {} cow schedules",
            self.preempting_schedules, self.deferring_schedules, self.cow_schedules
        )?;
        match &self.trickiest {
            Some((s, score)) => write!(f, "trickiest schedule (score {score}): {s}"),
            None => write!(f, "trickiest schedule: none"),
        }
    }
}

/// True when the two steps are independent — reordering them reaches
/// the same state, and applying one neither disables the other nor
/// changes what it does. Only the device thread's `Submit` and `Exec`
/// qualify (see module docs). The dependent same-slot chains
/// (`Bind(i)`/`Submit(i)`, `Submit(i)`/`Exec(i)`, `Exec(i)`/`Reap(i)`)
/// never reach this predicate together: they are mutually exclusive in
/// any enabled set, and a sleeping `Submit(i)`/`Exec(i)` keeps its slot
/// in the earlier stage, which keeps the later same-slot steps
/// disabled. The FIFO device-queue counters make `Submit(i)`/`Exec(j)`
/// and `Submit(i)`/`Submit(j)` mutually exclusive too, so the counter
/// reads never break commutativity between co-enabled steps.
fn commutes(a: Step, b: Step) -> bool {
    matches!(a, Step::Submit(_) | Step::Exec(_)) || matches!(b, Step::Submit(_) | Step::Exec(_))
}

struct Dfs<'a, F: FnMut(&World, &Schedule) -> Result<(), String>> {
    budget: &'a ExploreBudget,
    report: ExploreReport,
    path: Vec<u16>,
    on_terminal: F,
}

impl<F: FnMut(&World, &Schedule) -> Result<(), String>> Dfs<'_, F> {
    fn violation(&self, step: Option<Step>, message: String) -> Box<Violation> {
        Box::new(Violation {
            schedule: Schedule(self.path.clone()),
            step_index: self.path.len().saturating_sub(1),
            step,
            message,
        })
    }

    fn go(
        &mut self,
        world: &World,
        switches: usize,
        last: Option<Actor>,
        sleep: Vec<Step>,
    ) -> Result<(), Box<Violation>> {
        self.report.nodes += 1;
        if world.is_terminal() {
            self.report.schedules_explored += 1;
            self.report.max_depth = self.report.max_depth.max(self.path.len());
            if world.preemptions > 0 {
                self.report.preempting_schedules += 1;
            }
            if world.deferred_frees > 0 {
                self.report.deferring_schedules += 1;
            }
            if world.cow_seen() {
                self.report.cow_schedules += 1;
            }
            let score =
                world.preemptions * 3 + world.deferred_frees * 2 + u32::from(world.cow_seen());
            let better = match &self.report.trickiest {
                None => true,
                Some((_, best)) => score > *best,
            };
            if better {
                self.report.trickiest = Some((Schedule(self.path.clone()), score));
            }
            let sched = Schedule(self.path.clone());
            if let Err(msg) = (self.on_terminal)(world, &sched) {
                return Err(self.violation(None, msg));
            }
            return Ok(());
        }
        if self.path.len() >= self.budget.max_steps {
            self.report.bounded_out += 1;
            return Ok(());
        }
        let enabled = world.enabled_steps();
        if enabled.is_empty() {
            return Err(self.violation(
                None,
                "P3 deadlock: non-terminal state with no enabled step".to_string(),
            ));
        }
        // A choice is a preemptive switch when it changes actor while
        // the previous actor still has enabled steps.
        let prev_live =
            |l: Option<Actor>| l.is_some_and(|a| enabled.iter().any(|s| s.actor() == a));
        let mut sleep_now = sleep;
        for (j, &st) in enabled.iter().enumerate() {
            if self.report.schedules_explored >= self.budget.max_schedules {
                self.report.truncated = true;
                return Ok(());
            }
            // Sleep-set pruning: a sleeping step was already explored
            // first from an equivalent state (every step since then
            // commuted with it), so branches starting with it here are
            // redundant.
            if sleep_now.contains(&st) {
                self.report.pruned_commuting += 1;
                continue;
            }
            let is_switch = prev_live(last) && last != Some(st.actor());
            if is_switch && switches >= self.budget.switch_bound {
                self.report.switch_bound_skips += 1;
                continue;
            }
            // The chosen step wakes every sleeper it conflicts with;
            // only sleepers that commute with it stay asleep in the
            // child (their pruned orderings remain equivalent).
            let child_sleep: Vec<Step> =
                sleep_now.iter().copied().filter(|&s| commutes(s, st)).collect();
            let mut child = world.clone();
            self.path.push(j as u16);
            if let Err(msg) = child.apply_step(st).and_then(|()| child.check_invariants()) {
                return Err(self.violation(Some(st), msg));
            }
            self.go(&child, switches + usize::from(is_switch), Some(st.actor()), child_sleep)?;
            self.path.pop();
            // Explored: later sibling branches need not start with it.
            sleep_now.push(st);
        }
        Ok(())
    }
}

/// Explore every schedule of `cfg` within `budget`, checking the
/// invariant catalog after every step. `Err` carries the replayable
/// schedule of the first violation found (DFS order — deterministic).
pub fn explore(cfg: &CheckConfig, budget: &ExploreBudget) -> Result<ExploreReport, Box<Violation>> {
    explore_with(cfg, budget, |_, _| Ok(()))
}

/// [`explore`] with a per-terminal-state check (used by the projection
/// invariant; an `Err` from the callback becomes a violation carrying
/// that schedule).
pub fn explore_with<F>(
    cfg: &CheckConfig,
    budget: &ExploreBudget,
    on_terminal: F,
) -> Result<ExploreReport, Box<Violation>>
where
    F: FnMut(&World, &Schedule) -> Result<(), String>,
{
    let root = World::new(cfg).map_err(|e| {
        Box::new(Violation {
            schedule: Schedule(Vec::new()),
            step_index: 0,
            step: None,
            message: e,
        })
    })?;
    let mut dfs = Dfs { budget, report: ExploreReport::default(), path: Vec::new(), on_terminal };
    dfs.go(&root, 0, None, Vec::new())?;
    Ok(dfs.report)
}

/// Deterministically re-run one schedule, checking invariants after
/// every step. Returns the final world (for inspecting its trace and
/// counters) or the violation it reproduces.
pub fn replay(cfg: &CheckConfig, schedule: &Schedule) -> Result<World, Box<Violation>> {
    let mut world = World::new(cfg).map_err(|e| {
        Box::new(Violation {
            schedule: schedule.clone(),
            step_index: 0,
            step: None,
            message: e,
        })
    })?;
    for (k, &choice) in schedule.0.iter().enumerate() {
        let prefix = || Schedule(schedule.0[..=k].to_vec());
        let enabled = world.enabled_steps();
        if enabled.is_empty() {
            return Err(Box::new(Violation {
                schedule: prefix(),
                step_index: k,
                step: None,
                message: if world.is_terminal() {
                    "schedule continues past the terminal state".to_string()
                } else {
                    "P3 deadlock: non-terminal state with no enabled step".to_string()
                },
            }));
        }
        let st = match enabled.get(choice as usize) {
            Some(&s) => s,
            None => {
                return Err(Box::new(Violation {
                    schedule: prefix(),
                    step_index: k,
                    step: None,
                    message: format!(
                        "schedule choice {choice} out of range: {} steps enabled ({})",
                        enabled.len(),
                        enabled.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
                    ),
                }));
            }
        };
        if let Err(msg) = world.apply_step(st).and_then(|()| world.check_invariants()) {
            return Err(Box::new(Violation {
                schedule: prefix(),
                step_index: k,
                step: Some(st),
                message: msg,
            }));
        }
    }
    Ok(world)
}

/// Per-sequence projection of a trace — the unit P2 compares.
fn project(trace: &[TraceEvent], seqs: usize) -> Vec<Vec<TraceEvent>> {
    let mut out = vec![Vec::new(); seqs];
    for e in trace {
        out[e.seq()].push(e.clone());
    }
    out
}

/// P2 — depth projection: on a preemption-free configuration, every
/// schedule of the pipelined (depth ≥ 2) world must produce, for every
/// sequence, exactly the event trace of the serial depth-1 world. This
/// is the model analogue of the engine's
/// `pipelined_depth2_is_token_identical_to_depth1` e2e gate: planning
/// ahead may only *reserve* ahead, never change what gets committed.
///
/// The caller's config must be preemption-free (e.g.
/// [`CheckConfig::overlap`]): under memory pressure the pipelined world
/// legitimately preempts differently than the serial one (speculative
/// plans hold reservations longer), so projection equality is only an
/// invariant where no preemption is reachable — the check enforces this
/// precondition by failing on any preemption it sees.
pub fn depth_projection_check(
    cfg: &CheckConfig,
    budget: &ExploreBudget,
) -> Result<ExploreReport, Box<Violation>> {
    let mut base = cfg.clone();
    // Arrival order is scenario input, not schedule nondeterminism we
    // may vary while comparing traces across schedules.
    base.arrivals_upfront = true;
    base.fault = Fault::None;
    let mut d1 = base.clone();
    d1.depth = 1;
    let setup_violation = |message: String| {
        Box::new(Violation { schedule: Schedule(Vec::new()), step_index: 0, step: None, message })
    };
    let mut w = World::new(&d1).map_err(&setup_violation)?;
    let mut guard = 0usize;
    while !w.is_terminal() {
        let enabled = w.enabled_steps();
        if enabled.is_empty() {
            return Err(setup_violation(
                "P3 deadlock in the depth-1 canonical run".to_string(),
            ));
        }
        if let Err(msg) = w.apply_step(enabled[0]).and_then(|()| w.check_invariants()) {
            return Err(setup_violation(format!("depth-1 canonical run: {msg}")));
        }
        guard += 1;
        if guard > 100_000 {
            return Err(setup_violation(
                "depth-1 canonical run did not terminate".to_string(),
            ));
        }
    }
    if w.preemptions > 0 {
        return Err(setup_violation(format!(
            "P2 precondition: config must be preemption-free, depth-1 run preempted {} times",
            w.preemptions
        )));
    }
    let nseqs = base.seqs;
    let depth = base.depth;
    let canon = project(&w.trace, nseqs);
    explore_with(&base, budget, move |world, _| {
        if world.preemptions > 0 {
            return Err(format!(
                "P2 precondition: config must be preemption-free, depth-{depth} schedule \
                 preempted {} times",
                world.preemptions
            ));
        }
        let p = project(&world.trace, nseqs);
        for (i, (a, b)) in canon.iter().zip(p.iter()).enumerate() {
            if a != b {
                return Err(format!(
                    "P2 depth-projection mismatch for seq {i}: depth-1 trace {a:?} vs \
                     depth-{depth} trace {b:?}"
                ));
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_budget() -> ExploreBudget {
        ExploreBudget { max_schedules: 3_000, max_steps: 96, switch_bound: 4 }
    }

    #[test]
    fn schedule_roundtrips_through_display() {
        let s: Schedule = "0.3.1.2".parse().expect("parses");
        assert_eq!(s.0, vec![0, 3, 1, 2]);
        assert_eq!(s.to_string(), "0.3.1.2");
        let empty: Schedule = "".parse().expect("empty parses");
        assert_eq!(empty.0, Vec::<u16>::new());
        assert!("0.x.1".parse::<Schedule>().is_err());
    }

    #[test]
    fn contended_exploration_is_invariant_clean_and_reaches_contention() {
        let report = explore(&CheckConfig::contended(), &small_budget())
            .expect("no invariant violation on HEAD");
        assert!(report.schedules_explored > 10, "explored {report}");
        assert!(
            report.preempting_schedules > 0,
            "exploration must reach preemption: {report}"
        );
        assert!(
            report.deferring_schedules > 0,
            "exploration must reach deferred frees: {report}"
        );
        assert!(report.trickiest.is_some());
    }

    #[test]
    fn exploration_is_deterministic() {
        let a = explore(&CheckConfig::contended(), &small_budget()).expect("clean");
        let b = explore(&CheckConfig::contended(), &small_budget()).expect("clean");
        assert_eq!(a.schedules_explored, b.schedules_explored);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(
            a.trickiest.as_ref().map(|(s, sc)| (s.to_string(), *sc)),
            b.trickiest.as_ref().map(|(s, sc)| (s.to_string(), *sc))
        );
    }

    #[test]
    fn trickiest_schedule_replays_to_the_same_world() {
        let report = explore(&CheckConfig::contended(), &small_budget()).expect("clean");
        let (sched, score) = report.trickiest.expect("contention reached");
        let w = replay(&CheckConfig::contended(), &sched).expect("replay is clean");
        assert_eq!(
            w.preemptions * 3 + w.deferred_frees * 2 + u32::from(w.cow_seen()),
            score,
            "replay reproduces the explored world exactly"
        );
    }

    #[test]
    fn injected_free_inside_window_is_caught_with_a_replayable_schedule() {
        // Mutation test for the checker itself: reintroduce the
        // deferred-free bug the reservation windows exist to prevent
        // (frees completing while a window still pins the blocks) and
        // require the explorer to (a) catch it and (b) hand back a
        // schedule that deterministically reproduces it.
        let mut cfg = CheckConfig::contended();
        cfg.fault = Fault::FreeInsideWindow;
        let viol = match explore(&cfg, &small_budget()) {
            Err(v) => v,
            Ok(report) => panic!("fault injection must be caught, got clean report: {report}"),
        };
        assert!(
            viol.message.contains("K3")
                || viol.message.contains("free")
                || viol.message.contains("pinned"),
            "violation names the broken invariant: {}",
            viol.message
        );
        // And the schedule replays to the same violation.
        let replayed = match replay(&cfg, &viol.schedule) {
            Err(v) => v,
            Ok(_) => panic!("violating schedule must also fail under replay"),
        };
        assert_eq!(replayed.message, viol.message, "replay reproduces the violation");
        // The same schedule is clean without the fault: the bug is the
        // mutation, not the schedule.
        let clean_cfg = CheckConfig::contended();
        replay(&clean_cfg, &viol.schedule).expect("schedule is clean without the fault");
    }

    #[test]
    fn cow_window_exploration_reaches_privatization_under_a_window() {
        // The K7 scenario must actually reach its transition under
        // test: a copy-on-write privatization while a round's
        // reservation window is open (every plan after a bind runs
        // under the bound round's window, so any schedule admitting
        // the second sequence after the first published shares —
        // and then privatizes — the boundary block).
        let budget = ExploreBudget { max_schedules: 6_000, max_steps: 96, switch_bound: 6 };
        let report = explore(&CheckConfig::cow_window(), &budget)
            .expect("no invariant violation on HEAD");
        assert!(report.schedules_explored > 0, "explored {report}");
        assert!(
            report.cow_schedules > 0,
            "exploration must reach copy-on-write under an open window: {report}"
        );
    }

    #[test]
    fn injected_forgotten_cow_extension_is_caught_with_a_replayable_schedule() {
        // Mutation test for K7: undo the privatization-time window
        // extension and require the explorer to (a) catch the
        // disagreement between its shadow records and the arena's
        // window membership, with a schedule that (b) replays to the
        // same violation and (c) is clean without the fault.
        let budget = ExploreBudget { max_schedules: 6_000, max_steps: 96, switch_bound: 6 };
        let mut cfg = CheckConfig::cow_window();
        cfg.fault = Fault::PrivatizeWithoutExtension;
        let viol = match explore(&cfg, &budget) {
            Err(v) => v,
            Ok(report) => panic!("fault injection must be caught, got clean report: {report}"),
        };
        assert!(
            viol.message.contains("K7"),
            "violation names the broken invariant: {}",
            viol.message
        );
        let replayed = match replay(&cfg, &viol.schedule) {
            Err(v) => v,
            Ok(_) => panic!("violating schedule must also fail under replay"),
        };
        assert_eq!(replayed.message, viol.message, "replay reproduces the violation");
        replay(&CheckConfig::cow_window(), &viol.schedule)
            .expect("schedule is clean without the fault");
    }

    #[test]
    fn overlap_depth_projection_holds() {
        let report = depth_projection_check(&CheckConfig::overlap(), &small_budget())
            .expect("P2: depth-2 schedules project onto the depth-1 trace");
        assert!(report.schedules_explored > 0);
    }

    #[test]
    fn replay_rejects_out_of_range_choices() {
        let sched: Schedule = "40".parse().expect("parses");
        let err = replay(&CheckConfig::contended(), &sched).expect_err("choice 40 is invalid");
        assert!(err.message.contains("out of range"), "{}", err.message);
    }
}
