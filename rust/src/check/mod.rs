//! # drift-check — deterministic analysis for the pipelined KV engine
//!
//! PR 7's pipelined slot queue and PR 6's refcounted copy-on-write
//! prefix blocks put real concurrency *structure* into the serving
//! stack: a plan stage that runs admission, growth, and preemption
//! against speculated state while a dispatched round is still in
//! flight, reservation windows that defer frees under in-flight
//! gathers, and a reap stage that applies parked outcomes through
//! eviction-tolerant guards. Until this module, the only probe of that
//! race surface was the jittered `thread-stress` CI job — a
//! probabilistic smoke test. Before a second thread (the truly-async
//! device queue, multi-queue heterogeneous rounds — see ROADMAP) makes
//! every latent plan/reap/bind race real, the seams need a *systematic*
//! checker. This module holds two zero-dependency engines:
//!
//! * [`model`] + [`explore`] — a **bounded interleaving explorer**
//!   (loom-style, homegrown): the per-slot stage machine
//!   (PLAN → BIND → EXEC → REAP) and the KV arena's transition system
//!   (claim / grow / publish / attach / CoW-privatize / window-pin /
//!   deferred-free / release) modeled as explicit atomic steps driven
//!   by a replayable [`explore::Schedule`]. The state under test is the
//!   **real** [`crate::kv::KvArena`] — the model only supplies stage
//!   ordering and independent shadow bookkeeping. A DFS enumerates
//!   stage orderings up to a context-switch bound with DPOR-lite
//!   pruning of commuting steps, asserting the invariant catalog
//!   (DESIGN.md §6) after every step. A failure prints the exact
//!   schedule; [`explore::replay`] reproduces it deterministically.
//!
//! * [`lint`] — a **repo invariant linter** (`mldrift lint`,
//!   text/token-level, zero deps) for the cross-layer rules every PR
//!   has hand-maintained so far: sim code never reads wall clocks,
//!   KV allocation policy is only reached through the [`crate::kv::KvPool`]
//!   seam, bench gates assert only after their trajectory write, every
//!   `pub` window/provisional item in `kv/` and `serving/` documents
//!   its invariant, the crate-wide `unsafe` count stays pinned at
//!   zero (`#![forbid(unsafe_code)]`), and the speculative KV
//!   commit/rollback seam is driven only by the runtime step functions
//!   (serving code sees committed state only).
//!
//! Both engines run in tier-1 via `make check` (and the explorer's
//! regression schedules via `cargo test`). The linter walks the repo
//! with plain `std::fs`; the explorer needs nothing but the crate
//! itself.

pub mod explore;
pub mod lint;
pub mod model;

pub use explore::{
    depth_projection_check, explore, explore_with, replay, ExploreBudget, ExploreReport, Schedule,
    Violation,
};
pub use lint::{lint_files, lint_repo, LintDiagnostic};
pub use model::{CheckConfig, Fault, Step, TraceEvent, World};
