//! World model for the bounded interleaving explorer: the pipelined
//! executor's per-slot stage machine and the KV arena's transition
//! system as one deterministic step-transition system.
//!
//! The state under test is the **real** [`KvArena`] — not a
//! re-implementation. The model contributes three things the arena
//! cannot check about itself:
//!
//! 1. **Stage ordering.** Each pipeline slot cycles
//!    PLAN → BIND → EXEC → REAP with exactly the happens-before edges
//!    the engine worker has (`serving/server.rs::worker_loop_pipelined`):
//!    round `r + 1` is planned only after round `r` is bound (dispatch),
//!    and bound only after round `r` is reaped — but *execution
//!    completion* (the device) and *request arrivals* (other threads)
//!    interleave freely with planning and reaping. Those free
//!    interleavings are the race surface; the explorer enumerates them.
//! 2. **Shadow bookkeeping.** Independent per-sequence committed
//!    lengths, per-window pin sets, and refcount recounts derived from
//!    live block tables — so a drifting arena is caught by
//!    disagreement, not by its own (possibly equally wrong) counters.
//! 3. **The invariant catalog** (DESIGN.md §6), asserted by
//!    [`World::check_invariants`] after every step.
//!
//! Every stage is one *atomic* step because each engine actor is a
//! single thread whose stages never interleave internally; what can
//! reorder against a stage is the *other* actor's steps, which is
//! exactly the alphabet the model exposes. With the truly-async device
//! queue (PR 10) the model has **two** engine actors, mirroring
//! `serving/server.rs`: the policy worker runs PLAN → BIND for every
//! slot and REAP for completed rounds, while the device thread runs
//! SUBMIT (dequeue the bound round descriptor from the bounded
//! channel) → EXEC (complete it). Between BIND and SUBMIT the round
//! sits *in the channel* — the policy worker keeps planning against
//! it, so reservation windows must outlive cross-thread submission,
//! not just slot reap; K7 (privatization-time window extension) is
//! checked against exactly those interleavings.

use crate::error::DriftError;
use crate::kv::{shareable_prefix_keys, KvArena, KvArenaConfig, KvSeqHandle, PrefixKey};
use crate::util::div_ceil;

/// Deliberate bug injection for mutation-testing the explorer itself:
/// the acceptance bar is that the checker *catches* a reintroduced
/// free-inside-window with a replayable schedule, proving the invariant
/// catalog has teeth (see `explore::tests`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// No fault: the model drives the arena exactly as the engine does.
    None,
    /// At the end of every PLAN stage, complete all deferred frees
    /// immediately — ignoring the reservation-window pins that exist to
    /// defer them ([`KvArena::fault_free_deferred_ignoring_pins`]).
    /// This only *does* anything on schedules where a plan-stage
    /// preemption or completion hit a member of an in-flight round, so
    /// catching it requires actually exploring interleavings.
    FreeInsideWindow,
    /// After every capacity pass, undo the privatization-time window
    /// extensions the arena just recorded
    /// ([`KvArena::fault_forget_cow_extensions`]): a copy-on-write
    /// replacement block loses the pin that K7 says must protect it
    /// until the in-flight round's window closes. This only *does*
    /// anything on schedules where a plan- or bind-stage CoW hits a
    /// block pinned by a round that is bound, in the submission
    /// channel, or executing — the cross-thread race surface the
    /// two-actor split opens.
    PrivatizeWithoutExtension,
}

/// One scenario for the explorer: arena geometry, workload shape, and
/// the pipeline depth. Small numbers are the point — the explorer
/// enumerates interleavings exhaustively within its budget, so the
/// scenario must be the smallest world that still reaches the
/// transitions under test (attach, CoW, growth, preemption, deferred
/// free, retention revival).
#[derive(Clone, Debug)]
pub struct CheckConfig {
    /// Pipeline depth (concurrently in-flight rounds; the engine's
    /// `EngineConfig::pipeline_depth`). 1 = the serial loop.
    pub depth: usize,
    /// Number of requests.
    pub seqs: usize,
    /// Prompt tokens per request.
    pub prompt_tokens: usize,
    /// Decode tokens per request.
    pub new_tokens: usize,
    /// Prefill chunk quantum (tokens advanced per prefill round).
    pub chunk_tokens: usize,
    /// Arena blocks.
    pub blocks: usize,
    /// Tokens per arena block.
    pub block_tokens: usize,
    /// Max round members.
    pub max_batch: usize,
    /// Identical prompts — exercises publish/attach/CoW. The prompts
    /// are sized so the shared coverage ends mid-block, so the first
    /// divergent write *must* copy-on-write the boundary block.
    pub shared_prefix: bool,
    /// Prefix-retention LRU capacity (0 = off).
    pub retain_blocks: usize,
    /// All requests arrive before the first step (removes arrival
    /// nondeterminism — required by the depth-projection check, which
    /// compares traces across schedules).
    pub arrivals_upfront: bool,
    /// Tokens committed per decode round (≥ 1). 1 = plain decode; > 1
    /// models a speculative round's accepted run landing as one append
    /// — the fleet engine's KV shape, where a single decode step can
    /// demand multi-block growth mid-flight.
    pub spec_tokens_per_round: usize,
    /// Injected bug, if any.
    pub fault: Fault,
}

impl CheckConfig {
    /// The contention scenario `make check` explores: a tight arena
    /// where decode growth must preempt, preemption mid-flight defers
    /// frees behind the open reservation window, shared prompts attach
    /// and copy-on-write at the boundary block, and one retained block
    /// survives between waves. Arrivals are free steps, so admission
    /// interleaves with every stage. `max_batch` is deliberately one
    /// below `seqs`: a full-batch world has no active non-member left
    /// to evict, and the preemption/deferred-free transitions — the
    /// whole point of the scenario — would be unreachable.
    pub fn contended() -> Self {
        CheckConfig {
            depth: 2,
            seqs: 3,
            prompt_tokens: 4,
            new_tokens: 2,
            chunk_tokens: 2,
            blocks: 6,
            block_tokens: 2,
            max_batch: 2,
            shared_prefix: true,
            retain_blocks: 1,
            arrivals_upfront: false,
            spec_tokens_per_round: 1,
            fault: Fault::None,
        }
    }

    /// The overlap scenario for the depth-projection invariant (P2): a
    /// roomy arena (no preemption reachable) with upfront arrivals, so
    /// every depth-2 interleaving must produce exactly the depth-1
    /// trace per sequence — the model analogue of the engine's
    /// `pipelined_depth2_is_token_identical_to_depth1` e2e gate.
    pub fn overlap() -> Self {
        CheckConfig {
            depth: 2,
            seqs: 3,
            prompt_tokens: 4,
            new_tokens: 2,
            chunk_tokens: 2,
            blocks: 12,
            block_tokens: 2,
            max_batch: 3,
            shared_prefix: true,
            retain_blocks: 0,
            arrivals_upfront: true,
            spec_tokens_per_round: 1,
            fault: Fault::None,
        }
    }

    /// The privatization-under-submission scenario for K7: two
    /// sequences share a prefix whose coverage ends mid-block, and
    /// `max_batch` 1 alternates round membership — so the explorer can
    /// schedule sequence B's plan-stage copy-on-write of the shared
    /// boundary block while sequence A's round (whose window pins that
    /// block) is bound, sitting in the submission channel, or
    /// executing. The window must extend to pin B's replacement block
    /// for as long as the original. The arena is roomy on purpose:
    /// preemption stays out of the picture, CoW-against-an-in-flight-
    /// window is the only transition under test.
    pub fn cow_window() -> Self {
        CheckConfig {
            depth: 2,
            seqs: 2,
            prompt_tokens: 4,
            new_tokens: 2,
            chunk_tokens: 2,
            blocks: 8,
            block_tokens: 2,
            max_batch: 1,
            shared_prefix: true,
            retain_blocks: 0,
            arrivals_upfront: false,
            spec_tokens_per_round: 1,
            fault: Fault::None,
        }
    }

    /// The speculative scenario: decode rounds commit up to 3 accepted
    /// tokens as one append against the same tight arena as
    /// [`contended`](Self::contended), so a single decode step can
    /// demand multi-block growth while the in-flight round's window is
    /// open — the fleet engine's KV shape, where the window/deferred-
    /// free discipline must absorb k-token jumps, not single rows.
    pub fn speculative() -> Self {
        CheckConfig {
            depth: 2,
            seqs: 3,
            prompt_tokens: 4,
            new_tokens: 4,
            chunk_tokens: 2,
            blocks: 6,
            block_tokens: 2,
            max_batch: 2,
            shared_prefix: true,
            retain_blocks: 1,
            arrivals_upfront: false,
            spec_tokens_per_round: 3,
            fault: Fault::None,
        }
    }

    fn validate(&self) -> Result<(), String> {
        if self.depth == 0 || self.seqs == 0 || self.chunk_tokens == 0 || self.block_tokens == 0
        {
            return Err("check config: depth, seqs, chunk_tokens, block_tokens must be ≥ 1"
                .to_string());
        }
        if self.spec_tokens_per_round == 0 {
            return Err("check config: spec_tokens_per_round must be ≥ 1".to_string());
        }
        if self.prompt_tokens == 0 || self.new_tokens == 0 || self.max_batch == 0 {
            return Err(
                "check config: prompt_tokens, new_tokens, max_batch must be ≥ 1".to_string()
            );
        }
        // Every sequence must be able to finish alone, else the model
        // deadlocks by construction rather than by bug.
        let need = div_ceil(self.prompt_tokens + self.new_tokens, self.block_tokens);
        if need > self.blocks {
            return Err(format!(
                "check config: one sequence needs {need} blocks but the arena has {}",
                self.blocks
            ));
        }
        Ok(())
    }
}

/// One atomic transition of the world. `Arrive` models another thread
/// submitting a request; the four stage steps model the engine worker
/// and the device. The schedule (see [`crate::check::explore`]) picks
/// which enabled step fires next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    Arrive(usize),
    Plan(usize),
    Bind(usize),
    /// The device thread dequeues slot `i`'s bound round descriptor
    /// from the submission channel (the cross-thread handoff).
    Submit(usize),
    Exec(usize),
    Reap(usize),
}

/// Who performs a step — the unit the context-switch bound counts.
/// Mirrors the engine's real thread structure: one policy worker
/// thread runs every plan/bind/reap for every slot (so pipeline
/// round-robin is *not* a context switch), while the device thread's
/// dequeue/complete steps and request arrivals are the asynchronous
/// actors that preempt it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Actor {
    /// The outside world (request arrivals: client threads).
    Env,
    /// The policy worker thread (plan, bind, reap — all slots).
    Worker,
    /// The device thread dequeuing or completing slot `i`'s round.
    Device(usize),
}

impl Step {
    pub fn actor(&self) -> Actor {
        match *self {
            Step::Arrive(_) => Actor::Env,
            Step::Plan(_) | Step::Bind(_) | Step::Reap(_) => Actor::Worker,
            Step::Submit(s) | Step::Exec(s) => Actor::Device(s),
        }
    }
}

impl std::fmt::Display for Step {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Step::Arrive(i) => write!(f, "arrive({i})"),
            Step::Plan(s) => write!(f, "plan({s})"),
            Step::Bind(s) => write!(f, "bind({s})"),
            Step::Submit(s) => write!(f, "submit({s})"),
            Step::Exec(s) => write!(f, "exec({s})"),
            Step::Reap(s) => write!(f, "reap({s})"),
        }
    }
}

/// Observable event stream — what the depth-projection check compares.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    Admit { seq: usize, attached_tokens: usize },
    Commit { seq: usize, committed: usize },
    Preempt { seq: usize },
    Complete { seq: usize },
}

impl TraceEvent {
    pub fn seq(&self) -> usize {
        match *self {
            TraceEvent::Admit { seq, .. }
            | TraceEvent::Commit { seq, .. }
            | TraceEvent::Preempt { seq }
            | TraceEvent::Complete { seq } => seq,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SeqPhase {
    Unarrived,
    Waiting,
    Active,
    Done,
}

#[derive(Clone, Debug)]
struct SeqModel {
    prompt: Vec<i32>,
    keys: Vec<PrefixKey>,
    /// prompt + new tokens: committed positions at completion.
    target: usize,
    phase: SeqPhase,
    handle: Option<KvSeqHandle>,
    /// Shadow committed length — must mirror `arena.len(handle)` (K6).
    committed: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotStage {
    Idle,
    Planned,
    /// Bound and *enqueued*: the round descriptor is in the bounded
    /// submission channel, not yet dequeued by the device thread.
    Bound,
    /// Dequeued by the device thread; executing.
    Submitted,
    Executed,
}

/// A round member: fixed at plan (projected) and at bind (reconciled).
/// The handle is captured so a preempt-then-readmit between stages can
/// never be mistaken for the original membership (the engine's
/// generation-tag guard, mirrored).
#[derive(Clone, Copy, Debug)]
struct Member {
    seq: usize,
    /// Rows this round will commit for the sequence (P1 compares the
    /// plan's projection against the bind's reconciliation).
    rows: usize,
    /// Rows of reservation the capacity pass must secure — at plan this
    /// is in-flight rows *plus* `rows` (speculative: the plan reserves
    /// through the projected state), at bind just `rows`.
    need: usize,
    handle: KvSeqHandle,
}

#[derive(Clone, Debug)]
struct SlotModel {
    stage: SlotStage,
    round: usize,
    planned: Vec<Member>,
    bound: Vec<Member>,
    /// Open reservation window id (`KvSlotWindow::window_id`) — the
    /// token itself is deliberately `!Clone`, and DFS worlds clone.
    window: Option<u64>,
    /// Shadow pin set: every block the window pinned at bind. The K3
    /// check asserts none of these is ever on the free list while the
    /// window is open — independent of the arena's own pin counters.
    window_blocks: Vec<usize>,
}

impl SlotModel {
    fn idle() -> Self {
        SlotModel {
            stage: SlotStage::Idle,
            round: 0,
            planned: Vec::new(),
            bound: Vec::new(),
            window: None,
            window_blocks: Vec::new(),
        }
    }
}

/// The whole explorable state: real arena + model shadow. `Clone` is
/// what makes DFS branching cheap — the scenario keeps every Vec tiny.
#[derive(Clone, Debug)]
pub struct World {
    cfg: CheckConfig,
    arena: KvArena,
    seqs: Vec<SeqModel>,
    slots: Vec<SlotModel>,
    planned_rounds: usize,
    bound_rounds: usize,
    /// Rounds the device thread has dequeued from the submission
    /// channel — together with `executed_rounds` this encodes the
    /// single FIFO device thread: it finishes executing round `r`
    /// before it dequeues round `r + 1`.
    submitted_rounds: usize,
    executed_rounds: usize,
    reaped_rounds: usize,
    /// K7 shadow: `(window_id, replacement_block)` for every
    /// copy-on-write privatization that hit a block pinned by an open
    /// window — derived independently from the `ensure_detailed`
    /// outcome and the model's own `window_blocks`, then checked
    /// against the arena's
    /// window membership. Dropped when the window closes at reap.
    cow_pins: Vec<(u64, usize)>,
    /// Observable events, in order.
    pub trace: Vec<TraceEvent>,
    /// Preemptions performed (plan- or bind-stage capacity fights).
    pub preemptions: u32,
    /// Releases whose frees were deferred behind an open window.
    pub deferred_frees: u32,
}

impl World {
    pub fn new(cfg: &CheckConfig) -> Result<World, String> {
        cfg.validate()?;
        let mut arena = KvArena::new(KvArenaConfig {
            layers: 1,
            heads_kv: 1,
            head_dim: 2,
            block_tokens: cfg.block_tokens,
            num_blocks: cfg.blocks,
        });
        arena.set_prefix_retention(cfg.retain_blocks);
        let mut seqs = Vec::with_capacity(cfg.seqs);
        for i in 0..cfg.seqs {
            let prompt: Vec<i32> = if cfg.shared_prefix {
                vec![7; cfg.prompt_tokens]
            } else {
                (0..cfg.prompt_tokens).map(|j| (i * 31 + j) as i32 + 1).collect()
            };
            let keys = shareable_prefix_keys(&prompt, cfg.block_tokens);
            seqs.push(SeqModel {
                target: prompt.len() + cfg.new_tokens,
                prompt,
                keys,
                phase: if cfg.arrivals_upfront { SeqPhase::Waiting } else { SeqPhase::Unarrived },
                handle: None,
                committed: 0,
            });
        }
        Ok(World {
            cfg: cfg.clone(),
            arena,
            seqs,
            slots: (0..cfg.depth).map(|_| SlotModel::idle()).collect(),
            planned_rounds: 0,
            bound_rounds: 0,
            submitted_rounds: 0,
            executed_rounds: 0,
            reaped_rounds: 0,
            cow_pins: Vec::new(),
            trace: Vec::new(),
            preemptions: 0,
            deferred_frees: 0,
        })
    }

    /// All requests served, no slot mid-round.
    pub fn is_terminal(&self) -> bool {
        self.seqs.iter().all(|s| s.phase == SeqPhase::Done)
            && self.slots.iter().all(|s| s.stage == SlotStage::Idle)
    }

    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    pub fn done_seqs(&self) -> usize {
        self.seqs.iter().filter(|s| s.phase == SeqPhase::Done).count()
    }

    /// Whether any copy-on-write privatization happened (divergent
    /// write into an attached shared block) — read off the arena's own
    /// cumulative counter, which starts at zero per world.
    pub fn cow_seen(&self) -> bool {
        self.arena.cow_copies() > 0
    }

    /// Next prefill chunk (or one decode row) for a sequence at a given
    /// committed length — the plan's projection and the bind's
    /// reconciliation share this one formula, which is what makes P1
    /// (plan never under-reserves) hold: a surviving member's committed
    /// length at bind equals exactly the plan's projection (the
    /// in-flight outcome either landed in full or the handle changed
    /// and the member was dropped), so the reconciled rows equal the
    /// projected rows.
    fn rows_at(&self, i: usize, committed: usize) -> usize {
        let s = &self.seqs[i];
        if committed < s.prompt.len() {
            self.cfg.chunk_tokens.min(s.prompt.len() - committed)
        } else {
            // Decode: one round commits the accepted run as a single
            // append (spec_tokens_per_round = 1 is plain decode),
            // clamped so no token is ever committed past the target.
            self.cfg.spec_tokens_per_round.min(s.target - committed)
        }
    }

    /// Would a PLAN step make progress right now? Guards against
    /// planning empty rounds forever: there must be an active sequence
    /// with work left, or an admissible waiting head (admission is
    /// FIFO — a blocked head defers everyone behind it, exactly like
    /// the engine's deferred admission).
    fn plan_would_progress(&self) -> bool {
        if self
            .seqs
            .iter()
            .any(|s| s.phase == SeqPhase::Active && s.committed < s.target)
        {
            return true;
        }
        for s in &self.seqs {
            if s.phase == SeqPhase::Waiting {
                let keys: &[PrefixKey] =
                    if self.cfg.shared_prefix { &s.keys } else { &[] };
                return self.arena.can_claim_prefixed(s.prompt.len(), keys);
            }
        }
        false
    }

    /// The steps the schedule may choose from in this state. Encodes
    /// the engine's happens-before edges: plan(r+1) after bind(r),
    /// bind(r+1) after reap(r), submit(r) after bind(r) (the channel),
    /// exec(r) after submit(r), reap(r) after exec(r); the device
    /// thread's submit/exec and arrivals interleave freely with the
    /// policy worker's plan/bind/reap.
    pub fn enabled_steps(&self) -> Vec<Step> {
        let mut steps = Vec::new();
        for (i, s) in self.seqs.iter().enumerate() {
            if s.phase == SeqPhase::Unarrived {
                steps.push(Step::Arrive(i));
            }
        }
        if self.planned_rounds == self.bound_rounds
            && self.planned_rounds - self.reaped_rounds < self.cfg.depth
            && self.plan_would_progress()
        {
            let s = self.planned_rounds % self.cfg.depth;
            if self.slots[s].stage == SlotStage::Idle {
                steps.push(Step::Plan(s));
            }
        }
        for (si, slot) in self.slots.iter().enumerate() {
            match slot.stage {
                SlotStage::Planned => {
                    if self.reaped_rounds >= slot.round {
                        steps.push(Step::Bind(si));
                    }
                }
                SlotStage::Bound => {
                    // The single device thread dequeues in submission
                    // order and only after finishing the previous round.
                    if slot.round == self.submitted_rounds
                        && self.submitted_rounds == self.executed_rounds
                    {
                        steps.push(Step::Submit(si));
                    }
                }
                SlotStage::Submitted => steps.push(Step::Exec(si)),
                SlotStage::Executed => {
                    if self.reaped_rounds == slot.round {
                        steps.push(Step::Reap(si));
                    }
                }
                SlotStage::Idle => {}
            }
        }
        steps
    }

    /// Apply one step. `Err` is a model-detected violation (P1, a
    /// reservation the arena rejected after its gate passed, an
    /// un-enabled step in a replayed schedule, …) — the explorer turns
    /// it into a [`crate::check::Violation`] with the schedule attached.
    pub fn apply_step(&mut self, step: Step) -> Result<(), String> {
        match step {
            Step::Arrive(i) => {
                if self.seqs[i].phase != SeqPhase::Unarrived {
                    return Err(format!("arrive({i}) applied twice"));
                }
                self.seqs[i].phase = SeqPhase::Waiting;
                Ok(())
            }
            Step::Plan(s) => self.plan(s),
            Step::Bind(s) => self.bind(s),
            Step::Submit(s) => {
                if self.slots[s].stage != SlotStage::Bound {
                    return Err(format!("submit({s}) on a slot that is not bound"));
                }
                if self.slots[s].round != self.submitted_rounds
                    || self.submitted_rounds != self.executed_rounds
                {
                    return Err(format!("submit({s}) out of FIFO device-queue order"));
                }
                // Device dequeue: the round descriptor leaves the
                // bounded channel. Nothing arena-visible changes — the
                // point is that the window opened at bind has been
                // protecting the round across the cross-thread handoff.
                self.slots[s].stage = SlotStage::Submitted;
                self.submitted_rounds += 1;
                Ok(())
            }
            Step::Exec(s) => {
                if self.slots[s].stage != SlotStage::Submitted {
                    return Err(format!("exec({s}) on a round the device has not dequeued"));
                }
                // Device completion: the kernel's writes land in rows
                // the bind reserved and the window pins — nothing
                // arena-visible changes until the reap applies them.
                self.slots[s].stage = SlotStage::Executed;
                self.executed_rounds += 1;
                Ok(())
            }
            Step::Reap(s) => self.reap(s),
        }
    }

    /// Lowest-progress-youngest victim among active sequences (the
    /// scheduler's `choose_victim` shape, minus FIFO-head pinning —
    /// starvation policy is out of scope here, memory safety is not).
    /// `exclude` are sequences that must keep their reservations (the
    /// member being grown, or the round being bound).
    fn choose_victim(&self, exclude: &[usize]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.seqs.len() {
            if exclude.contains(&i) || self.seqs[i].phase != SeqPhase::Active {
                continue;
            }
            best = match best {
                None => Some(i),
                Some(b) => {
                    let (cb, ci) = (self.seqs[b].committed, self.seqs[i].committed);
                    if ci < cb || (ci == cb && i > b) {
                        Some(i)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        best
    }

    /// Preempt `v`: release its blocks (deferred when an open window
    /// pins them — the transition under test), park it for
    /// re-admission, reset progress (recompute semantics: re-prefill
    /// reproduces everything it lost, same contract as the engine).
    fn preempt(&mut self, v: usize) {
        let h = self.seqs[v].handle.take().expect("victim must hold a handle");
        let before = self.arena.deferred_blocks();
        let _ = self.arena.release_blocks(h);
        if self.arena.deferred_blocks() > before {
            self.deferred_frees += 1;
        }
        let s = &mut self.seqs[v];
        s.phase = SeqPhase::Waiting;
        s.committed = 0;
        self.preemptions += 1;
        self.trace.push(TraceEvent::Preempt { seq: v });
    }

    /// Reserve `rows` for every member, preempting victims on
    /// exhaustion — the shared capacity loop both PLAN (projected
    /// needs) and BIND (reconciled needs) run, exactly like the
    /// engine's `ensure_round_capacity` is one function called from
    /// both stages. A member with no victim left is dropped from the
    /// round (deferred, not failed). Restarts from the front after any
    /// preemption: `ensure` is idempotent for already-reserved rows,
    /// and each restart has strictly fewer active sequences, so the
    /// loop terminates.
    fn ensure_members(&mut self, members: &mut Vec<Member>) -> Result<(), String> {
        let mut idx = 0;
        while idx < members.len() {
            let m = members[idx];
            if self.seqs[m.seq].handle != Some(m.handle) {
                members.remove(idx);
                continue;
            }
            match self.arena.ensure_detailed(m.handle, m.need) {
                Ok(outcome) => {
                    // K7 shadow: every open window that pinned a
                    // privatized block must now also pin its
                    // replacement — record the expectation from the
                    // model's own window sets, independent of the
                    // arena's extension bookkeeping.
                    for &(old, new, _) in &outcome.cow {
                        for slot in self.slots.iter_mut() {
                            if let Some(id) = slot.window {
                                if slot.window_blocks.contains(&old) {
                                    slot.window_blocks.push(new);
                                    self.cow_pins.push((id, new));
                                }
                            }
                        }
                    }
                    idx += 1;
                }
                Err(DriftError::Memory(_)) => {
                    let keep: Vec<usize> = members.iter().map(|p| p.seq).collect();
                    match self.choose_victim(&keep) {
                        Some(v) => {
                            self.preempt(v);
                            idx = 0;
                        }
                        None => {
                            members.remove(idx);
                        }
                    }
                }
                Err(e) => {
                    return Err(format!(
                        "ensure(seq {}, {} rows): unexpected error: {e}",
                        m.seq, m.need
                    ))
                }
            }
        }
        if self.cfg.fault == Fault::PrivatizeWithoutExtension {
            self.arena.fault_forget_cow_extensions();
        }
        Ok(())
    }

    /// PLAN: admission (FIFO, prefix-attaching, dedup-aware gate),
    /// projected membership, and the plan-stage capacity pass — all
    /// against state that may still have a round in flight, so a victim
    /// may be an in-flight member (its outcome is dropped at reap, its
    /// blocks stay pinned until the window closes).
    fn plan(&mut self, si: usize) -> Result<(), String> {
        if self.slots[si].stage != SlotStage::Idle {
            return Err(format!("plan({si}) on a busy slot"));
        }
        // Admission: paged shape — gate and claim the *context* only,
        // decode grows block-by-block (that growth is where preemption
        // lives). Attached prefix blocks skip their prefill: committed
        // starts at the attach coverage.
        for i in 0..self.seqs.len() {
            if self.seqs[i].phase != SeqPhase::Waiting {
                continue;
            }
            let claim_tokens = self.seqs[i].prompt.len();
            let keys: Vec<PrefixKey> = if self.cfg.shared_prefix {
                self.seqs[i].keys.clone()
            } else {
                Vec::new()
            };
            if !self.arena.can_claim_prefixed(claim_tokens, &keys) {
                break; // FIFO: a blocked head defers everyone behind it
            }
            let (h, _attached_blocks) = self
                .arena
                .claim_prefixed_detailed(claim_tokens, &keys)
                .map_err(|e| format!("admission claim for seq {i} failed after its gate passed: {e}"))?;
            let attached_tokens = self.arena.len(h);
            let s = &mut self.seqs[i];
            s.handle = Some(h);
            s.committed = attached_tokens;
            s.phase = SeqPhase::Active;
            self.trace.push(TraceEvent::Admit { seq: i, attached_tokens });
        }
        // Speculative projection (PR 7's plan-ahead): the plan assumes
        // the in-flight round lands, so each sequence is projected
        // forward by its in-flight rows and the plan-stage ensure
        // reserves *through* the projected round. This is precisely
        // where growth — and therefore preemption, and therefore
        // deferred frees — can happen while the in-flight round's
        // reservation window is still open.
        let mut inflight: Vec<usize> = vec![0; self.seqs.len()];
        for slot in &self.slots {
            if matches!(
                slot.stage,
                SlotStage::Bound | SlotStage::Submitted | SlotStage::Executed
            ) {
                for m in &slot.bound {
                    if self.seqs[m.seq].handle == Some(m.handle) {
                        inflight[m.seq] += m.rows;
                    }
                }
            }
        }
        // Membership rotates with the round (the scheduler's fairness
        // rotation): without rotation the same sequences are members
        // forever and a pinned in-flight member could never become a
        // preemption victim.
        let n = self.seqs.len();
        let mut planned: Vec<Member> = Vec::new();
        for k in 0..n {
            if planned.len() >= self.cfg.max_batch {
                break;
            }
            let i = (self.planned_rounds + k) % n;
            let s = &self.seqs[i];
            if s.phase != SeqPhase::Active {
                continue;
            }
            let projected = s.committed + inflight[i];
            if projected >= s.target {
                continue; // projected to complete at the in-flight reap
            }
            let rows = self.rows_at(i, projected);
            planned.push(Member {
                seq: i,
                rows,
                need: inflight[i] + rows,
                handle: s.handle.expect("active sequence holds a handle"),
            });
        }
        self.ensure_members(&mut planned)?;
        if self.cfg.fault == Fault::FreeInsideWindow && self.arena.deferred_blocks() > 0 {
            self.arena.fault_free_deferred_ignoring_pins();
        }
        let slot = &mut self.slots[si];
        slot.stage = SlotStage::Planned;
        slot.round = self.planned_rounds;
        slot.planned = planned;
        self.planned_rounds += 1;
        Ok(())
    }

    /// BIND: reconcile the projected round against now-authoritative
    /// state (the previous round has been reaped), assert P1, re-run
    /// the capacity pass for rows the reap consumed, and open the
    /// reservation window over every surviving member's block table.
    fn bind(&mut self, si: usize) -> Result<(), String> {
        if self.slots[si].stage != SlotStage::Planned {
            return Err(format!("bind({si}) on a slot that is not planned"));
        }
        let planned = self.slots[si].planned.clone();
        let mut bound: Vec<Member> = Vec::new();
        for m in &planned {
            let s = &self.seqs[m.seq];
            if s.handle != Some(m.handle) || s.phase != SeqPhase::Active {
                continue; // preempted at plan: dropped from the round
            }
            if s.committed >= s.target {
                continue; // completed at the previous reap
            }
            let rows = self.rows_at(m.seq, s.committed);
            if rows > m.rows {
                return Err(format!(
                    "P1 plan under-reserved: seq {} planned {} rows, bind needs {rows}",
                    m.seq, m.rows
                ));
            }
            bound.push(Member { seq: m.seq, rows, need: rows, handle: m.handle });
        }
        self.ensure_members(&mut bound)?;
        let mut blocks: Vec<usize> = Vec::new();
        for m in &bound {
            let t = self
                .arena
                .block_table(m.handle)
                .map_err(|e| format!("bind block_table(seq {}): {e}", m.seq))?;
            blocks.extend_from_slice(t);
        }
        let token = self.arena.pin_window(&blocks);
        let slot = &mut self.slots[si];
        slot.window = Some(token.window_id());
        slot.window_blocks = blocks;
        slot.bound = bound;
        slot.stage = SlotStage::Bound;
        self.bound_rounds += 1;
        Ok(())
    }

    /// REAP: apply the round's outcomes through the same
    /// eviction-tolerant guard as the engine (a member whose handle
    /// changed since bind was preempted mid-flight — its outcome is
    /// dropped), publish newly committed prefix slices, release
    /// completed sequences (deferred behind the still-open window),
    /// then close the window, completing every deferred free whose
    /// last pin dropped.
    fn reap(&mut self, si: usize) -> Result<(), String> {
        if self.slots[si].stage != SlotStage::Executed {
            return Err(format!("reap({si}) on a slot that has not executed"));
        }
        let bound = std::mem::take(&mut self.slots[si].bound);
        for m in &bound {
            if self.seqs[m.seq].handle != Some(m.handle) {
                continue; // dropped outcome; re-prefill recomputes it
            }
            self.arena.append(m.handle, m.rows).map_err(|e| {
                format!(
                    "reap append(seq {}, {} rows) failed though bind reserved them: {e}",
                    m.seq, m.rows
                )
            })?;
            self.seqs[m.seq].committed += m.rows;
            self.trace.push(TraceEvent::Commit {
                seq: m.seq,
                committed: self.seqs[m.seq].committed,
            });
            if self.cfg.shared_prefix {
                let keys = self.seqs[m.seq].keys.clone();
                self.arena
                    .publish_prefix(m.handle, &keys)
                    .map_err(|e| format!("reap publish(seq {}): {e}", m.seq))?;
            }
            if self.seqs[m.seq].committed == self.seqs[m.seq].target {
                let h = self.seqs[m.seq].handle.take().expect("guarded above");
                let before = self.arena.deferred_blocks();
                let _ = self.arena.release_blocks(h);
                if self.arena.deferred_blocks() > before {
                    self.deferred_frees += 1;
                }
                self.seqs[m.seq].phase = SeqPhase::Done;
                self.trace.push(TraceEvent::Complete { seq: m.seq });
            }
        }
        let id = self
            .slots[si]
            .window
            .take()
            .ok_or_else(|| format!("reap({si}): no open reservation window"))?;
        if self.arena.unpin_window_raw(id).is_none() {
            return Err(format!("reap({si}): window {id} was already closed"));
        }
        self.cow_pins.retain(|&(w, _)| w != id);
        let slot = &mut self.slots[si];
        slot.window_blocks.clear();
        slot.planned.clear();
        slot.stage = SlotStage::Idle;
        self.reaped_rounds += 1;
        Ok(())
    }

    /// The invariant catalog (DESIGN.md §6), asserted after every step.
    /// K1/K5 delegate to the arena's own structural `verify`; K2, K3
    /// and K6 are *shadow* checks computed from model state, so arena
    /// bookkeeping bugs are caught by disagreement.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.arena
            .verify()
            .map_err(|e| format!("K1/K5 arena structural verify: {e}"))?;
        // K2: refcounts agree exactly with live block-table references.
        let nb = self.arena.config().num_blocks;
        let mut counts = vec![0u32; nb];
        for (i, s) in self.seqs.iter().enumerate() {
            if let Some(h) = s.handle {
                let table = self
                    .arena
                    .block_table(h)
                    .map_err(|e| format!("K4 live handle of seq {i} rejected: {e}"))?;
                for &b in table {
                    counts[b] += 1;
                }
            }
        }
        for (b, &c) in counts.iter().enumerate() {
            let rc = self.arena.block_refcount(b);
            if c != rc {
                return Err(format!(
                    "K2 refcount drift on block {b}: {c} live table references vs arena refcount {rc}"
                ));
            }
        }
        // K3: no free inside an open reservation window.
        for (si, slot) in self.slots.iter().enumerate() {
            if slot.window.is_some() {
                for &b in &slot.window_blocks {
                    if self.arena.is_block_free(b) {
                        return Err(format!(
                            "K3 block {b} freed inside slot {si}'s open reservation window"
                        ));
                    }
                }
            }
        }
        // K7: privatization-time window extension — every copy-on-write
        // replacement whose original was pinned by an open window must
        // itself be pinned by that window until it closes. The records
        // come from the model's shadow (ensure outcome × window sets);
        // the membership is the arena's own, so a forgotten extension
        // is caught by disagreement.
        for &(id, b) in &self.cow_pins {
            if !self.arena.window_pins_block(id, b) {
                return Err(format!(
                    "K7 copy-on-write replacement block {b} is not pinned by open window {id} \
                     after privatization"
                ));
            }
        }
        // K6: shadow committed lengths mirror the arena exactly.
        for (i, s) in self.seqs.iter().enumerate() {
            if let Some(h) = s.handle {
                let l = self.arena.len(h);
                if l != s.committed {
                    return Err(format!(
                        "K6 committed-length drift on seq {i}: model {} vs arena {l}",
                        s.committed
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Greedy serial run: always apply the first enabled step.
    fn run_serial(cfg: &CheckConfig) -> World {
        let mut w = World::new(cfg).expect("valid config");
        let mut steps = 0;
        while !w.is_terminal() {
            let enabled = w.enabled_steps();
            assert!(!enabled.is_empty(), "P3 deadlock: no enabled step in a non-terminal state");
            w.apply_step(enabled[0]).expect("serial step applies");
            w.check_invariants().expect("invariants after serial step");
            steps += 1;
            assert!(steps < 10_000, "serial run did not terminate");
        }
        w
    }

    #[test]
    fn contended_serial_run_drains_and_stays_invariant_clean() {
        let w = run_serial(&CheckConfig::contended());
        assert_eq!(w.done_seqs(), 3);
        assert_eq!(w.arena().seq_count(), 0, "drained arena holds no sequences");
        // The scenario is sized so even the first-choice schedule hits
        // the transitions under test: decode growth exhausts the arena
        // (→ preemption of the non-member sequence), and completions
        // release while their own round's window is still open
        // (→ deferred frees).
        assert!(w.preemptions >= 1, "contended scenario must preempt, got {}", w.preemptions);
        assert!(
            w.deferred_frees >= 1,
            "completion under an open window must defer frees, got {}",
            w.deferred_frees
        );
    }

    #[test]
    fn speculative_serial_run_commits_multi_token_rounds() {
        let w = run_serial(&CheckConfig::speculative());
        assert_eq!(w.done_seqs(), 3);
        assert_eq!(w.arena().seq_count(), 0, "drained arena holds no sequences");
        // The point of the scenario: at least one decode commit jumps
        // by more than one token (an accepted speculative run landing
        // as a single append), and no commit ever overshoots a target.
        let mut last: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
        let mut multi = false;
        for ev in &w.trace {
            if let TraceEvent::Commit { seq, committed } = *ev {
                let prev = last.insert(seq, committed).unwrap_or(0);
                if committed > 4 && committed - prev.max(4) > 1 {
                    multi = true;
                }
                assert!(committed <= 4 + 4, "seq {seq} committed past its target");
            }
        }
        assert!(multi, "speculative scenario must commit a multi-token decode round");
        // The tight arena still preempts under multi-token growth.
        assert!(w.preemptions >= 1, "speculative scenario must preempt, got {}", w.preemptions);
    }

    #[test]
    fn overlap_serial_run_is_preemption_free() {
        let w = run_serial(&CheckConfig::overlap());
        assert_eq!(w.done_seqs(), 3);
        assert_eq!(w.preemptions, 0, "roomy arena must never preempt");
    }

    #[test]
    fn depth1_config_has_singleton_schedules() {
        let mut cfg = CheckConfig::overlap();
        cfg.depth = 1;
        let mut w = World::new(&cfg).expect("valid config");
        while !w.is_terminal() {
            let enabled = w.enabled_steps();
            assert_eq!(
                enabled.len(),
                1,
                "depth-1 + upfront arrivals must be fully deterministic, got {enabled:?}"
            );
            w.apply_step(enabled[0]).expect("step applies");
            w.check_invariants().expect("invariants hold");
        }
    }

    #[test]
    fn shared_prefix_attaches_on_second_wave() {
        // Serial contended run: the prompts are identical, so once the
        // first sequence publishes its prefix the later admissions must
        // attach a nonzero coverage.
        let w = run_serial(&CheckConfig::contended());
        let attached: Vec<usize> = w
            .trace
            .iter()
            .filter_map(|e| match *e {
                TraceEvent::Admit { attached_tokens, .. } => Some(attached_tokens),
                _ => None,
            })
            .collect();
        assert!(attached.len() >= 3, "every sequence admits at least once");
        assert!(
            attached.iter().any(|&a| a > 0),
            "identical prompts must attach published prefix blocks at least once: {attached:?}"
        );
    }

    #[test]
    fn cow_window_serial_run_drains_and_stays_invariant_clean() {
        // The greedy schedule arrives both sequences before the first
        // plan, so they admit unshared — the scenario's CoW transition
        // needs the explorer to delay the second arrival past the
        // first publish (covered in `explore::tests`). Here: the
        // roomy-arena preset drains clean with no preemption.
        let w = run_serial(&CheckConfig::cow_window());
        assert_eq!(w.done_seqs(), 2);
        assert_eq!(w.arena().seq_count(), 0, "drained arena holds no sequences");
        assert_eq!(w.preemptions, 0, "roomy arena must never preempt");
    }

    #[test]
    fn device_queue_is_fifo_and_submit_gates_exec() {
        // Drive the overlap world to the first bound round, then
        // check the two-actor alphabet: a bound round must be
        // submitted (dequeued by the device thread) before it can
        // execute, and stages advance Bound → Submitted → Executed.
        let mut w = World::new(&CheckConfig::overlap()).expect("valid config");
        loop {
            let enabled = w.enabled_steps();
            if let Some(&submit) = enabled.iter().find(|s| matches!(s, Step::Submit(_))) {
                assert!(
                    !enabled.iter().any(|s| matches!(s, Step::Exec(_))),
                    "exec must not be enabled before the device dequeues: {enabled:?}"
                );
                let Step::Submit(si) = submit else { unreachable!() };
                assert!(w.apply_step(Step::Exec(si)).is_err(), "exec before submit rejected");
                w.apply_step(submit).expect("submit applies");
                w.check_invariants().expect("invariants after submit");
                let enabled = w.enabled_steps();
                assert!(
                    enabled.contains(&Step::Exec(si)),
                    "dequeued round becomes executable: {enabled:?}"
                );
                assert!(
                    !enabled.iter().any(|s| matches!(s, Step::Submit(_))),
                    "FIFO device thread dequeues one round at a time: {enabled:?}"
                );
                return;
            }
            w.apply_step(enabled[0]).expect("step applies");
            w.check_invariants().expect("invariants hold");
        }
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let mut cfg = CheckConfig::contended();
        cfg.blocks = 1; // one sequence alone cannot fit
        assert!(World::new(&cfg).is_err());
        let mut cfg = CheckConfig::contended();
        cfg.chunk_tokens = 0;
        assert!(World::new(&cfg).is_err());
    }

    #[test]
    fn un_enabled_steps_are_rejected_not_applied() {
        let mut w = World::new(&CheckConfig::contended()).expect("valid config");
        // Nothing has been planned: binding slot 0 is a model error.
        assert!(w.apply_step(Step::Bind(0)).is_err());
        assert!(w.apply_step(Step::Submit(0)).is_err());
        assert!(w.apply_step(Step::Exec(0)).is_err());
        assert!(w.apply_step(Step::Reap(0)).is_err());
    }
}
