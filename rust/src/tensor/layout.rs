//! Slice-aware memory layouts (paper §3.1).
//!
//! ML Drift organizes GPU-resident data into contiguous **4-channel slices**
//! to exploit 4-element SIMD. A layout is a *permutation* of slice-aware
//! dimensions; the physical linear order is the mixed-radix number system
//! over that permutation (outermost dimension first).
//!
//! Activation layouts permute `{B, H, W, D, S, C4}` — e.g. the paper's
//! `PHWC4`, `HSWBDC4` (2D-texture friendly: H outermost gives automatic zero
//! clamp on H), and `DSHWBC4` (3D-texture / image-buffer friendly).
//!
//! Weight layouts permute `(G, S_O, O4, H, W, D, S_I, I4)` where
//! `G · S_O = ceil(O/4)` — the paper's `(G, S_O, O4, HWD, S_I, I4)` family.
//! `G` is a kernel-design-dependent output-slice grouping factor (a kernel
//! computing `G` output slices per workgroup wants those slices adjacent).

use crate::error::{DriftError, Result};
use crate::tensor::shape::Shape;

/// One dimension of an activation layout permutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActDim {
    B,
    H,
    W,
    D,
    /// Slice index: `floor(C / 4)`.
    S,
    /// Index within a slice: `C mod 4`. Extent is always 4 (zero-padded).
    C4,
}

/// An activation memory layout: an ordered permutation of all six
/// slice-aware dimensions, outermost first.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ActivationLayout {
    pub name: String,
    pub order: Vec<ActDim>,
}

impl ActivationLayout {
    /// Construct and validate a layout from a permutation.
    pub fn new(name: &str, order: Vec<ActDim>) -> Result<Self> {
        use ActDim::*;
        for required in [B, H, W, D, S, C4] {
            if order.iter().filter(|d| **d == required).count() != 1 {
                return Err(DriftError::Layout(format!(
                    "layout {name}: dimension {required:?} must appear exactly once"
                )));
            }
        }
        if order.len() != 6 {
            return Err(DriftError::Layout(format!("layout {name}: expected 6 dims")));
        }
        Ok(ActivationLayout { name: name.to_string(), order })
    }

    /// `PHWC4` — the classic mobile-GPU buffer layout [26]: batch, then
    /// 4-channel planes, each plane HW-major. (D folded next to B; D=1 for
    /// non-3D-conv tensors.)
    pub fn phwc4() -> Self {
        use ActDim::*;
        Self::new("PHWC4", vec![B, D, S, H, W, C4]).unwrap()
    }

    /// `HSWBDC4` — 2D-texture layout: H outermost (y axis), so texture
    /// sampling clamps H automatically (paper §3.1).
    pub fn hswbdc4() -> Self {
        use ActDim::*;
        Self::new("HSWBDC4", vec![H, S, W, B, D, C4]).unwrap()
    }

    /// `DSHWBC4` — 3D-texture / linear image-buffer layout (paper Fig. 1).
    pub fn dshwbc4() -> Self {
        use ActDim::*;
        Self::new("DSHWBC4", vec![D, S, H, W, B, C4]).unwrap()
    }

    /// Extent of a layout dimension for a given logical shape.
    pub fn extent(shape: &Shape, dim: ActDim) -> usize {
        match dim {
            ActDim::B => shape.b,
            ActDim::H => shape.h,
            ActDim::W => shape.w,
            ActDim::D => shape.d,
            ActDim::S => shape.slices(),
            ActDim::C4 => 4,
        }
    }

    /// Total padded element count under this layout.
    pub fn padded_elements(&self, shape: &Shape) -> usize {
        self.order.iter().map(|d| Self::extent(shape, *d)).product()
    }

    /// Linear physical index of logical `(b, h, w, d, c)`.
    pub fn linear_index(
        &self,
        shape: &Shape,
        b: usize,
        h: usize,
        w: usize,
        d: usize,
        c: usize,
    ) -> usize {
        debug_assert!(
            b < shape.b && h < shape.h && w < shape.w && d < shape.d && c < shape.c,
            "coords ({b},{h},{w},{d},{c}) out of bounds for {shape}"
        );
        let coord = |dim: ActDim| -> usize {
            match dim {
                ActDim::B => b,
                ActDim::H => h,
                ActDim::W => w,
                ActDim::D => d,
                ActDim::S => c / 4,
                ActDim::C4 => c % 4,
            }
        };
        let mut idx = 0;
        for dim in &self.order {
            idx = idx * Self::extent(shape, *dim) + coord(*dim);
        }
        idx
    }

    /// Inverse of [`linear_index`]: recover logical coords from a physical
    /// index. Returns `None` for padding positions (c >= C).
    #[allow(clippy::type_complexity)]
    pub fn logical_coords(
        &self,
        shape: &Shape,
        mut idx: usize,
    ) -> Option<(usize, usize, usize, usize, usize)> {
        let mut coords = [0usize; 6];
        for (slot, dim) in self.order.iter().enumerate().rev() {
            let ext = Self::extent(shape, *dim);
            coords[slot] = idx % ext;
            idx /= ext;
        }
        if idx != 0 {
            return None; // out of range
        }
        let (mut b, mut h, mut w, mut d, mut s, mut c4) = (0, 0, 0, 0, 0, 0);
        for (slot, dim) in self.order.iter().enumerate() {
            match dim {
                ActDim::B => b = coords[slot],
                ActDim::H => h = coords[slot],
                ActDim::W => w = coords[slot],
                ActDim::D => d = coords[slot],
                ActDim::S => s = coords[slot],
                ActDim::C4 => c4 = coords[slot],
            }
        }
        let c = s * 4 + c4;
        if c >= shape.c {
            return None; // zero padding
        }
        Some((b, h, w, d, c))
    }
}

impl std::fmt::Display for ActivationLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

/// One dimension of a weight layout permutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightDim {
    /// Output-slice group index (extent = `group`).
    G,
    /// Output slice within the group (extent = `ceil(ceil(O/4) / group)`).
    So,
    /// Element within the output slice (extent 4).
    O4,
    H,
    W,
    D,
    /// Input slice (extent = `ceil(I/4)`).
    Si,
    /// Element within the input slice (extent 4).
    I4,
}

/// Logical weight shape for convolution / fully-connected weights:
/// `OHWDI` with `O` output channels and `I` input channels (paper §3.1;
/// `D = 1` except for 3D convolutions; `H = W = 1` for fully connected).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WeightShape {
    pub o: usize,
    pub h: usize,
    pub w: usize,
    pub d: usize,
    pub i: usize,
}

impl WeightShape {
    pub fn ohwi(o: usize, h: usize, w: usize, i: usize) -> Self {
        WeightShape { o, h, w, d: 1, i }
    }

    /// Fully-connected weight: spatial dims 1.
    pub fn fc(o: usize, i: usize) -> Self {
        WeightShape { o, h: 1, w: 1, d: 1, i }
    }

    pub fn elements(&self) -> usize {
        self.o * self.h * self.w * self.d * self.i
    }

    pub fn slices_o(&self) -> usize {
        self.o.div_ceil(4)
    }

    pub fn slices_i(&self) -> usize {
        self.i.div_ceil(4)
    }
}

/// A weight memory layout: grouping factor + permutation of all eight dims.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct WeightLayout {
    pub name: String,
    /// Output-slice grouping factor `G` (blocked grouping).
    pub group: usize,
    pub order: Vec<WeightDim>,
}

impl WeightLayout {
    pub fn new(name: &str, group: usize, order: Vec<WeightDim>) -> Result<Self> {
        use WeightDim::*;
        if group == 0 {
            return Err(DriftError::Layout(format!("layout {name}: group must be > 0")));
        }
        for required in [G, So, O4, H, W, D, Si, I4] {
            if order.iter().filter(|d| **d == required).count() != 1 {
                return Err(DriftError::Layout(format!(
                    "weight layout {name}: dimension {required:?} must appear exactly once"
                )));
            }
        }
        Ok(WeightLayout { name: name.to_string(), group, order })
    }

    /// The framework's default high-performance layout: groups of output
    /// slices outermost, spatial next, input slices inner, `O4` innermost so
    /// one vec4 store covers four output channels.
    /// Order: `(G, S_O, HWD, S_I, I4, O4)`.
    pub fn gso_hwdsi_i4o4(group: usize) -> Self {
        use WeightDim::*;
        Self::new(&format!("G{group}SO_HWDSI_I4O4"), group, vec![G, So, H, W, D, Si, I4, O4])
            .unwrap()
    }

    /// Variant with `I4` innermost (one vec4 load covers four input
    /// channels — preferred by dot-product-extension kernels).
    pub fn gso_hwdsi_o4i4(group: usize) -> Self {
        use WeightDim::*;
        Self::new(&format!("G{group}SO_HWDSI_O4I4"), group, vec![G, So, H, W, D, Si, O4, I4])
            .unwrap()
    }

    /// Naive padded row-major `OHWI` (the baseline the paper's ≤20 %
    /// matmul speedup is measured against).
    pub fn naive_ohwi() -> Self {
        use WeightDim::*;
        Self::new("OHWDI_naive", 1, vec![G, So, O4, H, W, D, Si, I4]).unwrap()
    }

    /// Output slices per group, padded: `ceil(ceil(O/4) / G)`.
    pub fn so_extent(&self, ws: &WeightShape) -> usize {
        ws.slices_o().div_ceil(self.group)
    }

    /// Extent of a layout dimension for a given weight shape.
    pub fn extent(&self, ws: &WeightShape, dim: WeightDim) -> usize {
        match dim {
            WeightDim::G => self.group,
            WeightDim::So => self.so_extent(ws),
            WeightDim::O4 => 4,
            WeightDim::H => ws.h,
            WeightDim::W => ws.w,
            WeightDim::D => ws.d,
            WeightDim::Si => ws.slices_i(),
            WeightDim::I4 => 4,
        }
    }

    /// Total padded element count (G·S_O·4 ≥ O, S_I·4 ≥ I).
    pub fn padded_elements(&self, ws: &WeightShape) -> usize {
        use WeightDim::*;
        [G, So, O4, H, W, D, Si, I4].iter().map(|d| self.extent(ws, *d)).product()
    }

    /// Linear physical index of logical weight element `(o, h, w, d, i)`.
    pub fn linear_index(
        &self,
        ws: &WeightShape,
        o: usize,
        h: usize,
        w: usize,
        d: usize,
        i: usize,
    ) -> usize {
        debug_assert!(o < ws.o && h < ws.h && w < ws.w && d < ws.d && i < ws.i);
        let so_total = self.so_extent(ws);
        let slice_o = o / 4;
        // Blocked grouping: group g owns output slices [g*so_total, (g+1)*so_total).
        let g = slice_o / so_total;
        let so = slice_o % so_total;
        let coord = |dim: WeightDim| -> usize {
            match dim {
                WeightDim::G => g,
                WeightDim::So => so,
                WeightDim::O4 => o % 4,
                WeightDim::H => h,
                WeightDim::W => w,
                WeightDim::D => d,
                WeightDim::Si => i / 4,
                WeightDim::I4 => i % 4,
            }
        };
        let mut idx = 0;
        for dim in &self.order {
            idx = idx * self.extent(ws, *dim) + coord(*dim);
        }
        idx
    }
}

impl std::fmt::Display for WeightLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};

    #[test]
    fn named_layouts_validate() {
        ActivationLayout::phwc4();
        ActivationLayout::hswbdc4();
        ActivationLayout::dshwbc4();
        WeightLayout::gso_hwdsi_i4o4(2);
        WeightLayout::naive_ohwi();
    }

    #[test]
    fn duplicate_dim_rejected() {
        use ActDim::*;
        assert!(ActivationLayout::new("bad", vec![B, B, W, D, S, C4]).is_err());
        assert!(ActivationLayout::new("short", vec![B, H, W, D, S]).is_err());
    }

    #[test]
    fn paper_figure1_sizes() {
        // Logical (1,2,3,5): 2 slices.
        let s = Shape::bhwc(1, 2, 3, 5);
        // 3D texture (2,3,2) = h × w × s → 12 vec4 texels = 48 elements.
        assert_eq!(ActivationLayout::dshwbc4().padded_elements(&s), 48);
        // 2D texture (2·2, 3) = 12 texels.
        assert_eq!(ActivationLayout::hswbdc4().padded_elements(&s), 48);
        // 1D image buffer: 2·3·2 = 12 pixels.
        assert_eq!(ActivationLayout::phwc4().padded_elements(&s), 48);
    }

    #[test]
    fn phwc4_order_matches_reference() {
        // For PHWC4 with B=D=1, index should be ((s*H + h)*W + w)*4 + c4.
        let shape = Shape::hwc(3, 5, 9);
        let l = ActivationLayout::phwc4();
        for h in 0..3 {
            for w in 0..5 {
                for c in 0..9 {
                    let expect = (((c / 4) * 3 + h) * 5 + w) * 4 + c % 4;
                    assert_eq!(l.linear_index(&shape, 0, h, w, 0, c), expect);
                }
            }
        }
    }

    #[test]
    fn activation_roundtrip_all_layouts() {
        let shape = Shape::bhwdc(2, 3, 4, 2, 7);
        for layout in [
            ActivationLayout::phwc4(),
            ActivationLayout::hswbdc4(),
            ActivationLayout::dshwbc4(),
        ] {
            let mut seen = vec![false; layout.padded_elements(&shape)];
            for b in 0..shape.b {
                for h in 0..shape.h {
                    for w in 0..shape.w {
                        for d in 0..shape.d {
                            for c in 0..shape.c {
                                let idx = layout.linear_index(&shape, b, h, w, d, c);
                                assert!(!seen[idx], "{layout}: collision at {idx}");
                                seen[idx] = true;
                                assert_eq!(
                                    layout.logical_coords(&shape, idx),
                                    Some((b, h, w, d, c)),
                                    "{layout}: inverse mismatch"
                                );
                            }
                        }
                    }
                }
            }
            // Unvisited positions must be padding (logical_coords → None).
            for (idx, v) in seen.iter().enumerate() {
                if !v {
                    assert_eq!(layout.logical_coords(&shape, idx), None);
                }
            }
        }
    }

    #[test]
    fn weight_roundtrip_is_injective() {
        // Figure 2's example: OHWI weights (5,2,1,7).
        let ws = WeightShape::ohwi(5, 2, 1, 7);
        for layout in [
            WeightLayout::gso_hwdsi_i4o4(2),
            WeightLayout::gso_hwdsi_o4i4(1),
            WeightLayout::naive_ohwi(),
        ] {
            let mut seen = vec![false; layout.padded_elements(&ws)];
            for o in 0..ws.o {
                for h in 0..ws.h {
                    for w in 0..ws.w {
                        for i in 0..ws.i {
                            let idx = layout.linear_index(&ws, o, h, w, 0, i);
                            assert!(idx < seen.len(), "{}: index {idx} out of range", layout.name);
                            assert!(!seen[idx], "{}: collision", layout.name);
                            seen[idx] = true;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn weight_group_times_so_covers_slices() {
        let ws = WeightShape::fc(37, 16); // 10 output slices
        for g in 1..=5 {
            let l = WeightLayout::gso_hwdsi_i4o4(g);
            assert!(g * l.so_extent(&ws) >= ws.slices_o(), "G·S_O must cover all slices");
        }
    }

    #[test]
    fn property_layout_bijection_random_shapes() {
        check("activation layout bijection", Config::cases(40), |rng| {
            let shape = Shape::bhwdc(
                1 + rng.gen_range(3) as usize,
                1 + rng.gen_range(5) as usize,
                1 + rng.gen_range(5) as usize,
                1 + rng.gen_range(2) as usize,
                1 + rng.gen_range(9) as usize,
            );
            let layout = match rng.gen_range(3) {
                0 => ActivationLayout::phwc4(),
                1 => ActivationLayout::hswbdc4(),
                _ => ActivationLayout::dshwbc4(),
            };
            let mut seen = vec![false; layout.padded_elements(&shape)];
            for b in 0..shape.b {
                for h in 0..shape.h {
                    for w in 0..shape.w {
                        for d in 0..shape.d {
                            for c in 0..shape.c {
                                let idx = layout.linear_index(&shape, b, h, w, d, c);
                                if seen[idx] {
                                    return Err(format!("collision at {idx} in {layout}"));
                                }
                                seen[idx] = true;
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
