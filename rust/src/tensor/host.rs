//! Host-side tensors: canonical f32 storage + layout packing.
//!
//! Host tensors are the reference representation used to validate layout
//! transforms, feed the quantizer, and marshal data into PJRT literals.
//! GPU-side representations are produced by packing through an
//! [`ActivationLayout`] / [`WeightLayout`].

use crate::error::{DriftError, Result};
use crate::tensor::layout::{ActivationLayout, WeightLayout, WeightShape};
use crate::tensor::shape::Shape;
use crate::util::rng::Pcg32;

/// A host activation tensor in canonical BHWDC row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: Shape) -> Self {
        HostTensor { data: vec![0.0; shape.elements()], shape }
    }

    /// Fill from a function of logical coordinates.
    pub fn from_fn(shape: Shape, mut f: impl FnMut(usize, usize, usize, usize, usize) -> f32) -> Self {
        let mut t = Self::zeros(shape);
        for b in 0..shape.b {
            for h in 0..shape.h {
                for w in 0..shape.w {
                    for d in 0..shape.d {
                        for c in 0..shape.c {
                            let idx = shape.logical_index(b, h, w, d, c);
                            t.data[idx] = f(b, h, w, d, c);
                        }
                    }
                }
            }
        }
        t
    }

    /// From an existing flat buffer (must match element count).
    pub fn from_vec(shape: Shape, data: Vec<f32>) -> Result<Self> {
        if data.len() != shape.elements() {
            return Err(DriftError::Shape(format!(
                "data length {} != shape {} elements {}",
                data.len(),
                shape,
                shape.elements()
            )));
        }
        Ok(HostTensor { shape, data })
    }

    /// Uniform random in [-1, 1) from a seeded generator.
    pub fn random(shape: Shape, rng: &mut Pcg32) -> Self {
        let data = (0..shape.elements()).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        HostTensor { shape, data }
    }

    pub fn get(&self, b: usize, h: usize, w: usize, d: usize, c: usize) -> f32 {
        self.data[self.shape.logical_index(b, h, w, d, c)]
    }

    pub fn set(&mut self, b: usize, h: usize, w: usize, d: usize, c: usize, v: f32) {
        let idx = self.shape.logical_index(b, h, w, d, c);
        self.data[idx] = v;
    }

    /// Pack into a physical layout. Padding positions are zero-filled
    /// (required for SIMD correctness per §3.1).
    pub fn pack(&self, layout: &ActivationLayout) -> Vec<f32> {
        let mut out = vec![0.0; layout.padded_elements(&self.shape)];
        let s = self.shape;
        for b in 0..s.b {
            for h in 0..s.h {
                for w in 0..s.w {
                    for d in 0..s.d {
                        for c in 0..s.c {
                            out[layout.linear_index(&s, b, h, w, d, c)] =
                                self.get(b, h, w, d, c);
                        }
                    }
                }
            }
        }
        out
    }

    /// Inverse of [`pack`].
    pub fn unpack(shape: Shape, layout: &ActivationLayout, packed: &[f32]) -> Result<Self> {
        if packed.len() != layout.padded_elements(&shape) {
            return Err(DriftError::Layout(format!(
                "packed length {} != expected {}",
                packed.len(),
                layout.padded_elements(&shape)
            )));
        }
        let mut t = Self::zeros(shape);
        for b in 0..shape.b {
            for h in 0..shape.h {
                for w in 0..shape.w {
                    for d in 0..shape.d {
                        for c in 0..shape.c {
                            let v = packed[layout.linear_index(&shape, b, h, w, d, c)];
                            t.set(b, h, w, d, c, v);
                        }
                    }
                }
            }
        }
        Ok(t)
    }
}

/// A host weight tensor in canonical OHWDI row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct HostWeights {
    pub shape: WeightShape,
    pub data: Vec<f32>,
}

impl HostWeights {
    pub fn zeros(shape: WeightShape) -> Self {
        HostWeights { data: vec![0.0; shape.elements()], shape }
    }

    pub fn random(shape: WeightShape, rng: &mut Pcg32) -> Self {
        let data = (0..shape.elements()).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        HostWeights { shape, data }
    }

    #[inline]
    fn logical_index(&self, o: usize, h: usize, w: usize, d: usize, i: usize) -> usize {
        let s = self.shape;
        debug_assert!(o < s.o && h < s.h && w < s.w && d < s.d && i < s.i);
        (((o * s.h + h) * s.w + w) * s.d + d) * s.i + i
    }

    pub fn get(&self, o: usize, h: usize, w: usize, d: usize, i: usize) -> f32 {
        self.data[self.logical_index(o, h, w, d, i)]
    }

    /// Rearrange into a physical weight layout (the paper's *weights
    /// conversion* transformation, §3.4), zero-padding O and I to slice
    /// multiples and G·S_O coverage.
    pub fn pack(&self, layout: &WeightLayout) -> Vec<f32> {
        let mut out = vec![0.0; layout.padded_elements(&self.shape)];
        let s = self.shape;
        for o in 0..s.o {
            for h in 0..s.h {
                for w in 0..s.w {
                    for d in 0..s.d {
                        for i in 0..s.i {
                            out[layout.linear_index(&s, o, h, w, d, i)] =
                                self.get(o, h, w, d, i);
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg32::seeded(11);
        let shape = Shape::bhwc(2, 3, 4, 5);
        let t = HostTensor::random(shape, &mut rng);
        for layout in [
            ActivationLayout::phwc4(),
            ActivationLayout::hswbdc4(),
            ActivationLayout::dshwbc4(),
        ] {
            let packed = t.pack(&layout);
            let back = HostTensor::unpack(shape, &layout, &packed).unwrap();
            assert_eq!(t, back, "roundtrip failed for {layout}");
        }
    }

    #[test]
    fn padding_is_zero() {
        let shape = Shape::hwc(1, 1, 5); // 2 slices, 3 padded lanes
        let t = HostTensor::from_fn(shape, |_, _, _, _, c| (c + 1) as f32);
        let packed = t.pack(&ActivationLayout::phwc4());
        assert_eq!(packed.len(), 8);
        // Lane values 1..5 present; padding zero.
        let nonzero: Vec<f32> = packed.iter().copied().filter(|v| *v != 0.0).collect();
        assert_eq!(nonzero.len(), 5);
        assert_eq!(packed.iter().filter(|v| **v == 0.0).count(), 3);
    }

    #[test]
    fn from_vec_length_checked() {
        assert!(HostTensor::from_vec(Shape::linear(4), vec![0.0; 3]).is_err());
        assert!(HostTensor::from_vec(Shape::linear(4), vec![0.0; 4]).is_ok());
    }

    #[test]
    fn weights_pack_preserves_values() {
        let mut rng = Pcg32::seeded(21);
        let ws = WeightShape::ohwi(5, 2, 1, 7);
        let w = HostWeights::random(ws, &mut rng);
        let layout = WeightLayout::gso_hwdsi_i4o4(2);
        let packed = w.pack(&layout);
        // Every logical value appears exactly where linear_index points.
        for o in 0..ws.o {
            for h in 0..ws.h {
                for i in 0..ws.i {
                    assert_eq!(packed[layout.linear_index(&ws, o, h, 0, 0, i)], w.get(o, h, 0, 0, i));
                }
            }
        }
        // Padded footprint from Fig. 2: 4 textures × (4,2) × vec4 = 2·1·2·1·1·2·4·4
        assert_eq!(packed.len(), layout.padded_elements(&ws));
    }
}
