//! Logical tensor core (paper §3.1).
//!
//! A *logical* tensor is a multidimensional array with semantically
//! meaningful axes. ML Drift assigns implicit axis semantics per rank:
//!
//! | rank | semantics |
//! |------|-----------|
//! | 0D   | scalar    |
//! | 1D   | Linear    |
//! | 2D   | HW        |
//! | 3D   | HWC       |
//! | 4D   | BHWC      |
//! | 5D   | BHWDC     |
//!
//! Data destined for the GPU is organized into contiguous **4-channel
//! slices** (`S = ceil(C/4)`, `C4 = C mod 4`) to exploit 4-element SIMD —
//! the PHWC4 family of layouts. [`layout`] generalizes this to arbitrary
//! slice-aware dimension orders (`HSWBDC4`, `DSHWBC4`, …) and to the weight
//! layout family `(G, S_O, O4, HWD, S_I, I4)`.

pub mod dtype;
pub mod shape;
pub mod layout;
pub mod host;

pub use dtype::DType;
pub use shape::{Axis, Shape};
pub use layout::{ActDim, ActivationLayout, WeightDim, WeightLayout, WeightShape};
pub use host::{HostTensor, HostWeights};
