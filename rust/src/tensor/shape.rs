//! Logical tensor shapes with BHWDC axis semantics (paper §3.1).

use crate::error::{DriftError, Result};

/// Semantic axis of a logical tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    Batch,
    Height,
    Width,
    Depth,
    Channel,
}

/// A logical tensor shape. All tensors are canonicalized to 5D **BHWDC**
/// internally; lower ranks embed per the paper's implicit semantics
/// (0D scalar, 1D Linear→C, 2D HW, 3D HWC, 4D BHWC, 5D BHWDC).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub d: usize,
    pub c: usize,
    /// Original logical rank (0–5) — retained so reports and codegen can
    /// show the tensor as the user declared it.
    pub rank: u8,
}

impl Shape {
    /// 0D scalar.
    pub fn scalar() -> Shape {
        Shape { b: 1, h: 1, w: 1, d: 1, c: 1, rank: 0 }
    }

    /// 1D Linear: a vector of `n` elements mapped onto the channel axis.
    pub fn linear(n: usize) -> Shape {
        Shape { b: 1, h: 1, w: 1, d: 1, c: n, rank: 1 }
    }

    /// 2D HW.
    pub fn hw(h: usize, w: usize) -> Shape {
        Shape { b: 1, h, w, d: 1, c: 1, rank: 2 }
    }

    /// 3D HWC.
    pub fn hwc(h: usize, w: usize, c: usize) -> Shape {
        Shape { b: 1, h, w, d: 1, c, rank: 3 }
    }

    /// 4D BHWC.
    pub fn bhwc(b: usize, h: usize, w: usize, c: usize) -> Shape {
        Shape { b, h, w, d: 1, c, rank: 4 }
    }

    /// 5D BHWDC (D used only by 3D convolutions; otherwise D = 1).
    pub fn bhwdc(b: usize, h: usize, w: usize, d: usize, c: usize) -> Shape {
        Shape { b, h, w, d, c, rank: 5 }
    }

    /// Build from a dims slice using the implicit per-rank semantics.
    pub fn from_dims(dims: &[usize]) -> Result<Shape> {
        Ok(match dims {
            [] => Shape::scalar(),
            [n] => Shape::linear(*n),
            [h, w] => Shape::hw(*h, *w),
            [h, w, c] => Shape::hwc(*h, *w, *c),
            [b, h, w, c] => Shape::bhwc(*b, *h, *w, *c),
            [b, h, w, d, c] => Shape::bhwdc(*b, *h, *w, *d, *c),
            _ => {
                return Err(DriftError::Shape(format!(
                    "rank {} > 5 unsupported",
                    dims.len()
                )))
            }
        })
    }

    /// Extent along a semantic axis.
    pub fn axis(&self, a: Axis) -> usize {
        match a {
            Axis::Batch => self.b,
            Axis::Height => self.h,
            Axis::Width => self.w,
            Axis::Depth => self.d,
            Axis::Channel => self.c,
        }
    }

    /// Number of logical elements (no padding).
    pub fn elements(&self) -> usize {
        self.b * self.h * self.w * self.d * self.c
    }

    /// Number of 4-channel slices: `S = ceil(C/4)`.
    pub fn slices(&self) -> usize {
        self.c.div_ceil(4)
    }

    /// Number of elements after zero-padding C to a multiple of 4
    /// (SIMD-compatible storage footprint).
    pub fn padded_elements(&self) -> usize {
        self.b * self.h * self.w * self.d * self.slices() * 4
    }

    /// Whether any axis is zero (empty tensor).
    pub fn is_empty(&self) -> bool {
        self.elements() == 0
    }

    /// Dims in declared-rank order (inverse of `from_dims`).
    pub fn dims(&self) -> Vec<usize> {
        match self.rank {
            0 => vec![],
            1 => vec![self.c],
            2 => vec![self.h, self.w],
            3 => vec![self.h, self.w, self.c],
            4 => vec![self.b, self.h, self.w, self.c],
            _ => vec![self.b, self.h, self.w, self.d, self.c],
        }
    }

    /// Flat logical index of `(b, h, w, d, c)` in canonical BHWDC row-major
    /// order. Used as the reference ordering by layout round-trip tests.
    pub fn logical_index(&self, b: usize, h: usize, w: usize, d: usize, c: usize) -> usize {
        debug_assert!(b < self.b && h < self.h && w < self.w && d < self.d && c < self.c);
        (((b * self.h + h) * self.w + w) * self.d + d) * self.c + c
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dims = self.dims();
        if dims.is_empty() {
            return write!(f, "()");
        }
        let strs: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
        write!(f, "({})", strs.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn implicit_semantics() {
        let s = Shape::from_dims(&[1, 2, 3, 5]).unwrap();
        assert_eq!((s.b, s.h, s.w, s.d, s.c), (1, 2, 3, 1, 5));
        assert_eq!(s.rank, 4);
        let s = Shape::from_dims(&[7]).unwrap();
        assert_eq!(s.c, 7);
        assert_eq!(Shape::from_dims(&[]).unwrap().elements(), 1);
        assert!(Shape::from_dims(&[1, 2, 3, 4, 5, 6]).is_err());
    }

    #[test]
    fn paper_figure1_tensor() {
        // Figure 1's running example: logical (1,2,3,5) BHWC tensor.
        let s = Shape::bhwc(1, 2, 3, 5);
        assert_eq!(s.slices(), 2); // ceil(5/4)
        assert_eq!(s.elements(), 30);
        assert_eq!(s.padded_elements(), 1 * 2 * 3 * 2 * 4); // 48
    }

    #[test]
    fn logical_index_rowmajor() {
        let s = Shape::bhwc(2, 2, 2, 3);
        assert_eq!(s.logical_index(0, 0, 0, 0, 0), 0);
        assert_eq!(s.logical_index(0, 0, 0, 0, 2), 2);
        assert_eq!(s.logical_index(0, 0, 1, 0, 0), 3);
        assert_eq!(s.logical_index(1, 1, 1, 0, 2), s.elements() - 1);
    }

    #[test]
    fn display_and_dims_roundtrip() {
        let s = Shape::bhwdc(2, 3, 4, 5, 6);
        assert_eq!(format!("{s}"), "(2,3,4,5,6)");
        assert_eq!(Shape::from_dims(&s.dims()).unwrap(), s);
    }
}
