//! Element data types supported by the framework.

/// Element type of a tensor, weight store, or KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float (NVIDIA-OpenCL fallback path in the paper).
    F32,
    /// 16-bit IEEE float (primary activation type).
    F16,
    /// bfloat16 (TPU-side accumulation format for the Pallas kernels).
    BF16,
    /// Per-channel quantized signed 8-bit integer.
    I8,
    /// Packed signed 4-bit integer (two elements per byte).
    I4,
    /// Unsigned 8-bit (e.g. token bytes).
    U8,
    /// 32-bit signed integer (token ids, positions).
    I32,
    /// Boolean mask.
    Bool,
}

impl DType {
    /// Size of one element in **bits** (I4 is sub-byte).
    pub fn bits(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 32,
            DType::F16 | DType::BF16 => 16,
            DType::I8 | DType::U8 | DType::Bool => 8,
            DType::I4 => 4,
        }
    }

    /// Bytes needed to store `n` elements of this type, including the
    /// final partial byte for sub-byte types.
    pub fn bytes_for(self, n: usize) -> usize {
        (n * self.bits()).div_ceil(8)
    }

    /// Whether this is a quantized integer weight type.
    pub fn is_quantized(self) -> bool {
        matches!(self, DType::I8 | DType::I4)
    }

    /// Whether this is a floating-point type.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16 | DType::BF16)
    }

    /// Short lowercase name used in shader codegen and reports.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::I8 => "i8",
            DType::I4 => "i4",
            DType::U8 => "u8",
            DType::I32 => "i32",
            DType::Bool => "bool",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.bits(), 32);
        assert_eq!(DType::F16.bits(), 16);
        assert_eq!(DType::I4.bits(), 4);
        assert_eq!(DType::F32.bytes_for(3), 12);
        assert_eq!(DType::I4.bytes_for(3), 2); // packed: 1.5 bytes → 2
        assert_eq!(DType::I4.bytes_for(4), 2);
    }

    #[test]
    fn classification() {
        assert!(DType::I8.is_quantized());
        assert!(DType::I4.is_quantized());
        assert!(!DType::F16.is_quantized());
        assert!(DType::F16.is_float());
        assert!(!DType::I8.is_float());
    }
}
