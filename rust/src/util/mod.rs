//! Infrastructure substrates built in-crate.
//!
//! The offline crate registry only carries the `xla` crate's dependency
//! closure, so the usual ecosystem crates (tokio, clap, serde, criterion,
//! proptest, rand) are unavailable. Everything the framework needs from them
//! is implemented here, tested, and kept deliberately small:
//!
//! * [`rng`] — PCG-family pseudorandom generator (deterministic, seedable).
//! * [`json`] — minimal JSON value model, parser, and pretty-printer.
//! * [`cli`] — declarative command-line argument parser.
//! * [`stats`] — streaming summary statistics and percentile estimation.
//! * [`threadpool`] — fixed-size worker pool with job handles.
//! * [`propcheck`] — property-based testing harness (generate + shrink-lite).
//! * [`log`] — leveled stderr logger.

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod threadpool;
pub mod propcheck;
pub mod log;

/// Integer ceiling division: `ceil(a / b)` for positive integers.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn align_up(a: usize, b: usize) -> usize {
    div_ceil(a, b) * b
}

/// Human-readable byte count (binary prefixes, two decimals).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_exact_and_inexact() {
        assert_eq!(div_ceil(8, 4), 2);
        assert_eq!(div_ceil(9, 4), 3);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(0, 4), 0);
    }

    #[test]
    fn align_up_basic() {
        assert_eq!(align_up(5, 4), 8);
        assert_eq!(align_up(8, 4), 8);
        assert_eq!(align_up(0, 16), 0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
