//! Fixed-size worker thread pool (tokio is unavailable offline).
//!
//! The serving layer and the benchmark sweeps are thread-structured rather
//! than async: request handling on an inference server is a small number of
//! long-lived pipeline stages, which maps naturally onto dedicated threads
//! plus channels (this is also how llama.cpp's server is structured).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    cv: Condvar,
}

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    idle_cv: Arc<(Mutex<()>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            cv: Condvar::new(),
        });
        let idle_cv = Arc::new((Mutex::new(()), Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let idle = Arc::clone(&idle_cv);
                std::thread::Builder::new()
                    .name(format!("mldrift-worker-{i}"))
                    .spawn(move || worker_loop(shared, idle))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, idle_cv }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.shared.queue.lock().unwrap();
        assert!(!st.shutdown, "execute after shutdown");
        st.jobs.push_back(Box::new(f));
        drop(st);
        self.shared.cv.notify_one();
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.idle_cv;
        let mut guard = lock.lock().unwrap();
        loop {
            {
                let st = self.shared.queue.lock().unwrap();
                if st.jobs.is_empty() && st.in_flight == 0 {
                    return;
                }
            }
            let (g, _timeout) = cv.wait_timeout(guard, std::time::Duration::from_millis(20)).unwrap();
            guard = g;
        }
    }

    /// Run a batch of jobs and wait for all of them (scoped helper).
    pub fn scope_all<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        for j in jobs {
            self.execute(j);
        }
        self.wait_idle();
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, idle: Arc<(Mutex<()>, Condvar)>) {
    loop {
        let job = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.in_flight += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        job();
        {
            let mut st = shared.queue.lock().unwrap();
            st.in_flight -= 1;
        }
        idle.1.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4);
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let r = Arc::clone(&running);
            let p = Arc::clone(&peak);
            pool.execute(move || {
                let now = r.fetch_add(1, Ordering::SeqCst) + 1;
                p.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(30));
                r.fetch_sub(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert!(peak.load(Ordering::SeqCst) >= 2, "no parallelism observed");
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
