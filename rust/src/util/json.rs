//! Minimal JSON: value model, recursive-descent parser, pretty printer.
//!
//! serde is unavailable offline; configs, device profiles, artifact manifests,
//! and bench reports use this module instead. Supports the full JSON grammar
//! minus `\u` surrogate-pair edge refinements (lone surrogates are replaced).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{DriftError, Result};

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write_compact(out),
        }
    }

    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(xs: Vec<Json>) -> Self {
        Json::Arr(xs)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> DriftError {
        DriftError::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(xs)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(chunk) => {
                            s.push_str(chunk);
                            self.pos = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for doc in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(doc).unwrap();
            let v2 = Json::parse(&v.compact()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""héllo ☃ é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃ é");
        let v2 = Json::parse(&v.compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", "adreno_750".into()),
            ("bw", 77.0.into()),
            ("tags", Json::Arr(vec!["mobile".into(), "qcom".into()])),
        ]);
        let v2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integer_formatting_stable() {
        assert_eq!(Json::Num(42.0).compact(), "42");
        assert_eq!(Json::Num(42.5).compact(), "42.5");
    }
}
