//! Deterministic pseudorandom generator (PCG-XSH-RR 64/32).
//!
//! Used by workload generators, property tests, and weight-init checks. The
//! `rand` crate is unavailable offline; PCG gives us a small, fast, seedable,
//! statistically solid generator with a stable stream across platforms.

/// PCG-XSH-RR 64/32 generator. One 64-bit state word, one stream constant.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next 32 uniform random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection, unbiased).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        // Rejection sampling over the top of the range to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.gen_range(span) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        // 53 bits of mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` as f32.
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal sample (Box–Muller; one value per call, simple & fine
    /// for test/workload use).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential sample with rate `lambda` (inter-arrival times for Poisson
    /// request traffic in the serving benchmarks).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.gen_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Bernoulli sample with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should diverge, {same} collisions");
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..1000 {
            let v = rng.gen_range(13);
            assert!(v < 13);
        }
        for _ in 0..1000 {
            let v = rng.gen_range_inclusive(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn uniform_f64_mean_near_half() {
        let mut rng = Pcg32::seeded(99);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}
