//! Summary statistics and percentile estimation for benchmarks and metrics.

/// A collected sample set with summary statistics (criterion substitute).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    sorted: Vec<f64>,
    sum: f64,
}

impl Summary {
    /// Build from raw samples (takes ownership, sorts once).
    pub fn from_samples(mut xs: Vec<f64>) -> Self {
        xs.retain(|x| x.is_finite());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let sum = xs.iter().sum();
        Summary { sorted: xs, sum }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        self.sum / self.sorted.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(f64::NAN)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.sorted.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.sorted.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = p / 100.0 * (self.sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let frac = rank - lo as f64;
            self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Compact one-line report: `mean ± sd [min … p50 … p95 … max]`.
    pub fn report(&self, unit: &str) -> String {
        format!(
            "{:.3} ± {:.3} {unit} [min {:.3}, p50 {:.3}, p95 {:.3}, max {:.3}] n={}",
            self.mean(),
            self.stddev(),
            self.min(),
            self.median(),
            self.percentile(95.0),
            self.max(),
            self.len()
        )
    }
}

/// Streaming histogram with fixed bucket boundaries (for serving metrics —
/// latency distributions without retaining every sample).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    /// Exponential bucket boundaries from `lo` with `factor` growth, `n` buckets.
    pub fn exponential(lo: f64, factor: f64, n: usize) -> Self {
        assert!(lo > 0.0 && factor > 1.0 && n > 0);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram { counts: vec![0; n + 1], bounds, total: 0, sum: 0.0, max: 0.0 }
    }

    /// Linear bucket boundaries `lo, lo+step, …`, `n` buckets — exact for
    /// small-integer metrics (batch occupancy, tokens per round) where
    /// exponential buckets would blur adjacent values together.
    pub fn linear(lo: f64, step: f64, n: usize) -> Self {
        assert!(step > 0.0 && n > 0);
        let bounds: Vec<f64> = (0..n).map(|i| lo + step * i as f64).collect();
        Histogram { counts: vec![0; n + 1], bounds, total: 0, sum: 0.0, max: 0.0 }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|b| *b < v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile estimate: upper bound of the bucket containing the quantile.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0 * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_samples(vec![4.0, 1.0, 3.0, 2.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_samples(vec![0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn nan_samples_dropped() {
        let s = Summary::from_samples(vec![1.0, f64::NAN, 3.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.mean(), 2.0);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = Histogram::exponential(0.1, 2.0, 16);
        for i in 1..=1000 {
            h.record(i as f64 / 100.0);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of uniform(0.01..10) ≈ 5; bucketed upper bound should bracket it.
        assert!(p50 >= 5.0 && p50 <= 13.0, "p50={p50}");
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::exponential(1.0, 2.0, 8);
        for v in [1.0, 2.0, 3.0] {
            h.record(v);
        }
        assert!((h.mean() - 2.0).abs() < 1e-12);
        assert_eq!(h.max(), 3.0);
    }
}
