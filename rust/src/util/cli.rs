//! Declarative command-line parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options with
//! defaults, and positional arguments; generates usage text from the spec.

use std::collections::BTreeMap;

use crate::error::{DriftError, Result};

/// Specification of one option or flag.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None ⇒ boolean flag; Some(default) ⇒ value option.
    pub default: Option<&'static str>,
    /// Value option with no default that must be supplied.
    pub required: bool,
}

/// Specification of a subcommand.
#[derive(Clone, Debug, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed argument values for one invocation.
#[derive(Debug, Default)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Matches {
    /// Value of `--name` (or its default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required string value (panics with clear message if spec guaranteed it).
    pub fn req(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("missing required arg --{name}"))
    }

    /// Parse a value as `T`.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        let raw = self
            .get(name)
            .ok_or_else(|| DriftError::Config(format!("missing argument --{name}")))?;
        raw.parse::<T>()
            .map_err(|_| DriftError::Config(format!("--{name}: cannot parse {raw:?}")))
    }

    /// Whether a boolean flag was set.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A CLI application: a list of subcommands plus global help.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Render usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str(&format!("\nRun `{} <command> --help` for command options.\n", self.bin));
        s
    }

    fn command_usage(&self, c: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, c.name, c.about);
        for a in &c.args {
            let left = match a.default {
                Some(d) => format!("--{} <v> (default {d})", a.name),
                None if a.required => format!("--{} <v> (required)", a.name),
                None => format!("--{}", a.name),
            };
            s.push_str(&format!("  {left:<36} {}\n", a.help));
        }
        for (p, h) in &c.positionals {
            s.push_str(&format!("  <{p}>{:<32} {h}\n", ""));
        }
        s
    }

    /// Parse argv (excluding argv[0]). Returns Err with usage text on problems,
    /// and `Ok(None)` when help was requested.
    pub fn parse(&self, argv: &[String]) -> Result<Option<Matches>> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            println!("{}", self.usage());
            return Ok(None);
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| DriftError::Config(format!("unknown command {cmd_name:?}\n\n{}", self.usage())))?;

        let mut m = Matches { command: cmd.name.to_string(), ..Default::default() };
        // Seed defaults.
        for a in &cmd.args {
            if let Some(d) = a.default {
                m.values.insert(a.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                println!("{}", self.command_usage(cmd));
                return Ok(None);
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = cmd
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| DriftError::Config(format!("unknown option --{key} for {cmd_name}")))?;
                if spec.default.is_none() && !spec.required && inline_val.is_none() {
                    // Check: flag (no value) unless the next token is a value
                    // and the spec is a value option.
                    m.flags.insert(key.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| DriftError::Config(format!("--{key} needs a value")))?
                        }
                    };
                    m.values.insert(key.to_string(), val);
                }
            } else {
                m.positionals.push(tok.clone());
            }
            i += 1;
        }
        for a in &cmd.args {
            if a.required && !m.values.contains_key(a.name) {
                return Err(DriftError::Config(format!(
                    "missing required option --{} for {}\n\n{}",
                    a.name,
                    cmd.name,
                    self.command_usage(cmd)
                )));
            }
        }
        Ok(Some(m))
    }
}

/// Shorthand for a value option with a default.
pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { name, help, default: Some(default), required: false }
}

/// Shorthand for a required value option.
pub fn req(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { name, help, default: None, required: true }
}

/// Shorthand for a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { name, help, default: None, required: false }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "mldrift",
            about: "test",
            commands: vec![CommandSpec {
                name: "serve",
                about: "serve a model",
                args: vec![
                    opt("port", "8080", "port"),
                    opt("model", "tinylm", "model name"),
                    flag("verbose", "noisy"),
                    req("artifacts", "artifact dir"),
                ],
                positionals: vec![],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let m = cli()
            .parse(&argv(&["serve", "--port", "9999", "--artifacts", "a/"]))
            .unwrap()
            .unwrap();
        assert_eq!(m.get("port"), Some("9999"));
        assert_eq!(m.get("model"), Some("tinylm"));
        assert_eq!(m.req("artifacts"), "a/");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let m = cli()
            .parse(&argv(&["serve", "--port=1", "--verbose", "--artifacts=x"]))
            .unwrap()
            .unwrap();
        assert_eq!(m.get("port"), Some("1"));
        assert!(m.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&["serve"])).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(cli().parse(&argv(&["nope"])).is_err());
    }

    #[test]
    fn typed_parse() {
        let m = cli()
            .parse(&argv(&["serve", "--artifacts", "a", "--port", "123"]))
            .unwrap()
            .unwrap();
        let p: u16 = m.parse("port").unwrap();
        assert_eq!(p, 123);
        assert!(m.parse::<u16>("model").is_err());
    }
}
