//! Property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Pcg32`]; the harness runs it for
//! `cases` seeds and, on failure, retries with nearby seeds to report the
//! smallest failing seed it can find (a light-weight stand-in for shrinking —
//! generators should derive *sizes* from early draws so smaller seeds tend to
//! produce smaller cases).
//!
//! ```no_run
//! use mldrift::util::propcheck::{check, Config};
//! check("sum is commutative", Config::default(), |rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     if a + b != b + a { return Err(format!("{a}+{b}")); }
//!     Ok(())
//! });
//! ```
//! (`no_run`: doctest binaries don't inherit the rpath link flags this
//! offline environment needs; the same property runs in the unit tests.)

use super::rng::Pcg32;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u64,
    /// Base seed; each case uses `base_seed + case_index`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, base_seed: 0x5eed }
    }
}

impl Config {
    pub fn cases(n: u64) -> Self {
        Config { cases: n, ..Default::default() }
    }
}

/// Run `prop` for `cfg.cases` seeds; panics with the failing seed and message
/// on the first failure (after probing for a smaller failing seed).
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case);
        let mut rng = Pcg32::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            // Probe smaller seeds for a (usually smaller) reproduction.
            let mut best = (seed, msg);
            for probe in 0..seed.min(64) {
                let mut rng = Pcg32::seeded(probe);
                if let Err(m) = prop(&mut rng) {
                    best = (probe, m);
                    break;
                }
            }
            panic!(
                "property '{name}' failed at seed {} (case {case}/{}): {}",
                best.0, cfg.cases, best.1
            );
        }
    }
}

/// Helper: draw a vector of length in `[min_len, max_len]` using `gen_elem`.
pub fn vec_of<T>(
    rng: &mut Pcg32,
    min_len: usize,
    max_len: usize,
    mut gen_elem: impl FnMut(&mut Pcg32) -> T,
) -> Vec<T> {
    let len = min_len + rng.gen_range((max_len - min_len + 1) as u64) as usize;
    (0..len).map(|_| gen_elem(rng)).collect()
}

/// Helper: assert two f32 slices are close; returns an Err description if not.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", Config::cases(64), |rng| {
            let xs = vec_of(rng, 0, 32, |r| r.gen_range(100));
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            if xs == ys {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", Config::cases(8), |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_divergence() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-6], 1e-5, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 1e-5, 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
