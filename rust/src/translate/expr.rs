//! Affine index-expression IR with constant folding.
//!
//! Coordinate translations are built symbolically so the shader generator
//! can fold shape constants at codegen time (e.g. `((s*3 + y)*4 + x)*1 + b`
//! simplifies to `(s*3 + y)*4 + x + b` with batch = 1 folded away).

use std::collections::BTreeMap;
use std::rc::Rc;

/// A symbolic integer index expression.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Expr {
    /// Free variable (logical coordinate such as `b`, `x`, `y`, `s`).
    Var(&'static str),
    /// Integer constant (folded shape extents).
    Const(i64),
    Add(Rc<Expr>, Rc<Expr>),
    Mul(Rc<Expr>, Rc<Expr>),
    /// Truncating division (non-negative operands in practice).
    Div(Rc<Expr>, Rc<Expr>),
    Mod(Rc<Expr>, Rc<Expr>),
}

impl Expr {
    pub fn var(name: &'static str) -> Expr {
        Expr::Var(name)
    }

    pub fn c(v: i64) -> Expr {
        Expr::Const(v)
    }

    pub fn add(self, rhs: Expr) -> Expr {
        Expr::Add(Rc::new(self), Rc::new(rhs)).fold()
    }

    pub fn mul(self, rhs: Expr) -> Expr {
        Expr::Mul(Rc::new(self), Rc::new(rhs)).fold()
    }

    pub fn div(self, rhs: Expr) -> Expr {
        Expr::Div(Rc::new(self), Rc::new(rhs)).fold()
    }

    pub fn rem(self, rhs: Expr) -> Expr {
        Expr::Mod(Rc::new(self), Rc::new(rhs)).fold()
    }

    /// One level of algebraic simplification (children are already folded
    /// because the builders fold bottom-up).
    fn fold(self) -> Expr {
        use Expr::*;
        match &self {
            Add(a, b) => match (a.as_ref(), b.as_ref()) {
                (Const(x), Const(y)) => Const(x + y),
                (Const(0), e) | (e, Const(0)) => e.clone(),
                _ => self,
            },
            Mul(a, b) => match (a.as_ref(), b.as_ref()) {
                (Const(x), Const(y)) => Const(x * y),
                (Const(1), e) | (e, Const(1)) => e.clone(),
                (Const(0), _) | (_, Const(0)) => Const(0),
                _ => self,
            },
            Div(a, b) => match (a.as_ref(), b.as_ref()) {
                (Const(x), Const(y)) if *y != 0 => Const(x / y),
                (e, Const(1)) => e.clone(),
                (Const(0), _) => Const(0),
                _ => self,
            },
            Mod(a, b) => match (a.as_ref(), b.as_ref()) {
                (Const(x), Const(y)) if *y != 0 => Const(x % y),
                (_, Const(1)) => Const(0),
                (Const(0), _) => Const(0),
                _ => self,
            },
            _ => self,
        }
    }

    /// Evaluate with a variable environment.
    pub fn eval(&self, env: &BTreeMap<&str, i64>) -> i64 {
        match self {
            Expr::Var(v) => *env
                .get(v)
                .unwrap_or_else(|| panic!("unbound variable {v} in index expression")),
            Expr::Const(c) => *c,
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::Div(a, b) => a.eval(env) / b.eval(env),
            Expr::Mod(a, b) => a.eval(env) % b.eval(env),
        }
    }

    /// Emit C-like source (valid in OpenCL-C, MSL, and WGSL expressions).
    pub fn emit(&self) -> String {
        self.emit_prec(0)
    }

    fn emit_prec(&self, parent: u8) -> String {
        // precedence: 1 = additive, 2 = multiplicative
        let (text, prec) = match self {
            Expr::Var(v) => (v.to_string(), 3),
            Expr::Const(c) => (c.to_string(), 3),
            Expr::Add(a, b) => (format!("{} + {}", a.emit_prec(1), b.emit_prec(1)), 1),
            Expr::Mul(a, b) => (format!("{} * {}", a.emit_prec(2), b.emit_prec(2)), 2),
            Expr::Div(a, b) => (format!("{} / {}", a.emit_prec(2), b.emit_prec(3)), 2),
            Expr::Mod(a, b) => (format!("{} % {}", a.emit_prec(2), b.emit_prec(3)), 2),
        };
        if prec < parent {
            format!("({text})")
        } else {
            text
        }
    }

    /// Count operations remaining after folding (codegen-quality metric:
    /// the paper's point is that translation cost is folded to near-zero
    /// when shape constants are known).
    pub fn op_count(&self) -> usize {
        match self {
            Expr::Var(_) | Expr::Const(_) => 0,
            Expr::Add(a, b) | Expr::Mul(a, b) | Expr::Div(a, b) | Expr::Mod(a, b) => {
                1 + a.op_count() + b.op_count()
            }
        }
    }
}

impl std::fmt::Display for Expr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.emit())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&'static str, i64)]) -> BTreeMap<&'static str, i64> {
        pairs.iter().copied().collect()
    }

    #[test]
    fn folding_collapses_units() {
        let e = Expr::var("x").mul(Expr::c(1)).add(Expr::c(0));
        assert_eq!(e, Expr::Var("x"));
        let e = Expr::var("x").mul(Expr::c(0));
        assert_eq!(e, Expr::Const(0));
        let e = Expr::c(6).div(Expr::c(2));
        assert_eq!(e, Expr::Const(3));
        let e = Expr::var("x").rem(Expr::c(1));
        assert_eq!(e, Expr::Const(0));
    }

    #[test]
    fn eval_matches_structure() {
        // ((s*3 + y)*4 + x)
        let e = Expr::var("s")
            .mul(Expr::c(3))
            .add(Expr::var("y"))
            .mul(Expr::c(4))
            .add(Expr::var("x"));
        assert_eq!(e.eval(&env(&[("s", 1), ("y", 2), ("x", 3)])), (1 * 3 + 2) * 4 + 3);
    }

    #[test]
    fn emit_is_valid_c() {
        let e = Expr::var("y").mul(Expr::c(2)).add(Expr::var("s"));
        assert_eq!(e.emit(), "y * 2 + s");
        let e = Expr::var("y").add(Expr::c(2)).mul(Expr::var("s"));
        assert_eq!(e.emit(), "(y + 2) * s");
        let e = Expr::var("a").div(Expr::var("b").add(Expr::c(1)));
        assert_eq!(e.emit(), "a / (b + 1)");
    }

    #[test]
    fn op_count_reflects_folding() {
        let folded = Expr::var("x").mul(Expr::c(1)); // folds to x
        assert_eq!(folded.op_count(), 0);
        let unfolded = Expr::var("x").mul(Expr::c(2)).add(Expr::var("b"));
        assert_eq!(unfolded.op_count(), 2);
    }
}
