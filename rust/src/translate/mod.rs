//! Coordinate translation (paper §3.3, Table 1).
//!
//! Consuming shader programs access tensor elements through generated helper
//! functions (e.g. `args.src.Read(b, x, y, s)`) that translate logical
//! coordinates into the physical GPU object's coordinates. The translation
//! is resolved **during shader code generation** — a pre-processing stage —
//! so it adds zero runtime latency.
//!
//! * [`expr`] — a small affine index-expression IR with constant folding.
//! * [`codegen`] — Table-1 translation expressions for every storage type
//!   and the `Read`/`Write` helper source emitted into shaders.

pub mod expr;
pub mod codegen;

pub use expr::Expr;
pub use codegen::{translation_coords, ReadWriteHelpers};
