//! Table-1 coordinate translation and Read/Write helper generation.
//!
//! Given a [`TensorDescriptor`], produce the symbolic storage coordinates
//! for logical `(b, x, y, s)` (BHWC convention: `x` = width index, `y` =
//! height index, `s` = slice index) and emit the `Read`/`Write` helper
//! functions that shaders call. Shape extents are folded as constants, so
//! e.g. batch-1 tensors lose their `* batch + b` terms entirely — this is
//! why the paper reports negligible overhead for virtualization.

use std::collections::BTreeMap;

use crate::tensor::ActDim;
use crate::translate::expr::Expr;
use crate::vgpu::descriptor::TensorDescriptor;
use crate::vgpu::object::StorageType;

/// Variable name for each layout dimension in the logical coordinate
/// convention of Table 1 (`x`=W, `y`=H, `s`=slice, `b`=batch, `d`=depth).
fn dim_var(dim: ActDim) -> Expr {
    match dim {
        ActDim::B => Expr::var("b"),
        ActDim::H => Expr::var("y"),
        ActDim::W => Expr::var("x"),
        ActDim::D => Expr::var("d"),
        ActDim::S => Expr::var("s"),
        ActDim::C4 => unreachable!("C4 is the texel lane, not a coordinate"),
    }
}

/// Symbolic storage coordinates for a descriptor, outermost-first matching
/// the native coordinate system:
/// * 1D storages → `[flat_texel]`
/// * 2D textures → `[u, v]`
/// * 3D/array textures → `[u, v, w]`
///
/// Each coordinate is the mixed-radix combination of one coordinate group
/// (see [`TensorDescriptor::coord_groups`]) with shape extents folded.
pub fn translation_coords(desc: &TensorDescriptor) -> Vec<Expr> {
    let groups = desc.coord_groups();
    let mut exprs: Vec<Expr> = groups
        .iter()
        .map(|group| {
            let mut e = Expr::c(0);
            for dim in group {
                let ext = crate::tensor::ActivationLayout::extent(&desc.shape, *dim) as i64;
                // An extent-1 dimension contributes a coordinate that is
                // always 0 — fold the whole term away (this is what makes
                // batch-1 translations free).
                if ext == 1 {
                    continue;
                }
                e = e.mul(Expr::c(ext)).add(dim_var(*dim));
            }
            e
        })
        .collect();
    // Native ordering is innermost-first (u, v, w); groups are outermost-first.
    exprs.reverse();
    exprs
}

/// Generated Read/Write helper source for one tensor argument.
#[derive(Clone, Debug)]
pub struct ReadWriteHelpers {
    /// Argument name as visible to the kernel (`args.src` → `src`).
    pub arg: String,
    /// Generated function source (backend-neutral C-style; the backend
    /// emitters wrap storage-specific access intrinsics around it).
    pub source: String,
    /// The translated coordinate expressions (innermost-first).
    pub coords: Vec<Expr>,
    pub storage: StorageType,
}

/// Emit the helper functions for a descriptor. The body uses placeholder
/// access intrinsics `LOAD_TEXEL` / `STORE_TEXEL` that each backend
/// ([`crate::codegen`]) substitutes with its native construct
/// (`read_imagef`, `tex.read`, `textureLoad`, raw pointer indexing …).
pub fn read_write_helpers(arg: &str, desc: &TensorDescriptor) -> ReadWriteHelpers {
    let coords = translation_coords(desc);
    let coord_src: Vec<String> = coords.iter().map(|e| e.emit()).collect();
    let sig_args = "int b, int x, int y, int d, int s";
    let coord_decl = match desc.storage {
        StorageType::Buffer | StorageType::ImageBuffer => {
            format!("  int idx = {};\n", coord_src[0])
        }
        StorageType::Texture2D => {
            format!("  int u = {};\n  int v = {};\n", coord_src[0], coord_src[1])
        }
        StorageType::Texture2DArray | StorageType::Texture3D => format!(
            "  int u = {};\n  int v = {};\n  int w = {};\n",
            coord_src[0], coord_src[1], coord_src[2]
        ),
    };
    let access = match desc.storage {
        StorageType::Buffer | StorageType::ImageBuffer => "idx",
        StorageType::Texture2D => "u, v",
        StorageType::Texture2DArray | StorageType::Texture3D => "u, v, w",
    };
    let source = format!(
        "FLT4 {arg}_Read({sig_args}) {{\n{coord_decl}  return LOAD_TEXEL({arg}, {access});\n}}\n\
         void {arg}_Write(FLT4 value, {sig_args}) {{\n{coord_decl}  STORE_TEXEL({arg}, {access}, value);\n}}\n"
    );
    ReadWriteHelpers { arg: arg.to_string(), source, coords, storage: desc.storage }
}

/// Numerically validate the symbolic translation against the mapper for
/// every logical coordinate (codegen-time self-check; also used in tests).
pub fn validate_translation(desc: &TensorDescriptor) -> Result<(), String> {
    let mapping = crate::vgpu::mapper::VirtualMapping::single(desc.clone());
    let coords = translation_coords(desc);
    let s = desc.shape;
    for b in 0..s.b {
        for y in 0..s.h {
            for x in 0..s.w {
                for d in 0..s.d {
                    for c in 0..s.c {
                        let env: BTreeMap<&str, i64> = [
                            ("b", b as i64),
                            ("x", x as i64),
                            ("y", y as i64),
                            ("d", d as i64),
                            ("s", (c / 4) as i64),
                        ]
                        .into_iter()
                        .collect();
                        let sym: Vec<usize> =
                            coords.iter().map(|e| e.eval(&env) as usize).collect();
                        let phys = mapping.map(b, y, x, d, c);
                        let want: Vec<usize> = match desc.storage {
                            StorageType::Buffer => vec![phys.coords[0] / 4],
                            StorageType::ImageBuffer => vec![phys.coords[0]],
                            StorageType::Texture2D => vec![phys.coords[0], phys.coords[1]],
                            _ => phys.coords.to_vec(),
                        };
                        if sym != want {
                            return Err(format!(
                                "translation mismatch at (b{b},x{x},y{y},d{d},c{c}): sym {sym:?} vs mapper {want:?}"
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, Shape};
    use crate::util::propcheck::{check, Config};

    fn desc(shape: Shape, storage: StorageType) -> TensorDescriptor {
        TensorDescriptor::with_default_layout("src", shape, DType::F16, storage).unwrap()
    }

    #[test]
    fn table1_formulas_hold_for_all_storages() {
        let shape = Shape::bhwc(2, 3, 4, 9);
        for st in [
            StorageType::Buffer,
            StorageType::ImageBuffer,
            StorageType::Texture2D,
            StorageType::Texture3D,
            StorageType::Texture2DArray,
        ] {
            validate_translation(&desc(shape, st)).unwrap();
        }
    }

    #[test]
    fn batch1_folds_away() {
        // With B = 1 the `* batch + b` term must fold out of the u coord.
        let d2 = desc(Shape::bhwc(1, 2, 3, 5), StorageType::Texture2D);
        let coords = translation_coords(&d2);
        let u = coords[0].emit();
        assert!(!u.contains('b'), "u should not reference b when batch == 1: {u}");
        // With B = 2 it must appear.
        let d2 = desc(Shape::bhwc(2, 2, 3, 5), StorageType::Texture2D);
        let u = translation_coords(&d2)[0].emit();
        assert!(u.contains('b'), "u must reference b when batch == 2: {u}");
    }

    #[test]
    fn helper_source_contains_read_and_write() {
        let h = read_write_helpers("src", &desc(Shape::bhwc(1, 8, 8, 16), StorageType::Texture2D));
        assert!(h.source.contains("src_Read"));
        assert!(h.source.contains("src_Write"));
        assert!(h.source.contains("LOAD_TEXEL(src, u, v)"));
        assert!(h.source.contains("STORE_TEXEL(src, u, v, value)"));
    }

    #[test]
    fn translation_op_count_is_small() {
        // The folded 2D-texture translation for a batch-1 tensor is ≤ 3 ops
        // (y*S + s and x) — the paper's "negligible overhead" claim.
        let d = desc(Shape::bhwc(1, 64, 64, 320), StorageType::Texture2D);
        let total: usize = translation_coords(&d).iter().map(|e| e.op_count()).sum();
        assert!(total <= 4, "folded translation should be tiny, got {total} ops");
    }

    #[test]
    fn property_translation_matches_mapper() {
        check("symbolic translation == mapper", Config::cases(25), |rng| {
            let shape = Shape::bhwc(
                1 + rng.gen_range(2) as usize,
                1 + rng.gen_range(5) as usize,
                1 + rng.gen_range(5) as usize,
                1 + rng.gen_range(12) as usize,
            );
            let st = *rng.choose(&[
                StorageType::Buffer,
                StorageType::ImageBuffer,
                StorageType::Texture2D,
                StorageType::Texture3D,
            ]);
            validate_translation(&desc(shape, st))
        });
    }
}
