//! Wall-clock measurement with warmup and adaptive iteration counts.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Result of one benchmark: timing summary in seconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.summary.mean()
    }

    pub fn mean_ms(&self) -> f64 {
        self.summary.mean() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.summary.mean() * 1e6
    }

    /// One-line report.
    pub fn line(&self) -> String {
        let mean = self.summary.mean();
        let (scale, unit) = if mean < 1e-6 {
            (1e9, "ns")
        } else if mean < 1e-3 {
            (1e6, "µs")
        } else if mean < 1.0 {
            (1e3, "ms")
        } else {
            (1.0, "s")
        };
        format!(
            "{:<48} {:>10.3} {unit}/iter (±{:.1}%, n={})",
            self.name,
            mean * scale,
            if mean > 0.0 { self.summary.stddev() / mean * 100.0 } else { 0.0 },
            self.summary.len()
        )
    }
}

/// Benchmark runner with warmup and target measurement time.
pub struct Bencher {
    /// Warmup duration before sampling.
    pub warmup: Duration,
    /// Target total sampling time.
    pub measure: Duration,
    /// Maximum number of samples collected.
    pub max_samples: usize,
    /// Minimum number of samples collected (even if over time budget).
    pub min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(800),
            max_samples: 200,
            min_samples: 10,
        }
    }
}

impl Bencher {
    /// Quick preset for cheap closures in unit-ish benches.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(200),
            max_samples: 64,
            min_samples: 5,
        }
    }

    /// Measure `f`, returning seconds-per-iteration samples.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup and estimate per-iter cost.
        let warm_start = Instant::now();
        let mut iters_per_sample = 1u64;
        let mut t = Instant::now();
        f();
        let first = t.elapsed();
        while warm_start.elapsed() < self.warmup {
            f();
        }
        if first < Duration::from_micros(50) {
            // Batch very cheap closures so timer overhead doesn't dominate.
            iters_per_sample = (Duration::from_micros(200).as_nanos() / first.as_nanos().max(1))
                .clamp(1, 10_000) as u64;
        }

        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        BenchResult { name: name.to_string(), summary: Summary::from_samples(samples) }
    }

    /// Measure and print the one-line report.
    pub fn bench<F: FnMut()>(&self, name: &str, f: F) -> BenchResult {
        let r = self.run(name, f);
        println!("{}", r.line());
        r
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box re-export
/// point so benches don't import std paths everywhere).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(40),
            max_samples: 10,
            min_samples: 3,
        };
        let r = b.run("sleep 2ms", || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean_ms() >= 1.5, "mean {} ms", r.mean_ms());
        assert!(r.mean_ms() < 20.0, "mean {} ms", r.mean_ms());
    }

    #[test]
    fn batches_cheap_closures() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.summary.len() >= 5);
        assert!(r.mean_s() < 1e-5);
    }
}
