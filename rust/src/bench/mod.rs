//! Benchmark harness (criterion substitute) + paper-style table rendering.
//!
//! Each `rust/benches/bench_*.rs` binary uses [`Bencher`] for wall-clock
//! measurements of real code paths and [`Table`] to print rows in the same
//! arrangement as the paper's tables/figures so EXPERIMENTS.md can show
//! paper-vs-measured side by side.

pub mod harness;
pub mod table;
pub mod trajectory;

pub use harness::{Bencher, BenchResult};
pub use table::Table;
pub use trajectory::{check_trajectory, validate_schema, TrajectoryCheck, TOLERANCE};
