//! Paper-style table rendering for bench outputs.

/// A simple column-aligned table with a title, printed to stdout.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                line.push_str(&cells[i]);
                line.push_str(&" ".repeat(pad));
                if i + 1 < ncols {
                    line.push_str("  ");
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: value with a paper reference in parens, e.g. `37.1 (37.1)`.
pub fn vs_paper(measured: f64, paper: f64, decimals: usize) -> String {
    format!("{measured:.decimals$} (paper {paper:.decimals$})")
}

/// Format a speedup factor.
pub fn speedup(ours: f64, theirs: f64) -> String {
    if theirs <= 0.0 || ours <= 0.0 {
        return "—".to_string();
    }
    format!("{:.2}×", ours / theirs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "prefill", "decode"]);
        t.row_strs(&["Gemma2 2B", "1370", "37.1"]);
        t.row_strs(&["Llama3.1 8B", "412", "12.7"]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("Gemma2 2B    1370     37.1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["1"]);
    }

    #[test]
    fn helpers() {
        assert_eq!(vs_paper(36.9, 37.1, 1), "36.9 (paper 37.1)");
        assert_eq!(speedup(10.0, 5.0), "2.00×");
        assert_eq!(speedup(1.0, 0.0), "—");
    }
}
